# One-step wrappers around the repo's verify/benchmark commands.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-quick bench-backends

# Tier-1 verify (ROADMAP.md).
test:
	$(PYTHON) -m pytest -x -q

# Skip the multi-device subprocess tests.
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# Full benchmark harness at reduced size.
bench-quick:
	$(PYTHON) -m benchmarks.run --quick

# Just the reduce-backend comparison section.
bench-backends:
	$(PYTHON) -m benchmarks.run --quick --sections backends
