# One-step wrappers around the repo's verify/benchmark commands.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-elastic test-plan bench-quick bench-backends \
	bench-cluster bench-phases bench-elastic bench-pipeline bench-obs \
	bench-service bench-resource bench-combine bench-check trace-demo \
	lint

# Tier-1 verify (ROADMAP.md).
test:
	$(PYTHON) -m pytest -x -q

# Ruff lint (config in pyproject.toml); skips gracefully when ruff is
# absent locally — CI always installs it.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi

# Skip the multi-device subprocess tests.
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# Just the elastic subsystem (resumable engine, snapshots, regrant
# scheduling); skips the slow wave-stepping EngineOracle tests.
test-elastic:
	$(PYTHON) -m pytest -x -q -m "not slow" tests/test_elastic.py

# The ExecutionPlan mode-equivalence suite (fused == traced == sharded
# == resumable, bit-exact, every backend combination).
test-plan:
	$(PYTHON) -m pytest -x -q -m "not slow" tests/test_plan.py

# Full benchmark harness at reduced size.  BENCH_FLAGS passes extra
# harness args (e.g. the CI bench-smoke job's tiny --tokens grid).
bench-quick:
	$(PYTHON) -m benchmarks.run --quick $(BENCH_FLAGS)

# Bench-regression guard: quick harness + comparison against the
# committed experiments/bench/BENCH_*.json baselines (>25% makespan/SLO
# regression fails).  CI's bench-smoke job runs this.
bench-check:
	$(PYTHON) -m benchmarks.run --quick --check $(BENCH_FLAGS)

# Just the reduce-backend comparison section.
bench-backends:
	$(PYTHON) -m benchmarks.run --quick --sections backends

# Just the predictive-scheduler policy comparison.
bench-cluster:
	$(PYTHON) -m benchmarks.run --quick --sections cluster

# Just the per-phase telemetry + decomposed-models section.
bench-phases:
	$(PYTHON) -m benchmarks.run --quick --sections phases

# Just the elastic regrant-scheduling comparison.
bench-elastic:
	$(PYTHON) -m benchmarks.run --quick --sections elastic

# Just the pipelined-vs-fused speedup + overlap-depth model axis.
bench-pipeline:
	$(PYTHON) -m benchmarks.run --quick --sections pipeline

# Just the observability section: span-tiling validation + drift-alarm
# recovery experiment (lands run.trace.json / metrics.json artifacts).
bench-obs:
	$(PYTHON) -m benchmarks.run --quick --sections obs

# Just the service section: SLO burn-rate overload control vs a static
# admission cap on a flash-crowd stream (lands service.trace.json /
# service.prom artifacts; gated on p99 turnaround + SLO-good goodput).
bench-service:
	$(PYTHON) -m benchmarks.run --quick --sections service

# Just the resource section: fabric-aware vs blind scheduling on a
# contended fabric (makespan_win gated) + heldout per-(phase, resource)
# CPU/net model error (lands resource.trace.json with the fabric/CPU
# counter tracks).
bench-resource:
	$(PYTHON) -m benchmarks.run --quick --sections resource

# Just the combine section: map-side combining — live-engine shuffle-byte
# contraction on skewed WordCount (net_reduction gated, bit-exactness
# asserted in-bench), contended-fabric makespan win from opening the
# combiner axis (contended_win gated), and heldout combined-bytes model
# error (lands combine.trace.json with the combine phase counters).
bench-combine:
	$(PYTHON) -m benchmarks.run --quick --sections combine

# Small committed example trace: a contended elastic run with
# suspend-to-disk, exported as Chrome trace-event JSON + service metrics.
# Open examples/trace_demo/run.trace.json in Perfetto (ui.perfetto.dev).
trace-demo:
	$(PYTHON) -m repro.launch.cluster --jobs 25 --workers 6 --seed 1 \
		--policies predict-elastic --elastic --suspend \
		--mean-interarrival 0.08 --arrival bursty \
		--trace-out examples/trace_demo/run.trace.json \
		--metrics-out examples/trace_demo/metrics.json
