"""§Perf hillclimb runner: evaluate named CellConfig variants for one cell
and append structured results to experiments/perf_log.json.

    PYTHONPATH=src python experiments/hillclimb.py --arch arctic-480b \
        --shape train_4k --variant baseline --variant no_fsdp ...

Variants are defined in VARIANTS below; each is (CellConfig overrides,
optional ModelConfig transform). The log records the full roofline report
per variant so EXPERIMENTS.md §Perf can cite before/after.
"""

import os
import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--mesh", default="16x16")
ap.add_argument("--variant", action="append", default=[])
ap.add_argument("--devices", type=int, default=256)
ap.add_argument("--log", default="experiments/perf_log.json")
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}"
)

import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.launch import cells  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

# variant name -> dict of CellConfig overrides (+ special keys:
#   "cfg_fn": ModelConfig -> ModelConfig transform applied before build)
VARIANTS: dict[str, dict] = {
    "baseline": {},
    "no_fsdp": {"fsdp": False},
    "fsdp": {"fsdp": True},
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": "none"},
    "mb2": {"microbatch": 2},
    "mb4": {"microbatch": 4},
    "mb8": {"microbatch": 8},
    "logits_chunk_512": {"logits_chunk": 512},
    "logits_chunk_1024": {"logits_chunk": 1024},
    "opt_bf16": {"opt_state_dtype": "bfloat16"},
    "moe_groups_256": {"moe_n_groups": 256},
    "moe_groups_64": {"moe_n_groups": 64},
    "moe_groups_16": {"moe_n_groups": 16},
    "cap_1_0": {
        "cfg_fn": lambda cfg: dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
        )
    },
    "mb4_opt_bf16": {"microbatch": 4, "opt_state_dtype": "bfloat16"},
    "mb4_remat_dots": {"microbatch": 4, "remat": "dots"},
    "mb8_opt_bf16": {"microbatch": 8, "opt_state_dtype": "bfloat16"},
    "mb4_opt_bf16_groups64": {
        "microbatch": 4, "opt_state_dtype": "bfloat16", "moe_n_groups": 64,
    },
    "mb4_opt_bf16_chunk512": {
        "microbatch": 4, "opt_state_dtype": "bfloat16", "logits_chunk": 512,
    },
}


def main() -> None:
    shape_dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = (("pod", "data", "model") if len(shape_dims) == 3
            else ("data", "model"))
    mesh = make_mesh(shape_dims, axes)
    log = []
    if os.path.exists(args.log):
        with open(args.log) as f:
            log = json.load(f)
    for name in args.variant or ["baseline"]:
        spec = dict(VARIANTS[name])
        cfg = C.get_config(args.arch)
        cfg_fn = spec.pop("cfg_fn", None)
        if cfg_fn is not None:
            cfg = cfg_fn(cfg)
        base = cells.default_cell_config(cfg, C.SHAPES[args.shape])
        cell = dataclasses.replace(base, **spec)
        t0 = time.time()
        try:
            r = cells.analyze_cell_extrapolated(
                args.arch, args.shape, mesh, cell=cell, cfg=cfg
            )
            roof = r["roofline"]
            entry = {
                "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                "variant": name, "ok": True,
                "roofline": roof,
                "peak_gib": r["memory"]["peak_bytes"] / 2**30,
                "compile_s": time.time() - t0,
            }
            print(
                f"{name:28s} dom={roof['dominant']:10s} "
                f"step={roof['step_time_no_overlap']:8.3f}s "
                f"C={roof['compute_s']:7.3f} M={roof['memory_s']:8.3f} "
                f"X={roof['collective_s']:8.3f} "
                f"frac={roof['roofline_fraction'] or 0:.4f} "
                f"peak={entry['peak_gib']:8.2f}GiB",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            entry = {
                "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                "variant": name, "ok": False, "error": repr(e)[:500],
            }
            print(f"{name:28s} FAILED: {e}", flush=True)
        log.append(entry)
        with open(args.log, "w") as f:
            json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
