"""Render the §Roofline markdown table from dry-run artifacts + perf log.

    PYTHONPATH=src python experiments/render_roofline_md.py >> EXPERIMENTS.md
"""

import glob
import json
import os


def rows_from(dryrun_dir):
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        r = json.load(open(path))
        mesh = os.path.basename(os.path.dirname(path))
        roof, meta = r["roofline"], r["meta"]
        out.append({
            "mesh": mesh, "arch": meta["arch"], "shape": meta["shape"],
            "C": roof["compute_s"], "M": roof["memory_s"],
            "X": roof["collective_s"],
            "step": roof["step_time_no_overlap"],
            "dom": roof["dominant"],
            "useful": roof.get("useful_ratio") or 0,
            "frac": roof.get("roofline_fraction") or 0,
            "peak": r["memory"]["peak_bytes"] / 2**30,
        })
    return out


def main():
    print("\n#### Baseline roofline table (single-pod 16x16; terms s/device)\n")
    print("| arch | shape | C | M | X | step | dominant | useful | frac | peak GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows_from("experiments/dryrun"):
        if r["mesh"] != "single_pod_16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['C']:.3f} | {r['M']:.3f} "
              f"| {r['X']:.3f} | {r['step']:.3f} | {r['dom']} "
              f"| {r['useful']:.2f} | {r['frac']:.4f} | {r['peak']:.1f} |")
    print("\n#### Multi-pod (2x16x16) — compile proof + terms\n")
    print("| arch | shape | step | dominant | peak GiB |")
    print("|---|---|---|---|---|")
    for r in rows_from("experiments/dryrun"):
        if r["mesh"] != "multi_pod_2x16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['step']:.3f} "
              f"| {r['dom']} | {r['peak']:.1f} |")
    # optimized entries from the perf log
    if os.path.exists("experiments/perf_log.json"):
        log = json.load(open("experiments/perf_log.json"))
        print("\n#### §Perf optimized cells (post-hillclimb defaults)\n")
        print("| arch | shape | variant | step | frac | peak GiB |")
        print("|---|---|---|---|---|---|")
        for e in log:
            if not e.get("ok"):
                continue
            roof = e["roofline"]
            print(f"| {e['arch']} | {e['shape']} | {e['variant']} "
                  f"| {roof['step_time_no_overlap']:.3f} "
                  f"| {roof.get('roofline_fraction') or 0:.4f} "
                  f"| {e['peak_gib']:.1f} |")


if __name__ == "__main__":
    main()
