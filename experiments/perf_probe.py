"""Hillclimb probe: compile ONE shallow unrolled group of a cell and dump
collective breakdown + biggest HLO buffers, under a given CellConfig.

    PYTHONPATH=src python experiments/perf_probe.py --arch arctic-480b \
        --shape train_4k [--devices 256] [--fsdp/--no-fsdp] [...]
"""

import os

import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="arctic-480b")
ap.add_argument("--shape", default="train_4k")
ap.add_argument("--devices", type=int, default=256)
ap.add_argument("--mesh", default="16x16",
                help="e.g. 16x16 or 2x16x16 (pod,data,model)")
ap.add_argument("--fsdp", dest="fsdp", action="store_true", default=None)
ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
ap.add_argument("--remat", default=None)
ap.add_argument("--logits-chunk", type=int, default=None)
ap.add_argument("--microbatch", type=int, default=None)
ap.add_argument("--opt-dtype", default=None)
ap.add_argument("--moe-groups", type=int, default=None)
ap.add_argument("--depth-groups", type=int, default=1)
ap.add_argument("--dump-hlo", default=None)
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}"
)

import dataclasses  # noqa: E402
import re  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.core import costmodel  # noqa: E402
from repro.launch import cells  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

shape_dims = tuple(int(x) for x in args.mesh.split("x"))
axes = ("pod", "data", "model") if len(shape_dims) == 3 else ("data", "model")
mesh = make_mesh(shape_dims, axes)

cfg = C.get_config(args.arch)
shape = C.SHAPES[args.shape]
cell = cells.default_cell_config(cfg, shape)
over = {}
if args.fsdp is not None:
    over["fsdp"] = args.fsdp
if args.remat:
    over["remat"] = args.remat
if args.logits_chunk is not None:
    over["logits_chunk"] = args.logits_chunk
if args.microbatch is not None:
    over["microbatch"] = args.microbatch
if args.opt_dtype:
    over["opt_state_dtype"] = args.opt_dtype
if args.moe_groups is not None:
    over["moe_n_groups"] = args.moe_groups
cell = dataclasses.replace(cell, unroll_layers=True, **over)
cfg_shallow = dataclasses.replace(
    cfg, n_layers=args.depth_groups * cfg.pattern_period
)
from repro.sharding.context import use_mesh  # noqa: E402

built = cells.build_cell(args.arch, args.shape, mesh, cell=cell,
                         cfg=cfg_shallow)
with use_mesh(mesh):
    lowered = built["jitted"].lower(*built["args"])
compiled = lowered.compile()
cost = compiled.cost_analysis()
text = compiled.as_text()
coll = costmodel.parse_collectives(text)
mem = compiled.memory_analysis()
print(f"== {args.arch} x {args.shape} @ {args.mesh}, "
      f"depth={args.depth_groups} group(s), cell={cell}")
print(f"flops/dev {cost.get('flops', 0):.3e}  "
      f"bytes/dev {cost.get('bytes accessed', 0):.3e}")
print(f"peak/dev {(mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30:.2f} GiB "
      f"(args {mem.argument_size_in_bytes / 2**30:.2f}, "
      f"temp {mem.temp_size_in_bytes / 2**30:.2f})")
print("collectives (bytes, count):")
for kind in coll.bytes_by_kind:
    if coll.count_by_kind[kind]:
        print(f"  {kind:20s} {coll.bytes_by_kind[kind]:.3e}  "
              f"x{coll.count_by_kind[kind]}")

# biggest collective ops
sizes = []
for line in text.splitlines():
    m = re.search(
        r"=\s+(?P<shape>\S+)\s+(?P<kind>all-gather|all-reduce|"
        r"reduce-scatter|all-to-all|collective-permute)(-start)?\(",
        line)
    if m and "-done(" not in line:
        nbytes = costmodel._shape_bytes(m.group("shape"))
        sizes.append((nbytes, m.group("kind"),
                      line.strip()[:140]))
sizes.sort(reverse=True)
print("\ntop-10 collective ops:")
for nbytes, kind, line in sizes[:10]:
    print(f"  {nbytes / 2**20:9.1f}MiB {kind:18s} {line[:120]}")

if args.dump_hlo:
    with open(args.dump_hlo, "w") as f:
        f.write(text)
    print(f"\nHLO written to {args.dump_hlo} ({len(text)} chars)")
