"""§Perf-llama3: the paper's technique hillclimbs the framework.

Configuration space for llama3-8b x train_4k on 256 chips:
    p1 = log2(data_axis)   (data, model) factorizations of 256
    p2 = microbatch in {1, 2, 4, 8}
— the modern analogue of the paper's (#mappers, #reducers).

Profiling phase: analytic step-time (shallow-probe roofline extrapolation,
`cells.estimate_step_time`) on a stratified SAMPLE of the space.
Modeling: the paper's cubic regression (+ cross terms, scaled — the tuner
defaults). Prediction: argmin over the whole space.  Validation: profile
every space point and report tuner regret.

    PYTHONPATH=src python experiments/tune_llama3.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.core import fit  # noqa: E402
from repro.launch import cells  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

ARCH, SHAPE = "llama3-8b", "train_4k"
FACTORIZATIONS = [(256, 1), (64, 4), (16, 16), (4, 64), (1, 256),
                  (128, 2), (32, 8), (8, 32), (2, 128)]
# second knob: loss logits-chunk size (0 = unchunked). NOTE: microbatch was
# the original second knob but unrolled-microbatch probes broke the secant
# extrapolation (XLA dedups repeated microbatch bodies at depth 2, giving
# p2 < p1 and negative extrapolated costs — recorded as a refuted
# methodology iteration in EXPERIMENTS.md §Perf-llama3).
LOGITS_CHUNKS = [0, 512, 2048]

_cache: dict = {}


def profile(log2_data: float, chunk: float) -> dict:
    d = int(round(2 ** log2_data))
    m = 256 // d
    key = (d, int(chunk))
    if key not in _cache:
        mesh = make_mesh((d, m), ("data", "model"))
        cfg = C.get_config(ARCH)
        cell = dataclasses.replace(
            cells.default_cell_config(cfg, C.SHAPES[SHAPE]),
            logits_chunk=int(chunk),
        )
        t0 = time.time()
        try:
            r = cells.estimate_step_time(ARCH, SHAPE, mesh, cell=cell)
            r["wall_s"] = time.time() - t0
        except Exception as e:  # noqa: BLE001 — infeasible cell
            r = {"step_s": float("inf"), "error": repr(e)[:200]}
        _cache[key] = r
    return _cache[key]


def main() -> None:
    space = np.asarray(
        [[np.log2(d), ch] for d, _ in FACTORIZATIONS
         for ch in LOGITS_CHUNKS]
    )
    # stratified sample: every third point (9/27 profiles)
    sample = space[::3]
    print(f"profiling {len(sample)}/{len(space)} configs ...")
    times = []
    for log2_d, mb in sample:
        r = profile(log2_d, mb)
        times.append(r["step_s"])
        print(f"  data=2^{int(log2_d)} model={256 >> int(log2_d)} chunk={int(mb)}: "
              f"step={r['step_s']:.3f}s (C={r.get('compute_s', 0):.2f} "
              f"M={r.get('memory_s', 0):.2f} X={r.get('collective_s', 0):.2f})",
              flush=True)
    finite = np.isfinite(times)
    model = fit(sample[finite], np.asarray(times)[finite],
                degree=3, cross_terms=True, scale=True, lam=1e-8)
    print(f"model fit: train MAPE {model.train_mape:.1f}% R2 {model.r2:.3f}")
    preds = np.asarray(model.predict(space), dtype=np.float64).ravel()
    best_idx = int(np.nanargmin(preds))
    bd, bmb = space[best_idx]
    print(f"\npredicted best: data=2^{int(bd)} "
          f"model={256 >> int(bd)} chunk={int(bmb)} "
          f"(predicted {preds[best_idx]:.3f}s)")

    print("\nexhaustive validation ...")
    actual = []
    for log2_d, mb in space:
        r = profile(log2_d, mb)
        actual.append(r["step_s"])
        print(f"  data=2^{int(log2_d)} chunk={int(mb)}: {r['step_s']:.3f}s",
              flush=True)
    actual = np.asarray(actual)
    true_best = int(np.nanargmin(actual))
    regret = (actual[best_idx] - actual[true_best]) / actual[true_best] * 100
    print(f"\ntrue best: data=2^{int(space[true_best][0])} "
          f"model={256 >> int(space[true_best][0])} "
          f"chunk={int(space[true_best][1])} ({actual[true_best]:.3f}s)")
    print(f"tuner-chosen config actual: {actual[best_idx]:.3f}s "
          f"-> regret {regret:.1f}% using {len(sample)}/{len(space)} profiles")
    out = {
        "space": space.tolist(),
        "sampled": sample.tolist(),
        "sample_times": list(map(float, times)),
        "predictions": preds.tolist(),
        "actual": actual.tolist(),
        "chosen": space[best_idx].tolist(),
        "true_best": space[true_best].tolist(),
        "regret_pct": float(regret),
        "profiles": {f"{k[0]}x{k[1]}": {kk: vv for kk, vv in v.items()
                                        if kk != "error"}
                     for k, v in _cache.items()},
    }
    with open("experiments/tune_llama3_result.json", "w") as f:
        json.dump(out, f, indent=1)
    print("written experiments/tune_llama3_result.json")


if __name__ == "__main__":
    main()
