"""Deployment-path roofline: replace the XLA attention's S^2 logits traffic
with the Pallas flash kernel's O(S*d) streaming traffic, analytically.

The dry-run measures the XLA reference attention because Pallas custom
calls hide FLOPs/bytes from cost_analysis (EXPERIMENTS.md §Dry-run).  On
TPU the deployment path uses `repro.kernels.flash_attention`, whose HBM
traffic per (batch, head, q-block) is one pass over Q/K/V/O tiles; the S^2
score matrix lives only in VMEM.  This script recomputes the memory term of
train/prefill cells under that substitution:

    removed per layer  = logits-chain bytes ~= r * B*H*Sq*Sk*4   (fp32)
      (r = number of times cost_analysis touches the scores chain; we take
       the conservative r = 6: QK write, mask read+write, softmax
       read+write, PV read — matching the measured per-layer byte deltas)
    added per layer    = flash passes: (2*B*Sq*Hq*hd + 2*B*Sk*Hkv*hd) * 2B
                         * (fwd + recompute-in-bwd + bwd ~= 3)

Output: adjusted memory term + step time per cell, appended to
experiments/perf_log.json as variant "flash_deploy_adjusted".
"""

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.costmodel import HBM_BW, PEAK_FLOPS_BF16, ICI_BW

R_TOUCHES = 6.0
PASSES = 3.0  # fwd + remat-recompute + bwd


def adjust(report: dict) -> dict | None:
    meta = report["meta"]
    cfg = get_config(meta["arch"])
    shape = SHAPES[meta["shape"]]
    if shape.kind == "decode":
        return None
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    if n_attn == 0:
        return None
    roof = report["roofline"]
    n_dev = roof["n_devices"]
    B, S = shape.global_batch, shape.seq_len
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    # per-device global-work share
    logits_bytes = R_TOUCHES * B * hq * S * S * 4 * n_attn * PASSES / n_dev
    flash_bytes = (
        (2 * B * S * hq * hd + 2 * B * S * hkv * hd) * 2 * n_attn
        * PASSES / n_dev
    )
    new_mem_bytes = max(
        roof["hbm_bytes"] - logits_bytes + flash_bytes, flash_bytes
    )
    new_memory_s = new_mem_bytes / HBM_BW
    step = max(roof["compute_s"], new_memory_s) + roof["collective_s"]
    frac = (
        (roof["model_flops"] / n_dev / step) / PEAK_FLOPS_BF16
        if roof.get("model_flops") else None
    )
    return {
        "arch": meta["arch"], "shape": meta["shape"],
        "mesh": "16x16", "variant": "flash_deploy_adjusted", "ok": True,
        "roofline": {
            **roof,
            "hbm_bytes": new_mem_bytes,
            "memory_s": new_memory_s,
            "step_time_no_overlap": step,
            "roofline_fraction": frac,
            "dominant": max(
                {"compute": roof["compute_s"], "memory": new_memory_s,
                 "collective": roof["collective_s"]}.items(),
                key=lambda kv: kv[1],
            )[0],
        },
        "note": f"analytic: -{logits_bytes:.3e}B logits chain, "
                f"+{flash_bytes:.3e}B flash streaming",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun/single_pod_16x16")
    ap.add_argument("--log", default="experiments/perf_log.json")
    args = ap.parse_args()
    log = []
    if os.path.exists(args.log):
        log = json.load(open(args.log))
    for path in sorted(glob.glob(os.path.join(args.dryrun_dir, "*.json"))):
        r = json.load(open(path))
        adj = adjust(r)
        if adj is None:
            continue
        old = r["roofline"]["step_time_no_overlap"]
        new = adj["roofline"]["step_time_no_overlap"]
        print(f"{r['meta']['arch']:24s} {r['meta']['shape']:12s} "
              f"step {old:8.3f}s -> {new:8.3f}s "
              f"frac {r['roofline']['roofline_fraction'] or 0:.4f} -> "
              f"{adj['roofline']['roofline_fraction'] or 0:.4f}")
        log.append(adj)
    with open(args.log, "w") as f:
        json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
