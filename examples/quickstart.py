"""Quickstart: the paper's 3-phase pipeline in ~40 lines.

Profile a WordCount MapReduce job under different (#mappers, #reducers)
settings, fit the multivariate cubic regression (Eqn. 6), and predict the
execution time of unseen configurations.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ModelDatabase, fit, grid, profile_experiments
from repro.mapreduce import JobConfig, build_job, wordcount, wordcount_corpus

# --- the application (black box to the modeling pipeline) -----------------
corpus = wordcount_corpus(1 << 15, vocab_size=2048, seed=0)
app = wordcount(2048)
_jobs: dict = {}


def run_job(config) -> float:
    """Total execution time (s) of one WordCount run under `config`."""
    import time, jax
    M, R = int(config[0]), int(config[1])
    if (M, R) not in _jobs:
        _jobs[(M, R)] = build_job(
            app, JobConfig(num_mappers=M, num_reducers=R), len(corpus)
        )
        jax.block_until_ready(_jobs[(M, R)](corpus))  # warmup (job setup)
    t0 = time.perf_counter()
    jax.block_until_ready(_jobs[(M, R)](corpus))
    return time.perf_counter() - t0


# --- phase 1: profiling (paper Fig. 2a; 5 repeats, mean) -------------------
configs = grid([(5, 40, 12), (5, 40, 12)])  # 16 experiments
prof = profile_experiments(run_job, configs, repeats=5,
                           param_names=("mappers", "reducers"), verbose=True)

# --- phase 2: modeling (Eqn. 6: A = (P^T P)^-1 P^T T) ----------------------
model = fit(prof.params, prof.times)
print(f"\nfit: train MAPE {model.train_mape:.2f}%  R^2 {model.r2:.3f}")
print("coefficients:", dict(zip(model.spec.column_names(),
                                np.round(model.coef, 6))))

# --- phase 3: prediction (paper Fig. 2b) -----------------------------------
db = ModelDatabase()
db.put("wordcount", "this-host", model)
for m, r in [(10, 10), (24, 7), (37, 30)]:
    pred = db.predict("wordcount", "this-host", [m, r])
    actual = np.mean([run_job((m, r)) for _ in range(3)])
    print(f"M={m:2d} R={r:2d}: predicted {pred * 1e3:7.2f}ms  "
          f"actual {actual * 1e3:7.2f}ms  "
          f"err {abs(pred - actual) / actual * 100:5.1f}%")
