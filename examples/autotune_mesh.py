"""Beyond-paper closure: the paper's config->time model autotunes the MESH.

The paper's configuration parameters are (#mappers, #reducers); the exact
analogue for a distributed JAX workload is the mesh factorization
(data_parallel x model_parallel).  This example:

1. enumerates (data, model) factorizations of a 32-chip slice;
2. "profiles" a llama-style train step under a SAMPLE of them using the
   analytic roofline timer from the compiled dry-run (this container has no
   TPU — on real hardware, swap in `core.profiler.timeit`);
3. fits the paper's regression on log2(data_axis) as the parameter;
4. predicts the best factorization and validates against the exhaustive
   sweep.

    PYTHONPATH=src python examples/autotune_mesh.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=32")

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.core import fit, mesh_factorizations  # noqa: E402
from repro.launch import cells  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def analytic_step_time(arch_cfg, shape_name, data_ax, model_ax) -> float:
    mesh = make_mesh((data_ax, model_ax), ("data", "model"))
    r = cells.analyze_cell_extrapolated(
        arch_cfg.name, shape_name, mesh, cfg=arch_cfg
    )
    roof = r["roofline"]
    return roof["step_time_no_overlap"]


def main() -> None:
    # scaled-down llama so 32 host devices + CPU compiles stay snappy
    cfg = dataclasses.replace(
        C.smoke_config("llama3-8b"),
        name="llama3-8b", d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, n_layers=4, vocab_size=8192, param_dtype="bfloat16",
    )
    shape_name = "train_4k"
    C.SHAPES[shape_name] = dataclasses.replace(
        C.SHAPES[shape_name], seq_len=512, global_batch=32
    )
    space = mesh_factorizations(32, min_axis=1)  # (1,32) ... (32,1)
    print(f"config space: {[tuple(map(int, r)) for r in space]}")

    # profile a sample (every other factorization)
    sample = space[::2]
    times = []
    for d, m in sample:
        t = analytic_step_time(cfg, shape_name, int(d), int(m))
        times.append(t)
        print(f"profiled data={int(d):2d} model={int(m):2d}: "
              f"{t * 1e3:8.2f}ms (analytic)")
    # model on log2(data) — the natural smooth parameterization
    x = np.log2(sample[:, :1])
    model = fit(x, np.asarray(times), degree=3, scale=True, lam=1e-9)
    pred = np.asarray(model.predict(np.log2(space[:, :1])))
    best = int(np.argmin(pred))
    print(f"\npredicted best: data={int(space[best][0])} "
          f"model={int(space[best][1])} "
          f"({float(pred[best]) * 1e3:.2f}ms predicted)")

    # validate against exhaustive
    full = [analytic_step_time(cfg, shape_name, int(d), int(m))
            for d, m in space]
    true_best = int(np.argmin(full))
    chosen_time = full[best]
    regret = (chosen_time - full[true_best]) / full[true_best] * 100
    print(f"exhaustive best: data={int(space[true_best][0])} "
          f"model={int(space[true_best][1])} "
          f"({full[true_best] * 1e3:.2f}ms)")
    print(f"tuner regret: {regret:.2f}% using {len(sample)}/{len(space)} "
          f"profiles")


if __name__ == "__main__":
    main()
