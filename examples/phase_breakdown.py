"""Where does a MapReduce job's time go?  Per-phase telemetry walkthrough.

Runs WordCount and Exim parsing at a few (M, R) settings through the
engine's telemetry path, prints a per-phase time/bytes table, then fits
the decomposed per-phase models next to the paper's monolithic one and
shows both predictions at an unseen setting.

    PYTHONPATH=src python examples/phase_breakdown.py [--tokens N]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import fit
from repro.mapreduce import JobConfig, build_job, eximparse, exim_mainlog, \
    wordcount, wordcount_corpus
from repro.telemetry import PhaseRecorder, collect_traced, \
    fit_phase_models, targets_from_traces
from repro.telemetry.models import TIME_RESOURCE

#: enough settings to determine the paper's cubic 2-param basis (7 coefs).
CONFIGS = [(5, 5), (5, 20), (12, 12), (20, 5), (20, 20), (28, 28),
           (36, 12), (40, 40)]
UNSEEN = (17, 9)


class TracedRunner:
    """Compile-cached traced runs: trace(M, R) -> JobTrace for one app."""

    def __init__(self, app, corpus):
        self.app = app
        self.corpus = corpus
        self.recorder = PhaseRecorder()
        self._jobs: dict = {}

    def __call__(self, config):
        M, R = int(config[0]), int(config[1])
        if (M, R) not in self._jobs:
            job = build_job(
                self.app,
                JobConfig(num_mappers=M, num_reducers=R,
                          capacity_factor=8.0),
                len(self.corpus), recorder=self.recorder,
            )
            job(self.corpus)
            self.recorder.traces.pop()  # warmup (compile) is not telemetry
            self._jobs[(M, R)] = job
        out_keys, out_vals, _ = self._jobs[(M, R)](self.corpus)
        trace = self.recorder.last
        collect_traced(trace, out_keys, out_vals)
        return trace


def profile_phases(runner, configs, repeats):
    params = np.asarray(configs, dtype=np.float64)
    return params, [[runner(row) for _ in range(repeats)] for row in configs]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=1 << 13)
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args()

    for app_name in ("wordcount", "eximparse"):
        if app_name == "wordcount":
            app = wordcount(4096)
            corpus = wordcount_corpus(args.tokens, vocab_size=4096)
        else:
            app = eximparse(1024)
            corpus = exim_mainlog(args.tokens, n_transactions=1024)
        print(f"\n=== {app_name} ({args.tokens} tokens) ===")
        runner = TracedRunner(app, corpus)
        params, traces = profile_phases(runner, CONFIGS, args.repeats)
        targets = targets_from_traces(traces)
        phase_names = traces[0][0].phase_names()

        print(f"{'M':>4} {'R':>4} | "
              + " ".join(f"{p:>10}" for p in phase_names)
              + f" | {'total':>9} {'shuf KiB':>9} {'dropped':>8}")
        for i, (m, r) in enumerate(params):
            times = [targets[(p, TIME_RESOURCE)][i] for p in phase_names]
            shuf_kib = targets[("shuffle", "bytes_out")][i] / 1024
            dropped = traces[i][0].counter("shuffle", "pairs_dropped")
            print(f"{int(m):>4} {int(r):>4} | "
                  + " ".join(f"{t * 1e3:>8.2f}ms" for t in times)
                  + f" | {sum(times) * 1e3:>7.2f}ms {shuf_kib:>9.1f}"
                  f" {int(dropped):>8}")

        phase_models = fit_phase_models(params, targets)
        totals = np.sum(
            [targets[(p, TIME_RESOURCE)] for p in phase_names], axis=0
        )
        monolithic = fit(params, totals)

        trace = runner(UNSEEN)
        actual = trace.phase_time_sum()
        composed = float(phase_models.predict_total(
            np.asarray(UNSEEN, float))[0])
        mono = float(np.asarray(monolithic.predict(
            np.asarray(UNSEEN, float))).ravel()[0])
        print(f"\nunseen (M, R) = {UNSEEN}:")
        print(f"  actual            {actual * 1e3:8.2f}ms")
        print(f"  composed (sum of phase models) "
              f"{composed * 1e3:8.2f}ms  "
              f"err {abs(composed - actual) / actual * 100:5.1f}%")
        print(f"  monolithic (paper)             "
              f"{mono * 1e3:8.2f}ms  "
              f"err {abs(mono - actual) / actual * 100:5.1f}%")
        per_phase = phase_models.predict_phase_times(
            np.asarray(UNSEEN, float)
        )
        breakdown = ", ".join(
            f"{p}={float(v[0]) * 1e3:.2f}ms" for p, v in per_phase.items()
        )
        print(f"  composed breakdown: {breakdown}")


if __name__ == "__main__":
    main()
