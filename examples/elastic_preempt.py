"""Elastic execution walkthrough: preempt, snapshot, regrant, resume.

    PYTHONPATH=src python examples/elastic_preempt.py

Runs a WordCount job through the wave-steppable engine, preempts it
mid-map, persists the wave-boundary snapshot through the checkpoint
manager, restores it template-free ("a different process"), *regrants*
the job from 2 workers to 4, resumes — and verifies the result is
bit-identical to the uninterrupted 2-worker run.  Then prices the
regrant with the cost model the ``predict-elastic`` scheduler uses.
"""

import tempfile

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.cluster import AnalyticOracle
from repro.elastic import (
    RegrantCostModel,
    ResumableJob,
    load_snapshot,
    run_resumable,
    save_snapshot,
)
from repro.mapreduce import JobConfig, collect_results, wordcount, \
    wordcount_corpus


def main():
    corpus = wordcount_corpus(6000, vocab_size=211, seed=1)
    app = wordcount(211)
    cfg = JobConfig(num_mappers=8, num_reducers=4, num_workers=2,
                    capacity_factor=8.0)
    # ResumableJob is the resumable *mode* of the one ExecutionPlan the
    # fused/traced/sharded paths also run (repro.mapreduce.plan), so the
    # wave-stepped results below are bit-exact vs build_job by
    # construction.
    job = ResumableJob(app, cfg, len(corpus))

    # Reference: the uninterrupted run.
    ref = run_resumable(job, corpus)
    ok0, ov0, d0 = job.result(ref)
    print(f"[elastic] uninterrupted: {ref.cursor.waves_executed} "
          f"wave-boundary steps, dropped={int(d0)}")

    # Preempt after 2 map waves, snapshot through the checkpoint manager.
    state = run_resumable(job, corpus, preempt_after=2)
    c = state.cursor
    print(f"[elastic] preempted at boundary: map {c.map_tasks_done}/"
          f"{c.mappers} tasks done, shuffled={c.shuffled}")
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        step, save_s = save_snapshot(mgr, state)
        restored, _, restore_s = load_snapshot(mgr)  # template-free
        print(f"[elastic] snapshot step {step}: save {save_s * 1e3:.1f}ms,"
              f" restore {restore_s * 1e3:.1f}ms")

    # Re-plan the remaining waves under twice the workers and resume.
    restored = job.regrant(restored, 4)
    done = run_resumable(job, corpus, state=restored)
    ok1, ov1, d1 = job.result(done)
    assert np.array_equal(np.asarray(ok0), np.asarray(ok1))
    assert np.array_equal(np.asarray(ov0), np.asarray(ov1))
    assert int(d0) == int(d1)
    assert collect_results(ok1, ov1) == collect_results(ok0, ov0)
    print("[elastic] resumed under W=4: bit-identical to the W=2 run")

    # Price the same regrant the way the scheduler would: predicted
    # remaining time under each grant + the measured checkpoint cost.
    oracle = AnalyticOracle(noise=0.0)
    cost = RegrantCostModel()
    cost.record_overhead(save_s, restore_s)
    progress = c.progress()
    decision = cost.evaluate(
        t_total_current=oracle.time("wordcount", "jnp", len(corpus),
                                    c.mappers, c.reducers, 2),
        t_total_new=oracle.time("wordcount", "jnp", len(corpus),
                                c.mappers, c.reducers, 4),
        progress=progress, current_workers=2, new_workers=4,
    )
    print(f"[elastic] regrant 2->4: remaining {decision.t_remaining_current:.3f}s"
          f" -> {decision.t_remaining_new:.3f}s + overhead "
          f"{decision.overhead_s * 1e3:.1f}ms, gain {decision.gain_s:+.3f}s,"
          f" worth_it={decision.worth_it}")


if __name__ == "__main__":
    main()
