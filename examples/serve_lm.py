"""Batched serving example: prefill + decode loop with a KV cache, plus the
paper's model predicting decode latency as a function of batch size (the
serving-side scheduling use case from the paper's conclusion).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import fit
from repro.models import transformer as tf
from repro.train import StepConfig, build_decode_step


def main() -> None:
    cfg = smoke_config("llama3-8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    decode = jax.jit(build_decode_step(cfg, StepConfig()),
                     donate_argnums=(1,))
    max_len = 128

    def serve_batch(batch_size: int, prompt_len: int = 16,
                    new_tokens: int = 32, time_it: bool = False):
        key = jax.random.PRNGKey(batch_size)
        prompts = jax.random.randint(
            key, (batch_size, prompt_len), 0, cfg.vocab_size, jnp.int32)
        state = tf.init_decode_state(cfg, batch_size, max_len)
        logits, state = decode(params, state, {"tokens": prompts})
        toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        outs = [toks]
        t0 = time.perf_counter()
        for _ in range(new_tokens):
            logits, state = decode(params, state, {"tokens": toks})
            toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            outs.append(toks)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        return jnp.concatenate(outs, 1), dt / new_tokens

    # warm + serve a batch
    generated, per_tok = serve_batch(4)
    print(f"served batch of 4, 32 new tokens, "
          f"{per_tok * 1e3:.2f}ms/token: sample {generated[0][:8].tolist()}")

    # paper technique: decode-latency model over the batch-size knob
    sizes, times = [], []
    for b in (1, 2, 4, 8):
        serve_batch(b, new_tokens=4)  # compile for this shape
        _, t = serve_batch(b, new_tokens=16)
        sizes.append([b])
        times.append(t)
        print(f"batch={b}: {t * 1e3:.2f}ms/token")
    model = fit(np.asarray(sizes, float), np.asarray(times),
                degree=2, scale=True, lam=1e-9)
    pred6 = float(np.asarray(model.predict(np.array([6.0]))).ravel()[0])
    print(f"predicted ms/token at unprofiled batch=6: {pred6 * 1e3:.2f}ms "
          f"-> a scheduler can now pick batch size against an SLO")


if __name__ == "__main__":
    main()
