"""Live-service walkthrough: flash crowd, burn-rate alarm, overload control.

    PYTHONPATH=src python examples/service_overload.py

Serves one seed-deterministic arrival stream — a diurnal Poisson base
hit by a 4.5x flash crowd — twice on the same 8-worker elastic cluster:

1. **no admission control**: every arrival is queued; the crowd backlog
   pushes p99 turnaround to minutes;
2. **burn-rate overload control**: an ``SLOMonitor`` watches the
   completion stream through fast/slow sliding windows, trips a burn
   alarm when the error budget is being consumed too fast, and an
   ``OverloadController`` sheds from the queue head and opens the
   suspend-to-disk valve until the budget recovers.

Along the way it prints the windowed health snapshots, the alarm
transitions, and the controller's auditable decision log — then the
final comparison, plus where the Chrome trace (with its "slo control"
track) landed.
"""

from repro.cluster import (
    AnalyticOracle,
    JobStream,
    PoissonProcess,
    diurnal_rate,
    flash_crowd_rate,
    get_policy,
)
from repro.elastic import ElasticCluster
from repro.obs import (
    ClusterMetrics,
    ControlledPolicy,
    OverloadController,
    SLOMonitor,
    SLOPolicy,
    SpanRecorder,
)

SLO_TARGET_S = 6.0       # good = turnaround within 6 s
N_JOBS = 400


def make_stream():
    """~0.85 jobs/s diurnal base; 4.5x flash crowd over t in [120, 200)."""
    rate = flash_crowd_rate(
        diurnal_rate(0.85, amplitude=0.3, period_s=600.0),
        [(120.0, 200.0, 4.5)],
    )
    return JobStream(
        PoissonProcess(rate, peak_rate=0.85 * 1.3 * 4.5, seed=11), seed=11
    )


def serve(policy, label):
    metrics = ClusterMetrics(window_s=30.0)
    cluster = ElasticCluster(8, AnalyticOracle(noise=0.02, seed=11))
    cluster.metrics = metrics

    def on_health(now, snap):
        w = snap.get("windowed") or {}
        p99 = w.get("p99_turnaround_s")
        print(f"  [{label}] t={now:6.1f}  queue={snap['queue_depth']:>3}  "
              f"busy={snap['busy_workers']}/8  "
              f"susp={snap['suspended_jobs']}  win p99="
              f"{'n/a' if p99 is None else format(p99, '.2f') + 's'}")

    result = cluster.run_service(
        make_stream(), policy, until_jobs=N_JOBS,
        health_every=60.0, on_health=on_health,
    )
    done = sorted(r.turnaround for r in result.records if r.completed)
    p99 = done[max(0, round(0.99 * len(done)) - 1)]
    good = sum(1 for t in done if t <= SLO_TARGET_S)
    print(f"  [{label}] completed={len(done)}  "
          f"rejected={sum(1 for r in result.records if not r.admitted)}  "
          f"good={good}  p99={p99:.2f}s")
    return result, p99


def main():
    print(f"=== arm 1: no admission control ({N_JOBS} jobs) ===")
    _, p99_naive = serve(get_policy("fifo-static"), "naive")

    print("\n=== arm 2: burn-rate overload control ===")
    monitor = SLOMonitor(
        SLOPolicy(SLO_TARGET_S, objective=0.95),
        fast_window_s=15.0, slow_window_s=60.0,
        trip_burn=1.5, clear_burn=0.5,
    )
    controller = OverloadController(monitor, queue_floor=4, max_suspended=1)
    policy = ControlledPolicy(get_policy("fifo-static"), controller)
    result, p99_ctrl = serve(policy, "burn")

    print("\nalarm transitions:")
    for a in monitor.alarms:
        print(f"  {a.event:<5} t={a.t:7.1f}  burn fast={a.burn_fast:5.2f} "
              f"slow={a.burn_slow:5.2f}  "
              f"budget remaining={a.budget_remaining_frac:+.2f}")

    print("\ncontroller decision log (first 10):")
    for a in controller.log[:10]:
        who = "" if a.job_id is None else f" job {a.job_id}"
        print(f"  t={a.t:7.1f}  {a.action:<7}{who:<9} {a.reason}")
    print(f"  ... {len(controller.log)} decisions total: "
          f"{sum(1 for a in controller.log if a.action == 'shed')} sheds, "
          f"{sum(1 for a in controller.log if a.action == 'suspend')} "
          f"suspends")

    budget = monitor.budget()
    print(f"\nerror budget: {budget['bad_events']} bad of "
          f"{budget['events']} completions "
          f"(allowed {budget['allowed_bad']:.1f}; "
          f"remaining {budget['remaining_frac']:+.1%})")
    print(f"p99 turnaround: naive {p99_naive:.2f}s -> "
          f"controlled {p99_ctrl:.2f}s")

    # The controlled run's span tree, ring-limited to the last 100 jobs,
    # with the control decisions as a Chrome "slo control" track.
    rec = SpanRecorder(max_jobs=100)
    rec.record(result, control_log=controller.log)
    assert rec.check() == [], "span tiling violated"
    path = "service_overload.trace.json"
    rec.save_chrome(path)
    print(f"\nwrote Chrome trace (open in ui.perfetto.dev): {path}")
    print(f"  retained jobs: 100 of {100 + rec.n_dropped_jobs} "
          f"completed; dropped {rec.n_dropped_jobs} jobs / "
          f"{rec.n_dropped_spans} spans from the ring")


if __name__ == "__main__":
    main()
