"""Walkthrough: the paper's models driving a multi-job cluster scheduler.

The paper motivates its config→time models with *smarter job scheduling*.
This example closes that loop end to end:

1. generate a deterministic heterogeneous trace (WordCount + EximParse
   jobs, Poisson arrivals, log-uniform sizes, some with SLO deadlines);
2. run the static-config FIFO baseline — the scheduler the paper argues
   real clusters settle for;
3. run the prediction-driven policies: each job's (backend, M, R,
   worker-grant) comes from the fitted per-(app, backend) models in a
   shared ModelDatabase, and shortest-predicted-first / deadline admission
   use the predicted time *before* dispatch;
4. watch online refinement shrink prediction error as completed jobs are
   fed back into the models (the profiling phase made continuous);
5. persist the model database, as a long-lived scheduler would.

    PYTHONPATH=src python examples/cluster_sim.py
    PYTHONPATH=src python examples/cluster_sim.py --real   # tiny trace on
                                                 # the live MapReduce engine
"""

import argparse
import tempfile

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    EngineOracle,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.core.predictor import ModelDatabase

ap = argparse.ArgumentParser()
ap.add_argument("--real", action="store_true",
                help="wall-clock the live MapReduce engine (tiny trace)")
args = ap.parse_args()

# --- the cluster and its workload ------------------------------------------
if args.real:
    oracle = EngineOracle()
    jobs = generate_workload(6, seed=7, mean_interarrival=0.05,
                             size_range=(1 << 11, 1 << 13))
    workers, grids = 4, dict(mapper_grid=(2, 4, 8), reducer_grid=(2, 4, 8),
                             worker_grid=(2, 4),
                             bootstrap_sizes=(1 << 11, 1 << 13))
else:
    oracle = AnalyticOracle(noise=0.02, seed=7)
    jobs = generate_workload(60, seed=7, mean_interarrival=0.12,
                             size_range=(1 << 14, 1 << 18))
    workers, grids = 16, {}
jobs = assign_deadlines(jobs, lambda j: oracle.nominal_time(j.app, j.size),
                        slack_range=(1.2, 6.0), fraction=0.6, seed=8)
cluster = Cluster(workers, oracle)
print(f"trace: {len(jobs)} jobs on {workers} workers "
      f"({sum(1 for j in jobs if j.deadline is not None)} with deadlines), "
      f"oracle={oracle.platform}")

# --- baseline: FIFO with one static config ---------------------------------
fifo = cluster.run(jobs, get_policy("fifo-static"))
mb = fifo.metrics()
print(f"\nfifo-static      : makespan {mb['makespan_s']:7.2f}s  "
      f"mean wait {mb['mean_wait_s']:5.2f}s  SLO {mb['slo_attainment']}")

# --- prediction-driven scheduling ------------------------------------------
for name in ("predict-sjf", "predict-deadline"):
    policy = get_policy(name, seed=7, **grids)
    result = cluster.run(jobs, policy)
    m = result.metrics()
    print(f"{name:<17}: makespan {m['makespan_s']:7.2f}s  "
          f"mean wait {m['mean_wait_s']:5.2f}s  SLO {m['slo_attainment']}  "
          f"rejected {m['n_rejected']}")
    trend = ("shrinking" if m["pred_mae_pct_second_half"]
             < m["pred_mae_pct_first_half"] else "dominated by run noise")
    print(f"                   prediction MAE "
          f"{m['pred_mae_pct_first_half']:.1f}% (first half of trace) -> "
          f"{m['pred_mae_pct_second_half']:.1f}% (second half; online "
          f"refinement: {trend})")
    speedup = mb["makespan_s"] / m["makespan_s"]
    print(f"                   {speedup:.2f}x the baseline's makespan")

# --- the model database persists, like a real scheduler's would ------------
with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
    path = f.name
policy.db.save(path)
reloaded = ModelDatabase.load(path)
print(f"\nmodel database: {len(reloaded)} fitted (app, platform, backend) "
      f"models round-tripped through {path}")
print("stored keys:", *reloaded.applications(), sep="\n  ")
