"""Run the TPU-native MapReduce engine end-to-end: WordCount over a Zipf
corpus, with the shuffle on the sharded (all_to_all) path when more than
one device is available.

    PYTHONPATH=src python examples/mapreduce_wordcount.py
    # multi-worker shuffle:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/mapreduce_wordcount.py --workers 4
"""

import argparse
import time

import jax

from repro.mapreduce import (
    JobConfig,
    build_job,
    build_job_sharded,
    collect_results,
    wordcount,
    wordcount_corpus,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=1 << 16)
    ap.add_argument("--mappers", type=int, default=20)
    ap.add_argument("--reducers", type=int, default=5)
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args()
    corpus = wordcount_corpus(args.tokens, vocab_size=4096, seed=0)
    app = wordcount(4096)
    cfg = JobConfig(
        num_mappers=args.mappers, num_reducers=args.reducers,
        num_workers=args.workers,
    )
    if args.workers > 1:
        mesh = jax.make_mesh(
            (args.workers,), ("workers",),
            axis_types=(jax.sharding.AxisType.Auto,),
        )
        job = build_job_sharded(app, cfg, len(corpus), mesh)
        path = f"sharded all_to_all over {args.workers} workers"
    else:
        job = build_job(app, cfg, len(corpus))
        path = "single-controller"
    jax.block_until_ready(job(corpus))  # job setup (compile)
    t0 = time.perf_counter()
    ok, ov, dropped = job(corpus)
    jax.block_until_ready(ov)
    dt = time.perf_counter() - t0
    counts = collect_results(ok, ov)
    top = sorted(counts.items(), key=lambda kv: -kv[1])[:10]
    print(f"{args.tokens} tokens, M={cfg.num_mappers} R={cfg.num_reducers} "
          f"({cfg.map_waves}/{cfg.reduce_waves} waves), {path}")
    print(f"execution time: {dt * 1e3:.1f}ms; dropped={int(dropped)}")
    print("top words:", top)
    assert sum(counts.values()) == args.tokens


if __name__ == "__main__":
    main()
