"""Run the TPU-native MapReduce engine end-to-end: WordCount over a Zipf
corpus, through one ExecutionPlan whose *mode* is picked by the flags —
fused single-controller by default, the sharded (all_to_all) mesh mode
with more than one worker, the software-pipelined wave schedule with
--depth 2+, and the phase-fenced traced mode (per-phase wall times, on
any path) with --phase-times.

    PYTHONPATH=src python examples/mapreduce_wordcount.py
    # per-phase wall times (works on the sharded path too):
    PYTHONPATH=src python examples/mapreduce_wordcount.py --phase-times
    # software-pipelined wave schedule (bit-exact vs fused):
    PYTHONPATH=src python examples/mapreduce_wordcount.py --depth 4
    # map-side combining (bit-exact; contracts shuffle bytes — pair
    # --combiner with --phase-times to see the combine phase counters):
    PYTHONPATH=src python examples/mapreduce_wordcount.py \
        --combiner --phase-times
    # multi-worker shuffle:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/mapreduce_wordcount.py --workers 4
"""

import argparse
import time

import jax

from repro.mapreduce import (
    ExecutionPlan,
    JobConfig,
    collect_results,
    wordcount,
    wordcount_corpus,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=1 << 16)
    ap.add_argument("--mappers", type=int, default=20)
    ap.add_argument("--reducers", type=int, default=5)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--depth", type=int, default=1,
                    help="overlap depth: group this many waves per "
                         "software-pipeline step (1 = serial fused)")
    ap.add_argument("--combiner", action="store_true",
                    help="map-side combine: pre-aggregate each map "
                         "task's pairs before the shuffle (bit-exact "
                         "for WordCount's sum; contracts shuffle bytes "
                         "hard on the Zipf-skewed corpus)")
    ap.add_argument("--phase-times", action="store_true",
                    help="run the traced mode: fence + wall-clock each "
                         "phase (three fenced mesh programs when sharded)")
    args = ap.parse_args()
    if args.depth > 1 and args.workers > 1:
        ap.error("--depth > 1 is a single-controller schedule; "
                 "it does not compose with --workers > 1")
    corpus = wordcount_corpus(args.tokens, vocab_size=4096, seed=0)
    app = wordcount(4096)
    cfg = JobConfig(
        num_mappers=args.mappers, num_reducers=args.reducers,
        num_workers=args.workers, overlap_depth=args.depth,
        combiner=args.combiner,
    )
    recorder = None
    if args.phase_times:
        from repro.telemetry import PhaseRecorder

        recorder = PhaseRecorder()
    plan = ExecutionPlan(app, cfg, len(corpus))
    if args.workers > 1:
        mesh = jax.make_mesh(
            (args.workers,), ("workers",),
            axis_types=(jax.sharding.AxisType.Auto,),
        )
        job = plan.sharded(mesh, recorder=recorder)
        path = f"sharded all_to_all over {args.workers} workers"
    elif recorder is not None:
        job = plan.traced(recorder)  # picks up cfg.overlap_depth
        path = "single-controller (traced)"
        if args.depth > 1:
            path += f", pipelined depth={args.depth}"
    elif args.depth > 1:
        job = plan.pipelined()
        path = f"single-controller (pipelined, depth={args.depth})"
    else:
        job = plan.fused()
        path = "single-controller (fused)"
    jax.block_until_ready(job(corpus))  # job setup (compile)
    t0 = time.perf_counter()
    ok, ov, dropped = job(corpus)
    jax.block_until_ready(ov)
    dt = time.perf_counter() - t0
    counts = collect_results(ok, ov)
    top = sorted(counts.items(), key=lambda kv: -kv[1])[:10]
    if args.combiner:
        path += ", combiner on"
    print(f"{args.tokens} tokens, M={cfg.num_mappers} R={cfg.num_reducers} "
          f"({cfg.map_waves}/{cfg.reduce_waves} waves), {path}")
    print(f"execution time: {dt * 1e3:.1f}ms; dropped={int(dropped)}")
    if recorder is not None:
        times = recorder.last.phase_times()
        print("phase walls: " + ", ".join(
            f"{k}={v * 1e3:.1f}ms" for k, v in times.items()
        ))
    print("top words:", top)
    assert sum(counts.values()) == args.tokens


if __name__ == "__main__":
    main()
