"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing, failure injection + automatic restore, and step-time
profiling feeding the paper's config->time model.

    PYTHONPATH=src python examples/train_lm.py              # full (~100M)
    PYTHONPATH=src python examples/train_lm.py --tiny       # CI-sized

After training, the collected per-step wall times are fit against the
microbatch-count knob — the paper's profiling->modeling loop applied to the
trainer itself.
"""

import argparse
import dataclasses
import shutil

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import fit
from repro.data import DataConfig
from repro.launch.train import TrainLoopConfig, run_training
from repro.train import StepConfig


def model_100m() -> ModelConfig:
    """~100M params: 12L d=768 12H GQA kv=4, llama-style."""
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, ffn_type="swiglu", rope_theta=10000.0,
    )


def model_tiny() -> ModelConfig:
    return dataclasses.replace(
        model_100m(), name="repro-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (demo)")
    args = ap.parse_args()
    cfg = model_tiny() if args.tiny else model_100m()
    steps = args.steps or (60 if args.tiny else 300)
    batch = args.batch or (8 if args.tiny else 16)
    seq = args.seq or (64 if args.tiny else 512)
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, structure=0.9)
    print(f"training {cfg.name} for {steps} steps "
          f"(batch {batch} x seq {seq})")
    out = run_training(
        cfg, data,
        TrainLoopConfig(steps=steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=max(10, steps // 10),
                        fail_at_step=args.fail_at, lr=1e-3),
        StepConfig(remat="none"),
    )
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} recorded steps)")
    assert losses[-1] < losses[0], "training should reduce loss"

    # --- paper technique on the trainer itself: model step time vs the
    # microbatch knob, predict an unprofiled setting -----------------------
    from repro.train import build_train_step
    import jax, time
    from repro.models import transformer as tf
    from repro.optim import adamw

    knob_values = [1, 2, 4, 8]
    times, params_rows = [], []
    pipeline_batch = data
    for mb in knob_values:
        step = jax.jit(build_train_step(
            cfg, adamw.AdamWConfig(lr=1e-3), StepConfig(microbatch=mb)
        ), donate_argnums=(0, 1))
        p = tf.init_params(cfg, jax.random.PRNGKey(0))
        s = adamw.init_state(adamw.AdamWConfig(lr=1e-3), p)
        from repro.data import TokenPipeline
        b = TokenPipeline(pipeline_batch).batch_at(0)
        p, s, m = step(p, s, b)  # compile+warm
        reps = []
        for _ in range(3):
            t0 = time.perf_counter()
            p, s, m = step(p, s, b)
            jax.block_until_ready(m["loss"])
            reps.append(time.perf_counter() - t0)
        times.append(float(np.mean(reps)))
        params_rows.append([mb])
        print(f"microbatch={mb}: {times[-1] * 1e3:.1f}ms/step")
    model = fit(np.asarray(params_rows, float), np.asarray(times),
                degree=2, scale=True, lam=1e-9)
    pred3 = float(np.asarray(model.predict(np.array([3.0]))).ravel()[0])
    print(f"predicted step time at unprofiled microbatch=3: "
          f"{pred3 * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
