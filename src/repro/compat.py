"""Version-compatibility shims for jax APIs that moved across releases.

The repo targets current jax (top-level ``jax.shard_map``, explicit mesh
``AxisType``, dict-valued ``cost_analysis``) but must also run on the 0.4.x
line shipped in some containers, where ``shard_map`` lives in
``jax.experimental``, meshes take no ``axis_types``, the replication-check
kwarg is ``check_rep`` (renamed ``check_vma`` later), and
``Compiled.cost_analysis()`` returns a per-device list.
"""

from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

_SIG = inspect.signature(_shard_map).parameters
if "check_vma" in _SIG:
    _CHECK_KW = "check_vma"
elif "check_rep" in _SIG:
    _CHECK_KW = "check_rep"
else:  # pragma: no cover
    _CHECK_KW = None


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """``jax.shard_map`` with the replication-check kwarg name normalized."""
    kwargs = {}
    if not check and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = False
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):  # pragma: no cover
        return jax.make_mesh(shape, axes)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as one dict (newer jax) even on versions
    returning a per-device list."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def compiled_cost_analysis(fn, *abstract_args) -> dict:
    """Lower + compile ``fn`` for abstract (shape/dtype-only) arguments and
    return its XLA cost analysis as a dict.

    Returns ``{}`` when the backend/version provides no cost analysis (some
    CPU builds) or compilation of the probe fails — callers treat an empty
    dict as "estimates unavailable", never as an error (telemetry must not
    take the engine down).
    """
    import jax

    try:
        compiled = jax.jit(fn).lower(*abstract_args).compile()
        return dict(cost_analysis_dict(compiled) or {})
    except Exception:  # pragma: no cover - backend/version dependent
        return {}
