"""Per-phase multi-resource telemetry + decomposed cost models.

The paper models total execution time as one scalar; its companion papers
model the CPU and network signals underneath.  This package is the
observability layer that makes both possible on the live engine:

    trace.py     — PhaseStats / JobTrace / PhaseRecorder: per-phase wall
                   times + resource counters with checkable conservation
                   laws; thread a recorder through ``build_job(recorder=)``
    estimator.py — static per-phase flops/bytes via XLA cost_analysis
                   (compat-shimmed), no execution required
    models.py    — one regression per (phase, resource) on the paper's
                   basis, composed total-time prediction, ModelDatabase
                   storage under resource-qualified keys

Entry points: ``python -m benchmarks.run --sections phases`` (composed vs
monolithic prediction error, per-phase breakdown),
``examples/phase_breakdown.py`` (walkthrough), and the ``predict-resource``
cluster policy (shuffle-bytes-aware dispatch).
"""

from repro.telemetry.trace import (
    PAIR_BYTES,
    TRACE_SCHEMA_VERSION,
    JobTrace,
    PhaseRecorder,
    PhaseStats,
    collect_traced,
)
from repro.telemetry.estimator import (
    estimates_available,
    stage_cost_estimates,
)
from repro.telemetry.models import (
    DEFAULT_COUNTER_TARGETS,
    PHASE_ORDER,
    TIME_RESOURCE,
    PhaseModelSet,
    composed_vs_monolithic,
    fit_phase_models,
    phase_resource_key,
    split_resource_key,
    targets_from_traces,
)

__all__ = [
    "PAIR_BYTES",
    "TRACE_SCHEMA_VERSION",
    "JobTrace",
    "PhaseRecorder",
    "PhaseStats",
    "collect_traced",
    "estimates_available",
    "stage_cost_estimates",
    "DEFAULT_COUNTER_TARGETS",
    "PHASE_ORDER",
    "TIME_RESOURCE",
    "PhaseModelSet",
    "composed_vs_monolithic",
    "fit_phase_models",
    "phase_resource_key",
    "split_resource_key",
    "targets_from_traces",
]
