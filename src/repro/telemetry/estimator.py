"""Static per-phase flops/bytes estimates from XLA's cost analysis.

Wall-clock telemetry (``trace.py``) answers "what did this run cost"; this
module answers "what does XLA *think* each phase costs" — without running
anything.  Each phase function of the canonical
:class:`repro.mapreduce.plan.ExecutionPlan` (the same stepper loops every
execution mode runs) is lowered and compiled for abstract (shape-only)
inputs, and the compiled executable's cost analysis (flops, bytes
accessed) is read through the version-compat shim
:func:`repro.compat.compiled_cost_analysis`.

The estimates feed two consumers:

* the ``phases`` benchmark section reports them next to measured wall
  times, giving a roofline-style sanity check per phase;
* arithmetic-intensity ratios (flops/byte) distinguish compute-bound
  phases (map's per-task setup matmuls) from memory/sort-bound ones
  (shuffle), which is the qualitative split the paper's companion CPU- and
  network-modeling papers draw.

Cost analysis availability varies by backend/jax version; estimates carry
an ``available`` flag and all consumers degrade gracefully.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import compiled_cost_analysis
from repro.mapreduce.phases import PAIR_BYTES
from repro.mapreduce.plan import ExecutionPlan

#: cost_analysis key for bytes moved (XLA's name, with fallbacks).
_BYTES_KEYS = ("bytes accessed", "bytes_accessed")


def _pick(cost: dict, *keys, default: float = 0.0) -> float:
    for k in keys:
        if k in cost:
            return float(cost[k])
    return default


def stage_cost_estimates(app, cfg, input_len: int) -> dict[str, dict]:
    """Per-phase {flops, bytes, flops_per_byte, available} via XLA, plus
    static resource estimates (``cpu_flops``, ``net_bytes``).

    Phases are the plan's compute stages (map, shuffle, reduce); collect
    is host-side and has no XLA program.  ``available=False`` (with zeroed
    numbers) means the backend reported no cost model for that stage.

    ``cpu_flops`` mirrors the XLA flop count (everything the lowered
    program executes runs on host CPU cores here); ``net_bytes`` is the
    shape-derived fabric upper bound — the shuffle's pair-slot capacity
    times the wire pair size, zero for the compute phases.  It pairs with
    the *measured* ``net_bytes`` trace counter (actual emitted pairs) the
    way ``bytes`` pairs with measured wall times.
    """
    plan = ExecutionPlan(app, cfg, input_len)
    stages = plan.phase_fns()
    meta = plan.meta()
    i32 = jnp.int32
    tok = jax.ShapeDtypeStruct((input_len,), i32)
    acc = jax.ShapeDtypeStruct((plan.M, plan.P), i32)
    acc_b = jax.ShapeDtypeStruct((plan.M, plan.P), jnp.bool_)
    part = jax.ShapeDtypeStruct(
        (plan.R, meta["partition_capacity"]), i32
    )
    abstract_args = {
        "map": (tok,),
        "shuffle": (acc, acc, acc_b),
        "reduce": (part, part),
    }
    out: dict[str, dict] = {}
    for phase, fn in stages.items():
        cost = compiled_cost_analysis(fn, *abstract_args[phase])
        flops = _pick(cost, "flops")
        nbytes = _pick(cost, *_BYTES_KEYS)
        out[phase] = {
            "flops": flops,
            "bytes": nbytes,
            "flops_per_byte": flops / nbytes if nbytes > 0 else 0.0,
            "available": bool(cost),
            "cpu_flops": flops,
            "net_bytes": (
                float(meta["n_pairs"] * PAIR_BYTES)
                if phase == "shuffle" else 0.0
            ),
        }
    return out


def estimates_available(estimates: dict[str, dict]) -> bool:
    """True when at least one phase reported a real XLA cost model."""
    return any(e.get("available") for e in estimates.values())
