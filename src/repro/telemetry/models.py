"""Decomposed per-(phase, resource) cost models, composed back to a total.

The paper fits one monolithic (config -> total time) polynomial.  Its
companion papers model the signals underneath — total CPU usage
(arXiv:1203.4054) and shuffle/network load (arXiv:1206.2016) — against the
same configuration knobs.  This module does both at once on top of the
telemetry layer: one :class:`~repro.core.regression.RegressionModel` per
(phase, resource) target, all sharing the paper's feature basis, plus a
composed total-time prediction (sum over per-phase time models).

Because ordinary least squares is linear in the regression target, fitting
each phase's time on the same design matrix and summing the fits is
algebraically identical to fitting the summed total directly — so the
composed prediction can never be worse than the monolithic one on the same
basis (the ``phases`` benchmark section verifies this numerically), while
additionally exposing *where* the time goes and per-resource predictions
(e.g. shuffle bytes) that a resource-aware scheduler can act on
(``repro.cluster.policies`` ``predict-resource``).

Storage: models live in the shared :class:`~repro.core.predictor.
ModelDatabase` under resource-qualified keys ``"<phase>:<resource>"``
(``phase_resource_key``), next to the monolithic model at resource ``""``.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import regression
from repro.core.predictor import ModelDatabase
from repro.telemetry.trace import JobTrace

#: the engine's phase order (combine only appears on combiner-enabled
#: traces; collect is host-side and usually negligible, but it is part of
#: the job and therefore part of the composed total).
PHASE_ORDER = ("map", "combine", "shuffle", "reduce", "collect")

#: the per-phase wall-time resource name.
TIME_RESOURCE = "time_s"

#: counters worth modeling per phase, beyond wall time.  Each is a
#: deterministic function of (config, corpus), so these regressions are
#: near-noise-free — the shuffle bytes model is what the network-aware
#: scheduling policy consumes.
DEFAULT_COUNTER_TARGETS = (
    ("map", "pairs_emitted"),
    ("shuffle", "bytes_out"),
    ("shuffle", "bytes_dropped"),
    ("reduce", "segments_out"),
    # Resource counters (PR 9): per-phase CPU seconds against the same
    # (M, R) basis — the arXiv:1203.4054 companion target — and the
    # shuffle's on-wire bytes (arXiv:1206.2016), which the fabric-aware
    # scheduler prices against ``net_capacity``.  Traces that predate the
    # resource counters simply contribute 0.0 (``JobTrace.counter``'s
    # default), so fitting on mixed trace vintages stays well-defined.
    ("map", "cpu_s"),
    ("shuffle", "cpu_s"),
    ("reduce", "cpu_s"),
    ("shuffle", "net_bytes"),
    # Combine counters (map-side combining): pairs surviving the local
    # pre-aggregation — the contraction that shrinks shuffle net_bytes —
    # and the stage's CPU cost.  Combiner-off traces have no combine
    # phase, so these fit only on combiner-enabled trace sets.
    ("combine", "pairs_out"),
    ("combine", "cpu_s"),
)


def phase_resource_key(phase: str, resource: str = TIME_RESOURCE) -> str:
    """The ModelDatabase ``resource`` key for one (phase, resource)."""
    if not phase or ":" in phase:
        raise ValueError(f"bad phase name {phase!r}")
    if not resource or ":" in resource:
        raise ValueError(f"bad resource name {resource!r}")
    return f"{phase}:{resource}"


def split_resource_key(key: str) -> tuple[str, str]:
    phase, sep, resource = key.partition(":")
    if not sep or not phase or not resource:
        raise ValueError(f"not a phase-resource key: {key!r}")
    return phase, resource


@dataclasses.dataclass
class PhaseModelSet:
    """A bundle of fitted per-(phase, resource) models for one
    (application, platform[, backend])."""

    models: dict[tuple[str, str], regression.RegressionModel]

    def __len__(self) -> int:
        return len(self.models)

    def __contains__(self, key: tuple[str, str]) -> bool:
        return key in self.models

    def time_phases(self) -> list[str]:
        """Phases with a fitted wall-time model, in engine order."""
        got = {p for (p, r) in self.models if r == TIME_RESOURCE}
        ordered = [p for p in PHASE_ORDER if p in got]
        return ordered + sorted(got.difference(PHASE_ORDER))

    def model(self, phase: str, resource: str = TIME_RESOURCE):
        try:
            return self.models[(phase, resource)]
        except KeyError:
            raise KeyError(
                f"no model for phase={phase!r} resource={resource!r}; "
                f"fitted: {sorted(self.models)}"
            ) from None

    def predict(
        self, phase: str, resource: str, params
    ) -> np.ndarray:
        return np.asarray(
            self.model(phase, resource).predict(np.asarray(params)),
            dtype=np.float64,
        ).reshape(-1)

    def predict_phase_times(self, params) -> dict[str, np.ndarray]:
        return {
            p: self.predict(p, TIME_RESOURCE, params)
            for p in self.time_phases()
        }

    def predict_total(self, params) -> np.ndarray:
        """Composed total-time prediction: sum of the per-phase models."""
        per_phase = self.predict_phase_times(params)
        if not per_phase:
            raise ValueError("no per-phase time models fitted")
        return np.sum(list(per_phase.values()), axis=0)

    # ---- ModelDatabase round trip ---------------------------------------

    def publish(
        self,
        db: ModelDatabase,
        application: str,
        platform: str,
        backend: str = "",
    ) -> None:
        for (phase, resource), model in self.models.items():
            db.put(
                application, platform, model, backend=backend,
                resource=phase_resource_key(phase, resource),
            )

    @staticmethod
    def load(
        db: ModelDatabase,
        application: str,
        platform: str,
        backend: str = "",
    ) -> "PhaseModelSet":
        models = {}
        for res_key in db.resources_for(application, platform, backend):
            try:
                phase, resource = split_resource_key(res_key)
            except ValueError:
                continue  # not a telemetry key; leave it alone
            models[(phase, resource)] = db.get(
                application, platform, backend, resource=res_key
            )
        return PhaseModelSet(models=models)


def targets_from_traces(
    traces_per_config: Sequence[Sequence[JobTrace]],
    counter_targets: Sequence[tuple[str, str]] = DEFAULT_COUNTER_TARGETS,
) -> dict[tuple[str, str], np.ndarray]:
    """Aggregate raw traces into fit-ready (phase, resource) -> targets.

    ``traces_per_config[i]`` holds the repeat traces of experiment ``i``
    (the paper's pruning-by-averaging, per phase): wall times are averaged
    over repeats; counters are deterministic per config so averaging is a
    no-op that still smooths any accounting surprise.
    """
    if not traces_per_config or not traces_per_config[0]:
        raise ValueError("need at least one trace per config")
    phases = traces_per_config[0][0].phase_names()
    out: dict[tuple[str, str], list[float]] = {
        (p, TIME_RESOURCE): [] for p in phases
    }
    for phase, counter in counter_targets:
        if phase in phases:
            out[(phase, counter)] = []
    for reps in traces_per_config:
        if not reps:
            raise ValueError("empty repeat list for a config")
        for p in phases:
            out[(p, TIME_RESOURCE)].append(
                float(np.mean([t.phase(p).wall_s for t in reps]))
            )
        for phase, counter in counter_targets:
            if phase in phases:
                out[(phase, counter)].append(
                    float(np.mean([t.counter(phase, counter) for t in reps]))
                )
    return {k: np.asarray(v, dtype=np.float64) for k, v in out.items()}


def fit_phase_models(
    params,
    targets: Mapping[tuple[str, str], np.ndarray],
    **fit_kwargs,
) -> PhaseModelSet:
    """One regression per (phase, resource) on the shared parameter rows.

    ``params`` is the same (M, N) experiment matrix the monolithic fit
    uses; ``targets`` maps (phase, resource) to its (M,) measurement
    vector (see :func:`targets_from_traces`).  ``fit_kwargs`` forward to
    :func:`repro.core.regression.fit` — use the same kwargs as the
    monolithic model so composed-vs-monolithic comparisons share a basis.
    """
    params = np.asarray(params, dtype=np.float64)
    models = {}
    for (phase, resource), values in targets.items():
        values = np.asarray(values, dtype=np.float64)
        if values.shape != (params.shape[0],):
            raise ValueError(
                f"target {(phase, resource)} has shape {values.shape}, "
                f"expected ({params.shape[0]},)"
            )
        models[(phase, resource)] = regression.fit(
            params, values, **fit_kwargs
        )
    return PhaseModelSet(models=models)


def composed_vs_monolithic(
    phase_models: PhaseModelSet,
    monolithic: regression.RegressionModel,
    params,
    totals,
) -> dict:
    """Paper-Table-1-style error stats for both predictors on one set.

    ``totals`` should be the sum of the per-phase times for each row (the
    quantity both predictors target).  Returns mean/max absolute percent
    error for the composed and monolithic predictions plus their gap.
    """
    totals = np.asarray(totals, dtype=np.float64)
    composed = phase_models.predict_total(params)
    mono = np.asarray(
        monolithic.predict(np.asarray(params)), dtype=np.float64
    ).reshape(-1)
    denom = np.maximum(np.abs(totals), 1e-12)
    err_c = np.abs(composed - totals) / denom * 100.0
    err_m = np.abs(mono - totals) / denom * 100.0
    return {
        "composed_mean_pct": float(err_c.mean()),
        "composed_max_pct": float(err_c.max()),
        "monolithic_mean_pct": float(err_m.mean()),
        "monolithic_max_pct": float(err_m.max()),
        "composed_minus_monolithic_mean_pct": float(
            err_c.mean() - err_m.mean()
        ),
        # OLS linearity makes the two predictors algebraically identical on
        # a shared basis; the tolerance (in percentage points) absorbs the
        # float64 solver rounding between solve(G, sum b) and sum solve(G, b),
        # while staying far below any real modeling difference.
        "composed_le_monolithic": bool(
            err_c.mean() <= err_m.mean() + 1e-3
        ),
    }
