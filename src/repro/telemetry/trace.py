"""Per-phase execution traces: the observability substrate.

A :class:`JobTrace` is one job execution decomposed into the engine's four
phases (map → shuffle → reduce → collect), each carrying a wall time and a
dict of resource counters (records/pairs moved, shuffle bytes, spill/drop
accounting, wave counts, segment-reduce work).  A :class:`PhaseRecorder`
accumulates traces across runs — thread one through
:func:`repro.mapreduce.build_job` via its ``recorder=`` argument and every
call of the returned job appends a trace.

Telemetry is strictly opt-in: with ``recorder=None`` (the default) the
engine compiles the usual fused pipeline and pays zero overhead.  With a
recorder, the pipeline is compiled as separately-jitted stages so each
phase can be fenced (``block_until_ready``) and wall-clocked — same
semantics, same outputs, slightly different timing profile (three dispatches
instead of one), which is why traced time is recorded per phase *and* as an
outer total.

Counters are computed from the actual phase outputs, not from the
configuration, so conservation laws are real invariants:

* ``shuffle.bytes_in == shuffle.bytes_out + shuffle.bytes_dropped``
* ``map.pairs_emitted == shuffle.pairs_in`` — or, when a map-side combine
  stage ran, ``map.pairs_emitted == combine.pairs_in``,
  ``combine.pairs_out <= combine.pairs_in``, and
  ``combine.pairs_out == shuffle.pairs_in`` (the combiner is the only
  stage allowed to contract the pair stream, and it must do so between
  the map's emit counter and the shuffle's intake)
* per-phase wall times sum to ~the outer job wall time.

Resource counters extend the same discipline to CPU and fabric:

* ``cpu_s`` — process CPU-clock seconds sampled at the same fences as the
  wall clock, bounded per phase by ``wall_s * cpu_workers`` (the
  parallelism ceiling in effect when the sample was taken:
  ``os.cpu_count()`` on real engine fences, W on analytic traces);
* ``net_bytes`` / ``net_s`` — bytes entering the shuffle fabric and the
  seconds the transfer occupied it.  ``net_bytes == pairs_in * PAIR_BYTES``
  exactly (every emitted pair crosses the fabric, dropped ones included),
  and only the shuffle phase may carry non-zero ``net_bytes`` — bookkeeping
  phases (``pipeline``, ``contention``) must record it as zero.

``JobTrace.check_conservation`` verifies all of them and returns the list
of violations (empty = healthy); the per-backend property tests in
``tests/test_telemetry.py`` assert it stays empty for every reduce backend.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Iterable

# One source of truth for the pair wire size (int32 key + int32 value):
# the engine's measured counters and the oracles' analytic counters must
# use the same constant or shuffle-bytes models silently diverge.
from repro.mapreduce.phases import PAIR_BYTES

__all__ = [
    "PAIR_BYTES",
    "TRACE_SCHEMA_VERSION",
    "PhaseStats",
    "JobTrace",
    "PhaseRecorder",
    "collect_traced",
]

#: serialized-trace schema version.  Bump on breaking shape changes;
#: ``JobTrace.from_json`` refuses versions it does not understand instead
#: of silently misparsing them (traces now outlive the process — the span
#: exporter and bench artifacts persist them).
TRACE_SCHEMA_VERSION = 1


@dataclasses.dataclass
class PhaseStats:
    """One phase of one job execution: wall time + resource counters."""

    phase: str
    wall_s: float
    counters: dict[str, float] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "phase": self.phase,
            "wall_s": self.wall_s,
            "counters": dict(self.counters),
        }


@dataclasses.dataclass
class JobTrace:
    """One job execution, decomposed per phase.

    ``config`` carries the JobConfig fields plus ``input_len`` and the app
    name, so a trace is self-describing (it IS one row of the paper's
    experiment set, with the total broken into its parts).
    """

    app: str
    config: dict
    phases: list[PhaseStats] = dataclasses.field(default_factory=list)
    total_s: float | None = None

    # ---- recording (the engine-facing protocol) -------------------------

    def record_phase(self, phase: str, wall_s: float, **counters) -> None:
        self.phases.append(
            PhaseStats(
                phase=phase,
                wall_s=float(wall_s),
                counters={k: float(v) for k, v in counters.items()},
            )
        )

    def finish(self, total_s: float) -> None:
        self.total_s = float(total_s)

    # ---- queries --------------------------------------------------------

    def phase(self, name: str) -> PhaseStats:
        for p in self.phases:
            if p.phase == name:
                return p
        raise KeyError(
            f"no phase {name!r} in trace; recorded: "
            f"{[p.phase for p in self.phases]}"
        )

    def phase_names(self) -> list[str]:
        return [p.phase for p in self.phases]

    def phase_times(self) -> dict[str, float]:
        """Wall seconds per phase name.

        A phase name may appear several times — an elastically preempted
        job records one entry per executed *segment* (e.g. the map waves
        run before and after a regrant).  Times sum per name, so segmented
        and uninterrupted traces answer this query identically.
        """
        out: dict[str, float] = {}
        for p in self.phases:
            out[p.phase] = out.get(p.phase, 0.0) + p.wall_s
        return out

    def phase_time_sum(self) -> float:
        return sum(p.wall_s for p in self.phases)

    def counter(self, phase: str, name: str, default: float = 0.0) -> float:
        """Counter total for ``phase`` — summed across segment entries of
        the same phase (single-entry traces are unaffected); ``default``
        only when no entry of the phase carries the counter."""
        entries = [p for p in self.phases if p.phase == phase]
        if not entries:
            raise KeyError(
                f"no phase {phase!r} in trace; recorded: "
                f"{self.phase_names()}"
            )
        vals = [p.counters[name] for p in entries if name in p.counters]
        return sum(vals) if vals else default

    # ---- invariants ------------------------------------------------------

    def check_conservation(
        self, *, time_rel_tol: float = 0.5, time_abs_tol: float = 0.1
    ) -> list[str]:
        """Verify counter conservation laws; return violations (empty = ok).

        Byte/pair conservation is exact (counters are integers measured from
        the actual arrays).  The timing check is tolerant: per-phase fencing
        measures the same work as the outer total but adds host-side counter
        reads between phases, so the sum is compared within
        ``max(time_rel_tol * total, time_abs_tol)`` seconds.
        """
        bad: list[str] = []
        names = set(self.phase_names())
        # Counters aggregate across segment entries of the same phase
        # (elastic preemption splits phases into segments), so the same
        # laws hold for interrupted and uninterrupted runs.
        if "shuffle" in names:
            has = set().union(
                *(p.counters for p in self.phases if p.phase == "shuffle")
            )
            c = lambda name: self.counter("shuffle", name)
            if "bytes_in" in has and c("bytes_in") != c("bytes_out") + c(
                "bytes_dropped"
            ):
                bad.append(
                    "shuffle bytes_in != bytes_out + bytes_dropped "
                    f"({c('bytes_in')} != {c('bytes_out')} + "
                    f"{c('bytes_dropped')})"
                )
            if "pairs_in" in has and c("pairs_in") != c("pairs_out") + c(
                "pairs_dropped"
            ):
                bad.append("shuffle pairs_in != pairs_out + pairs_dropped")
            if "map" in names and "pairs_in" in has:
                emitted = self.counter("map", "pairs_emitted")
                # The shuffle consumes the map's emitted stream directly —
                # unless a combine stage sat between them, in which case
                # the shuffle consumes the combiner's (contracted) output.
                expect = emitted
                label = "map pairs_emitted"
                if "combine" in names:
                    expect = self.counter("combine", "pairs_out")
                    label = "combine pairs_out"
                if expect != c("pairs_in"):
                    bad.append(
                        f"{label} {expect} != shuffle pairs_in "
                        f"{c('pairs_in')}"
                    )
            if "net_bytes" in has and "pairs_in" in has:
                if c("net_bytes") != c("pairs_in") * PAIR_BYTES:
                    bad.append(
                        f"shuffle net_bytes {c('net_bytes')} != pairs_in "
                        f"{c('pairs_in')} * PAIR_BYTES {PAIR_BYTES}"
                    )
            if "net_s" in has and c("net_s") < 0:
                bad.append(f"shuffle net_s {c('net_s')} negative")
        if "combine" in names:
            has_c = set().union(
                *(p.counters for p in self.phases if p.phase == "combine")
            )
            cc = lambda name: self.counter("combine", name)
            if "map" in names and "pairs_in" in has_c:
                emitted = self.counter("map", "pairs_emitted")
                if emitted != cc("pairs_in"):
                    bad.append(
                        f"map pairs_emitted {emitted} != combine pairs_in "
                        f"{cc('pairs_in')}"
                    )
            # The combiner may only *contract* the stream (it merges
            # equal-key pairs, never invents new ones).
            if {"pairs_in", "pairs_out"} <= has_c and (
                cc("pairs_out") > cc("pairs_in")
            ):
                bad.append(
                    f"combine pairs_out {cc('pairs_out')} > pairs_in "
                    f"{cc('pairs_in')}"
                )
            if {"bytes_in", "bytes_out"} <= has_c and (
                cc("bytes_out") > cc("bytes_in")
            ):
                bad.append(
                    f"combine bytes_out {cc('bytes_out')} > bytes_in "
                    f"{cc('bytes_in')}"
                )
        # Only the shuffle phase moves bytes over the fabric; bookkeeping
        # phases (pipelined overlap credit, contention stalls) and compute
        # phases must record net_bytes as exactly zero if they record it.
        for p in self.phases:
            if p.phase != "shuffle" and p.counters.get("net_bytes", 0.0):
                bad.append(
                    f"{p.phase} net_bytes {p.counters['net_bytes']} != 0 "
                    "(only shuffle occupies the fabric)"
                )
        # CPU law: process CPU-seconds inside one fenced phase cannot
        # exceed wall x the parallelism ceiling recorded with the sample.
        # Negative-wall bookkeeping phases (pipelined overlap credit) are
        # exempt per phase and excluded from the aggregate.
        cpu_entries = [
            p for p in self.phases
            if "cpu_s" in p.counters and p.wall_s >= 0
        ]
        for p in cpu_entries:
            limit = p.counters.get("cpu_workers", 1.0)
            if p.counters["cpu_s"] > p.wall_s * limit + time_abs_tol:
                bad.append(
                    f"{p.phase} cpu_s {p.counters['cpu_s']:.4f} > wall "
                    f"{p.wall_s:.4f} * cpu_workers {limit:g}"
                )
        if cpu_entries:
            ceiling = max(
                p.counters.get("cpu_workers", 1.0) for p in cpu_entries
            )
            cpu_sum = sum(p.counters["cpu_s"] for p in cpu_entries)
            wall_sum = sum(p.wall_s for p in cpu_entries)
            if cpu_sum > wall_sum * ceiling + time_abs_tol:
                bad.append(
                    f"sum(cpu_s) {cpu_sum:.4f} > sum(wall) {wall_sum:.4f} "
                    f"* cpu_workers {ceiling:g}"
                )
        if self.total_s is not None and self.phases:
            gap = abs(self.total_s - self.phase_time_sum())
            if gap > max(time_rel_tol * self.total_s, time_abs_tol):
                bad.append(
                    f"phase times sum {self.phase_time_sum():.4f}s far from "
                    f"total {self.total_s:.4f}s"
                )
        return bad

    # ---- (de)serialization ----------------------------------------------

    def to_dict(self) -> dict:
        return {
            "schema": TRACE_SCHEMA_VERSION,
            "app": self.app,
            "config": dict(self.config),
            "total_s": self.total_s,
            "phases": [p.to_dict() for p in self.phases],
        }

    @staticmethod
    def from_dict(d: dict) -> "JobTrace":
        # Pre-schema dicts (PR 3 era) carry no version marker; they are
        # shape-identical to version 1, so they load as version 1.
        version = int(d.get("schema", 1))
        if not 1 <= version <= TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported trace schema version {version}; this build "
                f"reads versions 1..{TRACE_SCHEMA_VERSION}"
            )
        return JobTrace(
            app=d["app"],
            config=dict(d["config"]),
            total_s=d.get("total_s"),
            phases=[
                PhaseStats(
                    phase=p["phase"],
                    wall_s=float(p["wall_s"]),
                    counters=dict(p["counters"]),
                )
                for p in d.get("phases", ())
            ],
        )

    def to_json(self, **dumps_kwargs) -> str:
        """Serialize (with the ``schema`` version field) to a JSON string."""
        dumps_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **dumps_kwargs)

    @staticmethod
    def from_json(s: str) -> "JobTrace":
        d = json.loads(s)
        if not isinstance(d, dict):
            raise ValueError(
                f"serialized trace must be a JSON object, got "
                f"{type(d).__name__}"
            )
        return JobTrace.from_dict(d)


class PhaseRecorder:
    """Accumulates :class:`JobTrace` objects across job executions.

    The engine only uses the narrow protocol ``start_job(...) -> trace`` +
    ``trace.record_phase/finish`` (duck-typed, so the engine never imports
    this package).  Everything else here is analysis convenience.

    ``max_traces`` bounds retention (oldest dropped first) for long-lived
    recorders whose consumers only read recent traces — e.g. a traced
    cluster oracle executing thousands of profiling runs but handing only
    ``last`` to the scheduler.  ``None`` (default) keeps everything, which
    is what profiling harnesses that aggregate over all traces want.
    """

    def __init__(self, max_traces: int | None = None) -> None:
        if max_traces is not None and max_traces < 1:
            raise ValueError("max_traces must be >= 1")
        self.max_traces = max_traces
        self.traces: list[JobTrace] = []

    def __len__(self) -> int:
        return len(self.traces)

    @property
    def last(self) -> JobTrace:
        if not self.traces:
            raise IndexError("no traces recorded yet")
        return self.traces[-1]

    def start_job(self, app_name: str, cfg, input_len: int) -> JobTrace:
        config = dataclasses.asdict(cfg)
        config["input_len"] = int(input_len)
        trace = JobTrace(app=app_name, config=config)
        self.traces.append(trace)
        if self.max_traces is not None and len(self.traces) > self.max_traces:
            del self.traces[: len(self.traces) - self.max_traces]
        return trace

    def clear(self) -> None:
        self.traces.clear()

    def mean_phase_times(
        self, traces: Iterable[JobTrace] | None = None
    ) -> dict[str, float]:
        """Mean wall time per phase over ``traces`` (default: all)."""
        traces = list(self.traces if traces is None else traces)
        if not traces:
            return {}
        acc: dict[str, list[float]] = {}
        for t in traces:
            for p in t.phases:
                acc.setdefault(p.phase, []).append(p.wall_s)
        return {k: sum(v) / len(v) for k, v in acc.items()}


def collect_traced(trace: JobTrace, out_keys, out_vals) -> dict[int, int]:
    """Host-side collect phase, recorded into ``trace`` as phase 4.

    The engine's job output stops at the reduce partitions; gathering the
    (key -> value) dict is the collect phase, timed and counted here so a
    trace covers the full map → shuffle → reduce → collect pipeline.
    """
    from repro.mapreduce.engine import collect_results

    t0 = time.perf_counter()
    c0 = time.process_time()
    result = collect_results(out_keys, out_vals)
    cpu = time.process_time() - c0
    wall = time.perf_counter() - t0
    trace.record_phase(
        "collect",
        wall,
        unique_keys=len(result),
        bytes_out=len(result) * PAIR_BYTES,
        cpu_s=cpu,
        cpu_workers=float(os.cpu_count() or 1),
    )
    if trace.total_s is not None:
        trace.finish(trace.total_s + wall)
    return result
