from repro.train.step import (
    StepConfig,
    build_compressed_dp_train_step,
    build_decode_step,
    build_eval_step,
    build_prefill_step,
    build_train_step,
    decode_state_shapes,
)

__all__ = [
    "StepConfig",
    "build_compressed_dp_train_step",
    "build_decode_step",
    "build_eval_step",
    "build_prefill_step",
    "build_train_step",
    "decode_state_shapes",
]
