"""Train / serve step builders: remat, microbatching, chunked loss, ZeRO.

``build_train_step`` returns a pure function suitable for
``jax.jit(..., in_shardings=..., donate_argnums=...)`` — the launcher and the
dry-run both consume it.  Distribution is pjit-style: parameter/batch
PartitionSpecs come from ``repro.sharding.rules``; FSDP param sharding makes
XLA emit the all-gather-params / reduce-scatter-grads (ZeRO-3) schedule
automatically.

``build_compressed_dp_train_step`` is the explicit shard_map variant with
int8 error-feedback gradient compression on the DP all-reduce (DP-only,
params replicated) — the distributed-optimization trick from DESIGN.md §3,
measured in §Perf.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.optim import adamw
from repro.optim import grad_compress


@dataclasses.dataclass(frozen=True)
class StepConfig:
    remat: str = "none"            # none | dots | full
    logits_chunk: int = 0          # 0 = full logits
    microbatch: int = 1            # gradient-accumulation chunks
    use_flash: bool = False
    cache_dtype: str = "bfloat16"  # KV cache / SSM state dtype
    unroll_layers: bool = False    # dry-run flop accounting (see transformer)


def build_train_step(cfg: ModelConfig, optim_cfg: adamw.AdamWConfig,
                     step_cfg: StepConfig = StepConfig()):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(p, b):
        return tf.loss_fn(
            p, cfg, b,
            use_flash=step_cfg.use_flash,
            remat=step_cfg.remat,
            logits_chunk=step_cfg.logits_chunk,
            unroll_layers=step_cfg.unroll_layers,
        )

    def grads_of(params, batch):
        if step_cfg.microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        k = step_cfg.microbatch

        def slice_mb(leaf):
            b = leaf.shape[0]
            if b % k:
                raise ValueError(f"batch {b} not divisible by microbatch {k}")
            return leaf.reshape(k, b // k, *leaf.shape[1:])

        mbs = jax.tree.map(slice_mb, batch)

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        if step_cfg.unroll_layers:
            # dry-run accounting mode: scan bodies are costed once by XLA
            # cost_analysis, so unroll the accumulation loop too
            loss_sum, g_sum = jnp.float32(0.0), g0
            for i in range(k):
                mb = jax.tree.map(lambda l: l[i], mbs)
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_sum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_sum, g
                )
                loss_sum = loss_sum + loss
        else:
            def acc(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + loss, g_acc), None

            (loss_sum, g_sum), _ = jax.lax.scan(
                acc, (jnp.float32(0.0), g0), mbs
            )
        grads = jax.tree.map(lambda g: g / k, g_sum)
        return loss_sum / k, grads

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        lr_scale = adamw.cosine_schedule(opt_state["step"])
        params, opt_state, metrics = adamw.apply_updates(
            optim_cfg, params, grads, opt_state, lr_scale
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def build_eval_step(cfg: ModelConfig, step_cfg: StepConfig = StepConfig()):
    def eval_step(params, batch):
        return tf.loss_fn(
            params, cfg, batch,
            use_flash=step_cfg.use_flash,
            logits_chunk=step_cfg.logits_chunk,
        )

    return eval_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, max_len: int,
                       step_cfg: StepConfig = StepConfig()):
    """Prompt processing.  Encoder archs: plain forward (no cache)."""
    cache_dtype = jnp.dtype(step_cfg.cache_dtype)
    if not cfg.causal:

        def encode_step(params, batch):
            logits, _ = tf.forward(
                params, cfg, batch, use_flash=step_cfg.use_flash,
                unroll_layers=step_cfg.unroll_layers,
            )
            return logits

        return encode_step

    def prefill_step(params, batch):
        return tf.prefill(
            params, cfg, batch, max_len,
            use_flash=step_cfg.use_flash, cache_dtype=cache_dtype,
            unroll_layers=step_cfg.unroll_layers,
        )

    return prefill_step


def build_decode_step(cfg: ModelConfig,
                      step_cfg: StepConfig = StepConfig()):
    """(params, state, batch(B,1)) -> (logits, state).  State is donated."""

    def decode(params, state, batch):
        return tf.decode_step(
            params, cfg, state, batch, use_flash=step_cfg.use_flash,
            unroll_layers=step_cfg.unroll_layers,
        )

    return decode


def decode_state_shapes(cfg: ModelConfig, batch: int, max_len: int,
                        step_cfg: StepConfig = StepConfig()):
    """ShapeDtypeStruct pytree of the decode state (no allocation)."""
    return jax.eval_shape(
        lambda: tf.init_decode_state(
            cfg, batch, max_len, jnp.dtype(step_cfg.cache_dtype)
        )
    )


# ---------------------------------------------------------------------------
# explicit-DP shard_map step with int8 gradient compression
# ---------------------------------------------------------------------------


def build_compressed_dp_train_step(cfg: ModelConfig,
                                   optim_cfg: adamw.AdamWConfig,
                                   mesh, axis: str = "data",
                                   step_cfg: StepConfig = StepConfig()):
    """DP-only train step: per-shard grads, int8+error-feedback all-reduce.

    params/opt_state replicated; batch sharded on ``axis``.  Returns a step
    taking an extra error-feedback state pytree.
    """
    from jax.sharding import PartitionSpec as P

    def loss_fn(p, b):
        return tf.loss_fn(
            p, cfg, b, use_flash=step_cfg.use_flash,
            remat=step_cfg.remat, logits_chunk=step_cfg.logits_chunk,
        )

    def shard_body(params, opt_state, err_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        n = jax.lax.psum(jnp.float32(1.0), axis)
        grads, err_state = grad_compress.psum_compressed(
            grads, err_state, axis
        )
        grads = jax.tree.map(lambda g: g / n, grads)
        lr_scale = adamw.cosine_schedule(opt_state["step"])
        params, opt_state, metrics = adamw.apply_updates(
            optim_cfg, params, grads, opt_state, lr_scale
        )
        metrics["loss"] = loss
        return params, opt_state, err_state, metrics

    rep = lambda tree: jax.tree.map(lambda _: P(), tree)

    def make(params_like, opt_like, err_like, batch_like):
        batch_spec = jax.tree.map(
            lambda l: P(axis, *([None] * (l.ndim - 1))), batch_like
        )
        from repro.compat import shard_map

        return shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(rep(params_like), rep(opt_like), rep(err_like),
                      batch_spec),
            out_specs=(rep(params_like), rep(opt_like), rep(err_like),
                       {"loss": P(), "grad_norm": P(), "lr": P()}),
            check=False,
        )

    return make
