from repro.kernels.local_reduce.ops import local_reduce
from repro.kernels.local_reduce.ref import PAD_KEY, local_reduce_ref

__all__ = ["local_reduce", "local_reduce_ref", "PAD_KEY"]
