"""Pallas TPU local-reduce kernel (MapReduce map-side combine).

One map task's spill-sorted pair row per grid step: aggregate equal-key
runs *and* front-pack the aggregates, so the combined row can be
truncated to the task's distinct-key bound before it reaches the shuffle
fabric.

TPU adaptation: like ``segment_reduce``, the scatter-style segment sum
becomes a matmul against a one-hot segment matrix — but here the output
is indexed by *segment id* instead of scattered back to first-occurrence
positions, which IS the compaction (segment ids are dense in
0..n_segments-1 because the row is sorted):

    seg_onehot[i, s] = (seg_id[i] == s)          (C x C, built from iota)
    agg = seg_onehot^T @ values                  (compacted segment sums)
    ck[s] = min_i (first[i] & seg_id[i] == s ? keys[i] : PAD_KEY)

Values ride the MXU in float32; keys stay int32 throughout (a one-hot
matmul would round-trip them through float32, which is not exact past
2**24 — PAD_KEY alone is 2**31 - 1), so the key compaction is a masked
min-reduce on the VPU.

Grid: (n_tasks,); blocks: keys/values (1, C) -> out (1, C).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD_KEY = jnp.iinfo(jnp.int32).max


def _local_reduce_kernel(k_ref, v_ref, ok_ref, ov_ref):
    keys = k_ref[0]                      # (C,) sorted, PAD_KEY = invalid
    vals = v_ref[0].astype(jnp.float32)
    C = keys.shape[0]
    valid = keys != PAD_KEY
    pos = jax.lax.iota(jnp.int32, C)
    prev = jnp.roll(keys, 1)
    first = ((keys != prev) | (pos == 0)) & valid
    seg_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    seg_id = jnp.where(valid, seg_id, C - 1)
    # one-hot segment matrix -> MXU segment sums, compacted by segment id
    iota = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    onehot = (seg_id[:, None] == iota).astype(jnp.float32)   # (i, s)
    vals = jnp.where(valid, vals, 0.0)
    agg = jax.lax.dot_general(
        onehot, vals[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                               # (C,) sums at slot = segment id
    # front-packed keys: each segment's key lands at slot seg_id, in
    # int32 (mask + min-reduce; empty slots stay PAD_KEY)
    mask = first[:, None] & (seg_id[:, None] == iota)
    ck = jnp.min(
        jnp.where(mask, keys[:, None], PAD_KEY), axis=0
    )
    ok_ref[0] = ck
    ov_ref[0] = jnp.where(ck != PAD_KEY, agg, 0.0).astype(ov_ref.dtype)


def local_reduce_fwd(keys, values, *, interpret: bool = True):
    """keys/values: (N, C) per-task spill-sorted rows.  Returns
    (out_k, out_v) of the same shape with each row's equal-key aggregates
    front-packed in ascending key order, (PAD_KEY, 0) tail."""
    N, C = keys.shape
    return pl.pallas_call(
        _local_reduce_kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, C), lambda r: (r, 0)),
            pl.BlockSpec((1, C), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda r: (r, 0)),
            pl.BlockSpec((1, C), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, C), keys.dtype),
            jax.ShapeDtypeStruct((N, C), values.dtype),
        ],
        interpret=interpret,
    )(keys, values)
