"""Pure-jnp oracle for the local-reduce (map-side combine) kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_reduce.ref import PAD_KEY, segment_reduce_ref


def local_reduce_ref(keys, values):
    """keys (N,) sorted int32 (PAD_KEY = invalid); values (N,) int32.

    Returns (out_keys, out_vals): each equal-key run's aggregate,
    front-packed in ascending key order with a (PAD_KEY, 0) tail.
    """
    ok, ov = segment_reduce_ref(keys, values)
    # First occurrences of a sorted row are ascending and distinct, so an
    # ascending sort of the sparse output front-packs the live aggregates
    # in key order (PAD_KEY sorts last; dead slots are all (PAD_KEY, 0)).
    order = jnp.argsort(ok)
    return ok[order], ov[order]
