"""jit'd public wrapper for the local-reduce (map-side combine) kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.local_reduce.kernel import local_reduce_fwd


@partial(jax.jit, static_argnames=("interpret",))
def local_reduce(keys, values, *, interpret: bool = True):
    """keys/values (N, C) (sorted, PAD_KEY-padded per row) or (C,) 1-D.

    Returns (out_keys, out_vals) with each row's equal-key aggregates
    front-packed in ascending key order and a (PAD_KEY, 0) tail — the
    compacting counterpart of ``segment_reduce``.
    """
    squeeze = keys.ndim == 1
    if squeeze:
        keys, values = keys[None], values[None]
    vals_f = values.astype(jnp.float32)
    ok, ov = local_reduce_fwd(keys, vals_f, interpret=interpret)
    ov = ov.astype(values.dtype)
    if squeeze:
        return ok[0], ov[0]
    return ok, ov
