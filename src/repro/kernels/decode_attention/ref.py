"""Pure-jnp oracle for decode attention (single/few queries vs long KV)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B, Sq, Hq, hd); caches: (B, S_max, n_kv, hd); kv_len scalar.

    Attends q (at positions kv_len - Sq .. kv_len - 1) over cache[:kv_len],
    causal within the fresh block.  fp32 softmax.
    """
    B, Sq, Hq, hd = q.shape
    S_max, n_kv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // n_kv
    qg = q.reshape(B, Sq, n_kv, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    logits = logits * hd**-0.5
    qpos = kv_len - Sq + jnp.arange(Sq)
    kpos = jnp.arange(S_max)
    mask = kpos[None, :] <= qpos[:, None]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, Sq, Hq, hd)
