"""Pallas TPU flash-decode kernel: few queries vs a long KV cache.

Decode is memory-bound (the whole cache streams HBM->VMEM once); the kernel
tiles the cache into ``block_k`` chunks and keeps the online-softmax
accumulators in VMEM scratch.

Grid: (B * n_kv, n_k_blocks) — cache chunks innermost.  The dynamic valid
length (how much of the cache is filled) arrives as a scalar-prefetch
operand in SMEM, so the same compiled kernel serves any fill level and
fully-invalid chunks are masked (and cheap: one compare + select per chunk).

Blocks:
    q   : (1, G*Sq_pad, d)  — all grouped query heads of one kv head
    k/v : (1, block_k, d)
    o   : (1, G*Sq_pad, d)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, sm_scale, block_k, n_k_blocks, n_q, sq):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)   # (n_q = G*Sq_pad, d)
        k = k_ref[0].astype(jnp.float32)   # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                       # (n_q, block_k)
        # rows are (g, qpos) pairs; query qpos sits at kv_len - sq + qpos
        row_q = jax.lax.broadcasted_iota(jnp.int32, (n_q, block_k), 0) % sq
        qpos = kv_len - sq + row_q
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (n_q, block_k), 1
        )
        mask = kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, kv_len, *, sm_scale: float,
                         sq: int,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True):
    """q: (B*n_kv, n_q=G*Sq_pad, d); k, v: (B*n_kv, S_max, d); kv_len ()."""
    BH, n_q, d = q.shape
    S_max = k.shape[1]
    n_k = S_max // block_k
    kernel = functools.partial(
        _decode_kernel,
        sm_scale=sm_scale,
        block_k=block_k,
        n_k_blocks=n_k,
        n_q=n_q,
        sq=sq,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_k),
        in_specs=[
            pl.BlockSpec((1, n_q, d), lambda h, ki, len_ref: (h, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, ki, len_ref: (h, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, ki, len_ref: (h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_q, d), lambda h, ki, len_ref: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_q,), jnp.float32),
            pltpu.VMEM((n_q,), jnp.float32),
            pltpu.VMEM((n_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, n_q, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray([kv_len], jnp.int32), q, k, v)
