"""jit'd public wrapper for the flash-decode kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    DEFAULT_BLOCK_K,
    decode_attention_fwd,
)

LANE = 128


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad:
        w = [(0, 0)] * x.ndim
        w[axis] = (0, pad)
        x = jnp.pad(x, w)
    return x


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True):
    """q: (B, Sq, Hq, hd); caches: (B, S_max, n_kv, hd); kv_len scalar.

    Returns (B, Sq, Hq, hd) — matches ``ref.decode_attention_ref``.
    """
    B, Sq, Hq, hd = q.shape
    S_max, n_kv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // n_kv
    # cache chunk size: cap at the (128-aligned) cache length
    bk = min(block_k, ((S_max + 127) // 128) * 128)
    # layout: (B, n_kv, G*Sq, hd)
    qk = q.reshape(B, Sq, n_kv, G, hd).transpose(0, 2, 3, 1, 4)
    qk = qk.reshape(B * n_kv, G * Sq, hd)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(B * n_kv, S_max, hd)
    vk = v_cache.transpose(0, 2, 1, 3).reshape(B * n_kv, S_max, hd)
    kk = _pad_axis(kk, 1, bk)
    vk = _pad_axis(vk, 1, bk)
    qk = _pad_axis(qk, 2, LANE)
    kk = _pad_axis(kk, 2, LANE)
    vk = _pad_axis(vk, 2, LANE)
    out = decode_attention_fwd(
        qk, kk, vk, kv_len, sm_scale=hd**-0.5, sq=Sq, block_k=bk,
        interpret=interpret,
    )
    out = out[:, :, :hd].reshape(B, n_kv, G, Sq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, Hq, hd)
