from repro.kernels.segment_reduce.ops import segment_reduce
from repro.kernels.segment_reduce.ref import PAD_KEY, segment_reduce_ref

__all__ = ["segment_reduce", "segment_reduce_ref", "PAD_KEY"]
