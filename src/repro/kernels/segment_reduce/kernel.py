"""Pallas TPU sorted segment-reduce kernel (MapReduce reduce-task combine).

One reduce-task partition per grid step: the engine hands each reducer a
capacity-bounded, key-sorted partition; the kernel aggregates equal-key runs
entirely in VMEM.

TPU adaptation: no scatter.  The scatter-style segment sum of the XLA
reference becomes a matmul against a one-hot segment matrix — MXU work
instead of serial VREG updates:

    seg_onehot[i, s] = (seg_id[i] == s)           (C x C, built from iota)
    agg = seg_onehot^T @ values                   (segment sums)
    out = first * (seg_onehot @ agg)              (scatter-back, again MXU)

Grid: (n_partitions,); blocks: keys/values (1, C) -> out (1, C).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD_KEY = jnp.iinfo(jnp.int32).max


def _segment_reduce_kernel(k_ref, v_ref, ok_ref, ov_ref):
    keys = k_ref[0]                      # (C,) sorted, PAD_KEY tail
    vals = v_ref[0].astype(jnp.float32)
    C = keys.shape[0]
    valid = keys != PAD_KEY
    pos = jax.lax.iota(jnp.int32, C)
    prev = jnp.roll(keys, 1)
    first = ((keys != prev) | (pos == 0)) & valid
    seg_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    seg_id = jnp.where(valid, seg_id, C - 1)
    # one-hot segment matrix -> MXU segment sums
    iota = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    onehot = (seg_id[:, None] == iota).astype(jnp.float32)   # (i, s)
    vals = jnp.where(valid, vals, 0.0)
    agg = jax.lax.dot_general(
        onehot, vals[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                               # (C,) per-segment sums
    back = jax.lax.dot_general(
        onehot, agg[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                               # agg[seg_id[i]]
    ok_ref[0] = jnp.where(first, keys, PAD_KEY)
    ov_ref[0] = jnp.where(first, back, 0.0).astype(ov_ref.dtype)


def segment_reduce_fwd(keys, values, *, interpret: bool = True):
    """keys/values: (R, C) per-partition sorted. Returns (out_k, out_v)."""
    R, C = keys.shape
    return pl.pallas_call(
        _segment_reduce_kernel,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, C), lambda r: (r, 0)),
            pl.BlockSpec((1, C), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda r: (r, 0)),
            pl.BlockSpec((1, C), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), keys.dtype),
            jax.ShapeDtypeStruct((R, C), values.dtype),
        ],
        interpret=interpret,
    )(keys, values)
