"""Pure-jnp oracle for the sorted segment-reduce (MapReduce combine)."""

from __future__ import annotations

import jax.numpy as jnp

PAD_KEY = jnp.iinfo(jnp.int32).max


def segment_reduce_ref(keys, values):
    """keys (N,) sorted int32 (PAD_KEY = invalid); values (N,) int32.

    Returns (out_keys, out_vals): the aggregate of each key's run sits at
    its first occurrence; other slots are (PAD_KEY, 0).
    """
    n = keys.shape[0]
    valid = keys != PAD_KEY
    first = jnp.concatenate(
        [jnp.array([True]), keys[1:] != keys[:-1]]
    ) & valid
    seg_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    seg_id = jnp.where(valid, seg_id, n - 1)
    agg = jnp.zeros((n,), values.dtype).at[seg_id].add(
        jnp.where(valid, values, 0)
    )
    out_keys = jnp.where(first, keys, PAD_KEY)
    out_vals = jnp.where(first, agg[seg_id], 0)
    return out_keys, out_vals
