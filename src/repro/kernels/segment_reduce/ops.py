"""jit'd public wrapper for the segment-reduce kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import segment_reduce_fwd


@partial(jax.jit, static_argnames=("interpret",))
def segment_reduce(keys, values, *, interpret: bool = True):
    """keys/values (R, C) (sorted, PAD_KEY-padded per row) or (C,) 1-D."""
    squeeze = keys.ndim == 1
    if squeeze:
        keys, values = keys[None], values[None]
    vals_f = values.astype(jnp.float32)
    ok, ov = segment_reduce_fwd(keys, vals_f, interpret=interpret)
    ov = ov.astype(values.dtype)
    if squeeze:
        return ok[0], ov[0]
    return ok, ov
