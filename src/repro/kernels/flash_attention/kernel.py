"""Pallas TPU flash-attention forward kernel (causal/bidirectional, GQA).

Grid: (B * n_heads, n_q_blocks, n_k_blocks) — k blocks innermost so the
online-softmax accumulators (m, l, acc) persist in VMEM scratch across the
k sweep.  BlockSpecs tile Q/K/V/O into VMEM:

    q   : (1, block_q, head_dim)   index (h, qi, ki) -> (h, qi, 0)
    k/v : (1, block_k, head_dim)   index (h, qi, ki) -> (h // G, ki, 0)
    o   : (1, block_q, head_dim)   index (h, qi, ki) -> (h, qi, 0)

GQA is expressed in the K/V index map (q-head h reads kv-head h // G) — no
repeated-KV materialization, matching the reference einsum semantics.
Fully-masked causal blocks are skipped via pl.when (no FLOPs burned).
MXU alignment: block_q/block_k default 128; head_dim padded to 128 by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  n_k_blocks: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k                          # tail padding
        if causal:
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip blocks strictly above the diagonal (no FLOPs burned)
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        sm_scale: float | None = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        true_seq_k: int | None = None,
                        interpret: bool = True):
    """q: (BH, Sq, d); k, v: (B*n_kv, Sk, d) with BH = B*n_kv*G.

    Sq/Sk must be pre-padded to block multiples by ops.py; d MXU-aligned.
    ``true_seq_k``: unpadded K length — tail-padding keys are masked out.
    """
    BH, Sq, d = q.shape
    BK, Sk, _ = k.shape
    G = BH // BK
    n_q = Sq // block_q
    n_k = Sk // block_k
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale if sm_scale is not None else d**-0.5,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_k_blocks=n_k,
        seq_k=true_seq_k if true_seq_k is not None else Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // G, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
