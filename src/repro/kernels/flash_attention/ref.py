"""Pure-jnp oracle for flash attention (GQA, causal/bidirectional)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, n_kv, hd) -> (B, Sq, Hq, hd).

    fp32 softmax, GQA via grouped einsum (no repeated-KV materialization).
    """
    B, Sq, Hq, hd = q.shape
    n_kv = k.shape[2]
    G = Hq // n_kv
    scale = sm_scale if sm_scale is not None else hd**-0.5
    qg = q.reshape(B, Sq, n_kv, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, Hq, hd)
