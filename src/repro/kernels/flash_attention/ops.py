"""jit'd public wrapper for the flash-attention kernel.

Handles layout (model-facing (B, S, H, hd) <-> kernel-facing (BH, S, d)),
GQA head grouping, sequence padding to block multiples, and head_dim
padding to the 128-lane MXU width.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_fwd,
)

LANE = 128


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, n_kv, hd) -> (B, Sq, Hq, hd)."""
    B, Sq, Hq, hd = q.shape
    n_kv = k.shape[2]
    G = Hq // n_kv
    Sk = k.shape[1]
    bq = min(block_q, max(16, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (Sk - 1).bit_length()))
    # kernel layout: q (B, n_kv, G, Sq, hd) -> (B*n_kv*G, Sq, hd)
    qk = q.reshape(B, Sq, n_kv, G, hd).transpose(0, 2, 3, 1, 4)
    qk = qk.reshape(B * n_kv * G, Sq, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * n_kv, Sk, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * n_kv, Sk, hd)
    qk, pad_q = _pad_to(qk, 1, bq)
    kk, _ = _pad_to(kk, 1, bk)
    vk, _ = _pad_to(vk, 1, bk)
    qk, pad_d = _pad_to(qk, 2, LANE)
    kk, _ = _pad_to(kk, 2, LANE)
    vk, _ = _pad_to(vk, 2, LANE)
    scale = sm_scale if sm_scale is not None else hd**-0.5
    out = flash_attention_fwd(
        qk, kk, vk, causal=causal, sm_scale=scale,
        block_q=bq, block_k=bk, true_seq_k=Sk, interpret=interpret,
    )
    out = out[:, : Sq, : hd]
    out = out.reshape(B, n_kv, G, Sq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, Hq, hd)
