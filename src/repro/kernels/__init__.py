"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as a subpackage with kernel.py (pl.pallas_call + explicit
BlockSpec VMEM tiling), ops.py (jit'd public wrapper), and ref.py (pure-jnp
oracle used by the allclose sweep tests).  Validated in interpret mode on
CPU; TPU is the deployment target.  The dry-run/roofline path deliberately
uses the XLA reference implementations (custom calls hide FLOPs from
cost_analysis) — see EXPERIMENTS.md §Dry-run.
"""

from repro.kernels import (  # noqa: F401
    decode_attention,
    flash_attention,
    local_reduce,
    rwkv6,
    segment_reduce,
)

__all__ = [
    "decode_attention",
    "flash_attention",
    "local_reduce",
    "rwkv6",
    "segment_reduce",
]
