"""jit'd public wrapper for the WKV6 kernel (layout + padding)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import DEFAULT_CHUNK, wkv6_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = True):
    """r,k,v,w: (B, T, H, hs); u: (H, hs) -> (out (B,T,H,hs), S (B,H,hs,hs)).

    Zero initial state (the model carries state across calls itself via the
    XLA path; kernel deployment fuses whole sequences).
    """
    B, T, H, hs = r.shape
    pad_t = (-T) % chunk
    def prep(x, fill=0.0):
        if pad_t:
            x = jnp.pad(
                x, ((0, 0), (0, pad_t), (0, 0), (0, 0)),
                constant_values=fill,
            )
        # (B, T, H, hs) -> (B*H, T, hs)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T + pad_t, hs)

    rk = prep(r)
    kk = prep(k)
    vk = prep(v)
    wk = prep(w, fill=1.0)  # pad decay=1: no state change on padding
    out, s = wkv6_fwd(rk, kk, vk, wk, u, chunk=chunk, interpret=interpret)
    out = out.reshape(B, H, T + pad_t, hs)[:, :, :T].transpose(0, 2, 1, 3)
    return out, s.reshape(B, H, hs, hs)
