from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

__all__ = ["wkv6", "wkv6_ref"]
