"""Pure-jnp step-scan oracle for the WKV6 recurrence.

    out_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

All shapes per-head; the oracle scans one step at a time (the slow but
obviously-correct formulation the chunked kernel is checked against).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, S0=None):
    """r,k,v,w: (B, T, H, hs); u: (H, hs); S0: (B, H, hs, hs) or None.

    Returns (out (B,T,H,hs), S_T).
    """
    B, T, H, hs = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B, H, hs)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = S * wt[..., None] + kv
        return S_new, out

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
    S_T, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2, 3), S_T
