"""Pallas TPU kernel for the chunked WKV6 recurrence (data-dependent decay).

Grid: (B*H, n_chunks) — chunks innermost; the per-head state S (hs x hs)
lives in VMEM scratch across the chunk sweep, so HBM traffic is exactly one
pass over r/k/v/w plus the output (the recurrence itself never round-trips).

Within a chunk the recurrence is parallelized with the same overflow-safe
log-space factorization as the XLA reference path (`models/ssm.py`):
all decay factors are exp() of non-positive cumulative-log differences.

Blocks (hs = head_size, lane-padded by ops.py; C = chunk length):
    r/k/v/w : (1, C, hs)   index (bh, ci) -> (bh, ci, 0)
    u       : (1, hs)      index (bh, ci) -> (bh % H, 0)
    o       : (1, C, hs)   index (bh, ci) -> (bh, ci, 0)
    S_out   : (1, hs, hs)  index (bh, ci) -> (bh, 0, 0)   (final state)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref,
                 S_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        S_scr[...] = jnp.zeros_like(S_scr)

    r = r_ref[0].astype(jnp.float32)   # (C, hs)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (hs,)
    S0 = S_scr[...]                    # (hs_k, hs_v)

    logw = jnp.log(jnp.clip(w, 1e-8, 1.0))
    logD = jnp.cumsum(logw, axis=0)            # (C, hs), <= 0
    logDm1 = logD - logw                       # log D_{j-1}
    # inter-chunk: out_q += (r_q * D_{q-1}) @ S0
    out = jax.lax.dot_general(
        r * jnp.exp(logDm1), S0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # intra-chunk: att[q, d] = sum_c r[q,c] k[d,c] exp(logDm1[q,c]-logD[d,c])
    pair = jnp.exp(
        jnp.minimum(logDm1[:, None, :] - logD[None, :, :], 0.0)
    )                                          # (Cq, Cd, hs)
    att = jnp.einsum("qc,dc,qdc->qd", r, k, pair)
    C = r.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(tri, att, 0.0)
    out = out + jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # bonus diagonal
    bonus = jnp.sum(r * (u[None, :] * k), axis=1)   # (C,)
    out = out + bonus[:, None] * v
    o_ref[0] = out.astype(o_ref.dtype)
    # state update
    logD_C = logD[-1]                          # (hs,)
    decay_i = jnp.exp(logD_C[None, :] - logD)  # (C, hs) <= 1
    S_new = S0 * jnp.exp(logD_C)[:, None] + jax.lax.dot_general(
        k * decay_i, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    S_scr[...] = S_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0] = S_new.astype(s_out_ref.dtype)


def wkv6_fwd(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = True):
    """r,k,v,w: (BH, T, hs); u: (H, hs).  T must be a multiple of chunk.

    Returns (out (BH, T, hs), S_final (BH, hs, hs)).
    """
    BH, T, hs = r.shape
    H = u.shape[0]
    n_chunks = T // chunk
    kernel = functools.partial(
        _wkv6_kernel, chunk=chunk, n_chunks=n_chunks
    )
    out, s_final = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, hs), lambda bh, ci: (bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, hs, hs), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hs), r.dtype),
            jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out, s_final
