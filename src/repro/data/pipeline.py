"""Deterministic, resumable, sharded synthetic token pipeline.

Large-scale requirements this covers:

* **determinism / resumability** — batches are a pure function of
  (seed, step); restoring from a checkpoint at step k resumes the exact
  stream with a constant-time skip (no replaying k steps of state);
* **per-host sharding** — each data-parallel host generates only its slice
  of the global batch (no host ever materializes the global batch);
* **straggler isolation** — generation is stateless per step, so a re-run
  of a failed host's slice is trivially consistent.

The "corpus" is a seeded markov-ish token stream with enough structure for
loss to decrease (shifted-window next-token dependency), which makes the
end-to-end example (examples/train_lm.py) genuinely learnable.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structure of the synthetic language (mixture weight of copy-prev rule)
    structure: float = 0.7


class TokenPipeline:
    """Stateless-per-step synthetic stream; state == the step counter."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError(
                f"global_batch {cfg.global_batch} not divisible by "
                f"{n_hosts} hosts"
            )
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        """The (host-local slice of the) batch for one global step."""
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
            self.host_id,
        )
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(
            k1, (self.local_batch, cfg.seq_len), 0, cfg.vocab_size,
            dtype=jnp.int32,
        )
        # learnable structure: odd positions are (with prob `structure`) a
        # fixed function of the OBSERVED even token before them, so a
        # next-token model can reach a loss floor of ~0.5*ln(V).
        gate = jax.random.bernoulli(
            k2, self.cfg.structure, (self.local_batch, cfg.seq_len)
        )
        prev_even = jnp.roll(base, 1, axis=1)
        structured = (prev_even * 7 + 1) % cfg.vocab_size
        odd = (jnp.arange(cfg.seq_len) % 2 == 1)[None, :]
        tokens = jnp.where(odd & gate, structured, base)
        return {"tokens": tokens}

    def state_dict(self, step: int) -> dict:
        return {"step": int(step), "seed": self.cfg.seed}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])


def host_batches(pipeline: TokenPipeline, start_step: int = 0):
    """Infinite iterator of (step, batch)."""
    step = start_step
    while True:
        yield step, pipeline.batch_at(step)
        step += 1
