from repro.data.pipeline import DataConfig, TokenPipeline, host_batches

__all__ = ["DataConfig", "TokenPipeline", "host_batches"]
