"""Streaming windowed aggregations: sliding P² quantiles, EWMA rates.

The run-lifetime quantiles in :mod:`repro.obs.metrics` answer "how did
this trace do?"; a live service needs "how are the *last W seconds*
doing?" — the quantity SLO burn-rate alarms are defined over.  Three
primitives, all O(1) memory in the stream length and fully deterministic
(same observation sequence → same estimate, the property the bench
regression gate relies on):

* :class:`WindowedQuantile` — a ring of ``n_buckets`` :class:`~repro.obs.
  metrics.P2Quantile` summaries, each owning ``window_s / n_buckets`` of
  sim time.  Old buckets are recycled as time advances; querying merges
  the live buckets deterministically: every bucket contributes weighted
  points (its raw observations while ≤ 5, its five P² markers with
  position-derived weights afterwards) and the estimate is the weighted
  order statistic over the pool.  The error against an exact recompute
  over the same window is bounded by the P² marker approximation per
  bucket — property-tested in ``tests/test_service.py``.
* :class:`EwmaRate` — continuous-time exponentially-weighted event rate
  (events/s with a ``tau_s`` memory), the smooth signal for arrival /
  completion rates.
* :class:`RollingSum` — bucketed sliding sum/count over the window, the
  exact primitive under rolling goodput, windowed queue-depth means, and
  the SLO monitor's good/bad event counts.

Windows are *sim-time* windows: callers pass event timestamps, nothing
here reads a wall clock.
"""

from __future__ import annotations

import math

from repro.obs.metrics import P2Quantile

__all__ = [
    "EwmaRate",
    "RollingSum",
    "WindowedQuantile",
    "p2_weighted_points",
    "weighted_quantile",
]


def p2_weighted_points(est: P2Quantile) -> list[tuple[float, float]]:
    """Deterministic (value, weight) summary of one P² estimator.

    Below six observations the raw (exact) samples are returned with unit
    weight.  Afterwards the five markers stand in for the whole stream:
    marker ``i`` at position ``n[i]`` (1-based) represents the
    observations nearest to it, i.e. weight ``(n[i+1] - n[i-1]) / 2`` for
    interior markers and ``(n[1] - n[0]) / 2 + 0.5`` (symmetrically) for
    the extremes — the midpoint partition of [1, count], so the weights
    sum exactly to the observation count.
    """
    if est.count == 0:
        return []
    if est.count <= 5:
        return [(x, 1.0) for x in est._initial]
    q, n = est._q, est._n
    w = [
        (n[1] - n[0]) / 2.0 + 0.5,
        (n[2] - n[0]) / 2.0,
        (n[3] - n[1]) / 2.0,
        (n[4] - n[2]) / 2.0,
        (n[4] - n[3]) / 2.0 + 0.5,
    ]
    return [(q[i], w[i]) for i in range(5) if w[i] > 0]


def weighted_quantile(
    points: list[tuple[float, float]], p: float
) -> float | None:
    """Order statistic of a weighted point set: the smallest value whose
    cumulative weight reaches ``p`` of the total.  Deterministic and
    monotone in ``p``; ``None`` on an empty/zero-weight set."""
    if not points:
        return None
    pts = sorted(points)
    total = sum(w for _, w in pts)
    if total <= 0:
        return None
    target = p * total
    cum = 0.0
    for v, w in pts:
        cum += w
        if cum >= target:
            return v
    return pts[-1][0]


class _Ring:
    """Shared bucket-ring bookkeeping: map t → bucket index, recycle
    buckets whose epoch left the window."""

    def __init__(self, window_s: float, n_buckets: int):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if n_buckets < 2:
            raise ValueError(f"n_buckets must be >= 2, got {n_buckets}")
        self.window_s = float(window_s)
        self.n_buckets = int(n_buckets)
        self.bucket_s = self.window_s / self.n_buckets
        #: parallel arrays: bucket epoch (t // bucket_s) or None, payload.
        self._epochs: list[int | None] = [None] * self.n_buckets
        self._latest: int | None = None

    def _epoch(self, t: float) -> int:
        return int(math.floor(t / self.bucket_s))

    def slot(self, t: float) -> int:
        """Slot index for an observation at ``t`` (caller resets payload
        when the returned slot's epoch mismatches)."""
        e = self._epoch(t)
        if self._latest is None or e > self._latest:
            self._latest = e
        return e % self.n_buckets

    def live_slots(self, now: float) -> list[int]:
        """Slots whose epoch lies in the window ``(now - W, now]``."""
        e_now = max(
            self._epoch(now),
            self._latest if self._latest is not None else -(2**62),
        )
        lo = e_now - self.n_buckets + 1
        return [
            i for i, e in enumerate(self._epochs)
            if e is not None and lo <= e <= e_now
        ]

    def window_start(self, now: float) -> float:
        """Left edge of the retained window at query time ``now`` — the
        exact span :meth:`live_slots` covers, for recompute tests."""
        e_now = max(
            self._epoch(now),
            self._latest if self._latest is not None else -(2**62),
        )
        return (e_now - self.n_buckets + 1) * self.bucket_s


class WindowedQuantile:
    """Sliding-window quantile: a ring of P² buckets, merged on query."""

    def __init__(self, p: float, window_s: float, n_buckets: int = 8):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile p must be in (0, 1), got {p}")
        self.p = float(p)
        self._ring = _Ring(window_s, n_buckets)
        self._buckets: list[P2Quantile | None] = [None] * n_buckets
        self.count = 0          #: lifetime observations (not windowed)

    @property
    def window_s(self) -> float:
        return self._ring.window_s

    def observe(self, t: float, x: float) -> None:
        slot = self._ring.slot(t)
        e = self._ring._epoch(t)
        if self._ring._epochs[slot] != e:
            self._ring._epochs[slot] = e
            self._buckets[slot] = P2Quantile(self.p)
        self._buckets[slot].add(x)
        self.count += 1

    def window_count(self, now: float) -> int:
        return sum(
            self._buckets[i].count for i in self._ring.live_slots(now)
        )

    def window_start(self, now: float) -> float:
        return self._ring.window_start(now)

    def value(self, now: float) -> float | None:
        """Merged estimate over the live buckets; None if the window is
        empty.  Single-bucket windows return the bucket's own (exact ≤ 5
        observations) P² estimate."""
        live = self._ring.live_slots(now)
        if not live:
            return None
        if len(live) == 1:
            return self._buckets[live[0]].value
        points: list[tuple[float, float]] = []
        for i in live:
            points.extend(p2_weighted_points(self._buckets[i]))
        return weighted_quantile(points, self.p)


class EwmaRate:
    """Continuous-time EWMA event rate (events/s, memory ``tau_s``)."""

    def __init__(self, tau_s: float):
        if tau_s <= 0:
            raise ValueError(f"tau_s must be > 0, got {tau_s}")
        self.tau_s = float(tau_s)
        self._rate = 0.0
        self._t: float | None = None

    def observe(self, t: float, amount: float = 1.0) -> None:
        if self._t is not None and t > self._t:
            self._rate *= math.exp(-(t - self._t) / self.tau_s)
        self._t = t if self._t is None else max(self._t, t)
        self._rate += amount / self.tau_s

    def rate(self, now: float) -> float:
        if self._t is None:
            return 0.0
        if now <= self._t:
            return self._rate
        return self._rate * math.exp(-(now - self._t) / self.tau_s)


class RollingSum:
    """Bucketed sliding sum + count over the last ``window_s`` seconds."""

    def __init__(self, window_s: float, n_buckets: int = 8):
        self._ring = _Ring(window_s, n_buckets)
        self._sums = [0.0] * n_buckets
        self._counts = [0] * n_buckets

    @property
    def window_s(self) -> float:
        return self._ring.window_s

    def observe(self, t: float, amount: float = 1.0) -> None:
        slot = self._ring.slot(t)
        e = self._ring._epoch(t)
        if self._ring._epochs[slot] != e:
            self._ring._epochs[slot] = e
            self._sums[slot] = 0.0
            self._counts[slot] = 0
        self._sums[slot] += float(amount)
        self._counts[slot] += 1

    def total(self, now: float) -> float:
        return sum(self._sums[i] for i in self._ring.live_slots(now))

    def count(self, now: float) -> int:
        return sum(self._counts[i] for i in self._ring.live_slots(now))

    def rate(self, now: float) -> float:
        """Windowed average rate: total / window span."""
        return self.total(now) / self._ring.window_s

    def mean(self, now: float) -> float | None:
        n = self.count(now)
        return self.total(now) / n if n else None

    def window_start(self, now: float) -> float:
        return self._ring.window_start(now)
