"""Streaming service metrics: counters, gauges, P² quantile histograms.

The ROADMAP's continuous-service item needs p50/p99 turnaround and goodput
under overload — order statistics over an *unbounded* completion stream.
:class:`Histogram` tracks them with the P² algorithm (Jain & Chlamtac,
CACM 1985): five markers per target quantile, updated per observation with
a parabolic interpolation, O(1) memory and — crucially for the bench
regression gate — fully deterministic: the same observation sequence
always yields the same estimate, so committed p50/p99 values are
comparable across PRs.  Below five observations the exact interpolated
order statistic is returned, so small sims report textbook quantiles.

:class:`ClusterMetrics` is the hook object the simulators call: construct
one, pass it as ``Cluster(..., metrics=...)``, and every scheduling event
(arrival / dispatch / finish / reject / regrant / suspend / resume) lands
in the registry, plus an event-granularity sample of queue depth, busy
workers and suspended jobs.  With ``metrics=None`` (the default) the sims
pay one ``if`` per event and nothing else.
"""

from __future__ import annotations

import json
import re

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "P2Quantile",
    "ClusterMetrics",
]

_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Prometheus metric-name sanitization: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    name = _PROM_NAME.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _prom_value(v: float | None) -> str:
    if v is None:
        return "NaN"
    return repr(float(v))


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        self.value += amount

    def to_dict(self) -> float:
        return self.value


class Gauge:
    """Last-value gauge with an optional (t, value) series.

    ``set(v, t=...)`` appends a series point; consecutive points with the
    same value collapse (event loops sample densely, series stay small).
    """

    __slots__ = ("name", "value", "series")

    def __init__(self, name: str):
        self.name = name
        self.value = None
        self.series: list[tuple[float, float]] = []

    def set(self, value: float, t: float | None = None) -> None:
        self.value = float(value)
        if t is not None:
            if self.series and self.series[-1][1] == self.value:
                return
            self.series.append((float(t), self.value))

    def to_dict(self) -> dict:
        return {
            "value": self.value,
            "series": [[t, v] for t, v in self.series],
        }


class P2Quantile:
    """One streaming quantile estimate via the P² marker algorithm."""

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile p must be in (0, 1), got {p}")
        self.p = float(p)
        self.count = 0
        self._initial: list[float] = []   # first five observations, sorted
        self._q: list[float] = []         # marker heights
        self._n: list[float] = []         # marker positions (1-based)
        self._np: list[float] = []        # desired positions
        self._dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if self.count <= 5:
            self._initial.append(x)
            self._initial.sort()
            if self.count == 5:
                p = self.p
                self._q = list(self._initial)
                self._n = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._np = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p,
                            3.0 + 2.0 * p, 5.0]
            return
        q, n = self._q, self._n
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 5):
                if x < q[i]:
                    k = i - 1
                    break
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self._np[i] += self._dn[i]
        for i in (1, 2, 3):
            d = self._np[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                d <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                s = 1.0 if d > 0 else -1.0
                cand = self._parabolic(i, s)
                if not (q[i - 1] < cand < q[i + 1]):
                    cand = self._linear(i, s)
                q[i] = cand
                n[i] += s

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self._q, self._n
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
        )

    def _linear(self, i: int, d: float) -> float:
        j = i + int(d)
        q, n = self._q, self._n
        return q[i] + d * (q[j] - q[i]) / (n[j] - n[i])

    @property
    def value(self) -> float | None:
        """Current estimate: exact (interpolated) below five observations,
        the P² center marker afterwards.  None before any observation."""
        if self.count == 0:
            return None
        if self.count <= 5:
            xs = self._initial
            h = (len(xs) - 1) * self.p
            lo = int(h)
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (h - lo) * (xs[hi] - xs[lo])
        return self._q[2]


class Histogram:
    """Count / sum / min / max plus P² estimates at target quantiles."""

    def __init__(self, name: str, quantiles: tuple[float, ...] = (0.5, 0.99)):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._quantiles = {float(p): P2Quantile(p) for p in quantiles}

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)
        for est in self._quantiles.values():
            est.add(x)

    def quantile(self, p: float) -> float | None:
        return self._quantiles[float(p)].value

    @property
    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "quantiles": {
                f"{p:g}": est.value for p, est in self._quantiles.items()
            },
        }


class MetricsRegistry:
    """Named counters / gauges / histograms, create-on-first-use."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self.counters:
            self.counters[name] = Counter(name)
        return self.counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self.gauges:
            self.gauges[name] = Gauge(name)
        return self.gauges[name]

    def histogram(
        self, name: str, quantiles: tuple[float, ...] = (0.5, 0.99)
    ) -> Histogram:
        if name not in self.histograms:
            self.histograms[name] = Histogram(name, quantiles)
        return self.histograms[name]

    def to_dict(self) -> dict:
        return {
            "counters": {k: c.to_dict() for k, c in self.counters.items()},
            "gauges": {k: g.to_dict() for k, g in self.gauges.items()},
            "histograms": {
                k: h.to_dict() for k, h in self.histograms.items()
            },
        }

    def to_prom_text(self) -> str:
        """Prometheus text exposition (version 0.0.4) of the registry.

        Counters export as ``counter``, gauges as ``gauge`` (last value;
        the time series stays a JSON concern), histograms as ``summary``
        — per-quantile sample lines plus ``_sum`` / ``_count``.  Output
        is sorted by metric name so the dump is byte-stable for golden
        tests and diffable across runs.
        """
        lines: list[str] = []
        for name in sorted(self.counters):
            n = _prom_name(name)
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {_prom_value(self.counters[name].value)}")
        for name in sorted(self.gauges):
            g = self.gauges[name]
            if g.value is None:
                continue
            n = _prom_name(name)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_prom_value(g.value)}")
        for name in sorted(self.histograms):
            h = self.histograms[name]
            n = _prom_name(name)
            lines.append(f"# TYPE {n} summary")
            for p in sorted(h._quantiles):
                lines.append(
                    f'{n}{{quantile="{p:g}"}} '
                    f"{_prom_value(h.quantile(p))}"
                )
            lines.append(f"{n}_sum {_prom_value(h.sum)}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def save_prom(self, path: str) -> None:
        with open(path, "w") as fp:
            fp.write(self.to_prom_text())


#: the quantiles every ClusterMetrics histogram tracks.
DEFAULT_QUANTILES = (0.5, 0.9, 0.99)


class ClusterMetrics:
    """The hook object ``Cluster``/``ElasticCluster`` drive at event
    granularity.  All hooks are cheap pure-Python accounting; the sims
    guard every call behind ``if self.metrics is not None``."""

    def __init__(
        self,
        quantiles: tuple[float, ...] = DEFAULT_QUANTILES,
        *,
        window_s: float | None = None,
        window_buckets: int = 8,
    ):
        self.registry = MetricsRegistry()
        r = self.registry
        self.turnaround = r.histogram("turnaround_s", quantiles)
        self.wait = r.histogram("wait_s", quantiles)
        self.regrant_overhead = r.histogram("regrant_overhead_s", quantiles)
        self._t0: float | None = None
        self._t_last: float | None = None
        self._tokens_done = 0.0
        #: service mode: sliding-window views over the last ``window_s``
        #: sim seconds (see :mod:`repro.obs.windows`) next to the
        #: run-lifetime aggregates above.
        self.window_s = float(window_s) if window_s else None
        if self.window_s:
            from repro.obs.windows import (
                EwmaRate,
                RollingSum,
                WindowedQuantile,
            )

            W, B = self.window_s, window_buckets
            self.win_turnaround = {
                p: WindowedQuantile(p, W, B) for p in (0.5, 0.99)
            }
            self.win_wait = {
                p: WindowedQuantile(p, W, B) for p in (0.5, 0.99)
            }
            self.win_tokens = RollingSum(W, B)
            self.win_queue = RollingSum(W, B)
            self.arrival_rate = EwmaRate(W / 4.0)
            self.completion_rate = EwmaRate(W / 4.0)

    # ---- run lifecycle ---------------------------------------------------

    def on_run_start(self, t: float) -> None:
        self._t0 = float(t)

    def sample(
        self, now: float, queue_depth: int, busy_workers: int,
        suspended_jobs: int, *, net_bytes_per_s: float | None = None,
        net_capacity: float | None = None,
    ) -> None:
        """Event-granularity gauge sample (queue / busy / suspended, plus
        — on fabric-priced runs — aggregate shuffle demand vs capacity).
        The fabric kwargs are optional so capacity-unlimited callers
        (the elastic sim) keep their positional 4-arg call unchanged."""
        r = self.registry
        r.gauge("queue_depth").set(queue_depth, t=now)
        r.gauge("busy_workers").set(busy_workers, t=now)
        r.gauge("suspended_jobs").set(suspended_jobs, t=now)
        if net_bytes_per_s is not None:
            r.gauge("fabric_bytes_per_s").set(net_bytes_per_s, t=now)
            if net_capacity:
                r.gauge("fabric_utilization").set(
                    net_bytes_per_s / net_capacity, t=now
                )
        if self.window_s:
            self.win_queue.observe(now, queue_depth)
        self._t_last = float(now)

    # ---- per-event hooks -------------------------------------------------

    def on_arrival(self, now: float, job) -> None:
        self.registry.counter("jobs_arrived").inc()
        if self.window_s:
            self.arrival_rate.observe(now)

    def on_dispatch(self, now: float, rec) -> None:
        self.registry.counter("jobs_dispatched").inc()
        if rec.wait is not None:
            self.wait.observe(rec.wait)
            if self.window_s:
                for wq in self.win_wait.values():
                    wq.observe(now, rec.wait)

    def on_finish(self, now: float, rec) -> None:
        r = self.registry
        r.counter("jobs_completed").inc()
        contention = getattr(rec, "contention_s", 0.0)
        if contention:
            r.counter("contended_jobs").inc()
            r.counter("contention_s_total").inc(float(contention))
        if rec.turnaround is not None:
            self.turnaround.observe(rec.turnaround)
            if self.window_s:
                for wq in self.win_turnaround.values():
                    wq.observe(now, rec.turnaround)
        self._tokens_done += float(rec.spec.size)
        r.counter("tokens_completed").inc(float(rec.spec.size))
        if self.window_s:
            self.win_tokens.observe(now, float(rec.spec.size))
            self.completion_rate.observe(now)
        if self._t0 is not None and now > self._t0:
            r.gauge("goodput_tokens_per_s").set(
                self._tokens_done / (now - self._t0), t=now
            )

    def on_reject(self, now: float, rec) -> None:
        self.registry.counter("jobs_rejected").inc()

    def on_regrant(self, now: float, kind: str, overhead_s: float) -> None:
        r = self.registry
        r.counter("n_regrants").inc()
        r.counter(f"n_regrants_{kind}").inc()
        self.regrant_overhead.observe(overhead_s)

    def on_suspend(self, now: float, save_s: float) -> None:
        self.registry.counter("n_suspends").inc()

    def on_resume(self, now: float, restore_s: float) -> None:
        self.registry.counter("n_resumes").inc()

    # ---- export ----------------------------------------------------------

    def windowed_summary(self, now: float | None = None) -> dict | None:
        """Last-``window_s``-seconds view (p50/p99 turnaround + wait,
        goodput, queue depth, arrival/completion rates); ``None`` when the
        metrics object was built without a window.  ``now`` defaults to
        the last sampled event time."""
        if not self.window_s:
            return None
        now = self._t_last if now is None else float(now)
        if now is None:
            return None
        return {
            "window_s": self.window_s,
            "t": now,
            "p50_turnaround_s": self.win_turnaround[0.5].value(now),
            "p99_turnaround_s": self.win_turnaround[0.99].value(now),
            "p50_wait_s": self.win_wait[0.5].value(now),
            "p99_wait_s": self.win_wait[0.99].value(now),
            "jobs_completed": self.win_turnaround[0.99].window_count(now),
            "goodput_tokens_per_s": self.win_tokens.rate(now),
            "queue_depth_mean": self.win_queue.mean(now),
            "arrival_rate_per_s": self.arrival_rate.rate(now),
            "completion_rate_per_s": self.completion_rate.rate(now),
        }

    def summary(self) -> dict:
        """The service-metric scalars the launch CLI tabulates."""
        r = self.registry
        elapsed = (
            (self._t_last - self._t0)
            if self._t0 is not None and self._t_last is not None
            and self._t_last > self._t0 else None
        )
        out = {
            "jobs_completed": r.counter("jobs_completed").value,
            "jobs_rejected": r.counter("jobs_rejected").value,
            "p50_turnaround_s": self.turnaround.quantile(0.5),
            "p99_turnaround_s": self.turnaround.quantile(0.99),
            "p50_wait_s": self.wait.quantile(0.5),
            "p99_wait_s": self.wait.quantile(0.99),
            "goodput_tokens_per_s": (
                self._tokens_done / elapsed if elapsed else None
            ),
            "n_regrants": r.counter("n_regrants").value,
            "n_suspends": r.counter("n_suspends").value,
            "regrant_overhead_total_s": self.regrant_overhead.sum,
        }
        if self.window_s:
            out["windowed"] = self.windowed_summary()
        return out

    def to_dict(self) -> dict:
        return {"summary": self.summary(), **self.registry.to_dict()}

    def save(self, path: str) -> None:
        with open(path, "w") as fp:
            json.dump(self.to_dict(), fp, indent=1, sort_keys=True)
