"""Cluster-wide observability: spans, service metrics, drift alarms.

The paper's method *is* observation — profiling runs feed the regression
that predicts total time — but PR 3's :class:`~repro.telemetry.JobTrace`
only ever sees one job.  This package is the cluster-wide layer on top:

    log.py     — leveled structured logging (text or JSON-lines), the
                 replacement for bare ``print`` in long sim runs
    metrics.py — counters / gauges / deterministic P² streaming-quantile
                 histograms + the ``ClusterMetrics`` hook object the
                 simulators call at event granularity (p50/p99 turnaround,
                 wait, goodput, regrant overhead)
    spans.py   — ``SpanRecorder``: the causal tree cluster-run → job →
                 segment → wave/phase assembled from data the sims already
                 produce, exported as Chrome trace-event JSON (Perfetto)
                 with per-worker-slot tracks and counter tracks
    resources.py — ``ResourceTimeline``: per-job cpu_s/net_bytes phase
                 counters folded into cluster-wide utilization series
                 (fabric bytes/s vs net_capacity, busy CPU vs W), with
                 over-capacity episode detection, registry gauges, and
                 pid 4 Chrome counter tracks
    drift.py   — ``PredictionLedger``: every oracle estimate recorded
                 against the realized wall per (app, backend, depth)
                 category; EWMA absolute-relative-error raises a
                 ``DriftAlarm`` that :class:`~repro.cluster.online.
                 OnlineRefiner.refit_category` consumes
    windows.py — sim-time sliding windows: bucketed P² quantiles with
                 deterministic merge, EWMA rates, rolling sums — the
                 "last W seconds" view service mode runs on
    slo.py     — ``SLOMonitor``: multi-window burn-rate alarms and
                 error-budget accounting against an ``SLOPolicy``
    controller.py — ``OverloadController`` + ``ControlledPolicy``: the
                 alarm→action loop (shed / suspend-to-disk / resume)
                 with an auditable decision log, and the
                 ``StaticAdmission`` baseline it is benchmarked against

Everything here is strictly opt-in: ``Cluster(..., metrics=None)`` is the
default and costs one ``if`` per event; the engine's fused mode is never
touched (span assembly is post-hoc, from completed :class:`JobRecord`\\ s).
"""

from repro.obs.log import LEVELS, Logger, get_logger
from repro.obs.metrics import (
    ClusterMetrics,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    P2Quantile,
)
from repro.obs.resources import RESOURCE_PID, ResourceTimeline
from repro.obs.spans import (
    Span,
    SpanRecorder,
    build_span_tree,
    check_span_tiling,
    render_slots,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.obs.drift import (
    LEDGER_SCHEMA_VERSION,
    DriftAlarm,
    PredictionLedger,
)
from repro.obs.windows import (
    EwmaRate,
    RollingSum,
    WindowedQuantile,
    weighted_quantile,
)
from repro.obs.slo import BurnAlarm, SLOMonitor, SLOPolicy
from repro.obs.controller import (
    ControlAction,
    ControlledPolicy,
    OverloadController,
    StaticAdmission,
)

__all__ = [
    "LEVELS",
    "Logger",
    "get_logger",
    "ClusterMetrics",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "P2Quantile",
    "RESOURCE_PID",
    "ResourceTimeline",
    "Span",
    "SpanRecorder",
    "build_span_tree",
    "check_span_tiling",
    "render_slots",
    "to_chrome_trace",
    "validate_chrome_trace",
    "DriftAlarm",
    "LEDGER_SCHEMA_VERSION",
    "PredictionLedger",
    "EwmaRate",
    "RollingSum",
    "WindowedQuantile",
    "weighted_quantile",
    "BurnAlarm",
    "SLOMonitor",
    "SLOPolicy",
    "ControlAction",
    "ControlledPolicy",
    "OverloadController",
    "StaticAdmission",
]
