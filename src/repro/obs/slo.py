"""SLO policies + multi-window burn-rate alarms over windowed metrics.

An SLO here is the service-level statement the ROADMAP's "millions of
users" north star implies: *"objective × 100 % of completed jobs are
good"*, where good means turnaround within the target (or, for
deadline-carrying workloads, the deadline was met).  The monitor turns
the completion stream into **burn rates** — the rate at which the error
budget (the allowed ``1 - objective`` bad fraction) is being consumed,
measured over two sliding sim-time windows:

* the **fast** window reacts to an overload within seconds but would flap
  on a single unlucky burst;
* the **slow** window confirms the burn is sustained but would alarm far
  too late on its own.

An alarm *trips* only when **both** exceed ``trip_burn`` (the classic
multi-window burn-rate alerting rule), and *clears* — re-arms, in the
style of :mod:`repro.obs.drift`'s alarm/re-arm machinery — once both
fall below ``clear_burn``.  Each transition is recorded as a
:class:`BurnAlarm`; :class:`~repro.obs.controller.OverloadController`
converts them into admission shedding and the suspend-to-disk valve.

Error-budget accounting is lifetime: ``budget()`` reports events, bad
events, the allowed budget at the current event count, and the remaining
fraction — negative remaining means the service has formally blown its
SLO for the run.
"""

from __future__ import annotations

import dataclasses

from repro.obs.windows import RollingSum

__all__ = ["BurnAlarm", "SLOMonitor", "SLOPolicy"]


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """The objective: ``objective`` of completed jobs must be *good*.

    A job is good when its turnaround is within ``p99_turnaround_s``; a
    deadline-carrying job is judged by its deadline instead when
    ``use_deadlines`` is set (best-effort jobs still fall back to the
    turnaround target).
    """

    p99_turnaround_s: float
    objective: float = 0.99
    use_deadlines: bool = False

    def __post_init__(self):
        if self.p99_turnaround_s <= 0:
            raise ValueError("p99_turnaround_s must be > 0")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"objective must be in (0, 1), got {self.objective}"
            )

    @property
    def budget_fraction(self) -> float:
        """Allowed bad fraction: 1 - objective."""
        return 1.0 - self.objective

    def is_good(
        self, turnaround_s: float, met_deadline: bool | None = None
    ) -> bool:
        if self.use_deadlines and met_deadline is not None:
            return met_deadline
        return turnaround_s <= self.p99_turnaround_s


class SLOMonitor:
    """Multi-window burn-rate alarm over a completion stream.

    Drive it with :meth:`observe` on every completion and :meth:`update`
    whenever a control decision is due; ``update`` returns a
    :class:`BurnAlarm` exactly at trip/clear transitions and ``None``
    otherwise.  All times are sim time.
    """

    def __init__(
        self,
        slo: SLOPolicy,
        *,
        fast_window_s: float = 30.0,
        slow_window_s: float = 120.0,
        trip_burn: float = 2.0,
        clear_burn: float = 1.0,
        min_events: int = 12,
        n_buckets: int = 8,
    ):
        if slow_window_s <= fast_window_s:
            raise ValueError(
                f"slow window ({slow_window_s}s) must exceed the fast "
                f"window ({fast_window_s}s)"
            )
        if not 0 < clear_burn <= trip_burn:
            raise ValueError(
                f"need 0 < clear_burn <= trip_burn, got "
                f"({clear_burn}, {trip_burn})"
            )
        self.slo = slo
        self.trip_burn = float(trip_burn)
        self.clear_burn = float(clear_burn)
        self.min_events = int(min_events)
        self._fast_bad = RollingSum(fast_window_s, n_buckets)
        self._fast_all = RollingSum(fast_window_s, n_buckets)
        self._slow_bad = RollingSum(slow_window_s, n_buckets)
        self._slow_all = RollingSum(slow_window_s, n_buckets)
        self.tripped = False
        self.alarms: list[BurnAlarm] = []
        self.n_events = 0
        self.n_bad = 0

    # ---- feeding ---------------------------------------------------------

    def observe(
        self,
        t: float,
        turnaround_s: float,
        met_deadline: bool | None = None,
    ) -> None:
        good = self.slo.is_good(turnaround_s, met_deadline)
        bad = 0.0 if good else 1.0
        self._fast_all.observe(t, 1.0)
        self._slow_all.observe(t, 1.0)
        if bad:
            self._fast_bad.observe(t, 1.0)
            self._slow_bad.observe(t, 1.0)
        self.n_events += 1
        self.n_bad += int(bad)

    # ---- queries ---------------------------------------------------------

    def _burn(self, bad: RollingSum, all_: RollingSum, now: float) -> float:
        n = all_.total(now)
        if n <= 0:
            return 0.0
        return (bad.total(now) / n) / self.slo.budget_fraction

    def burn_rates(self, now: float) -> tuple[float, float]:
        """(fast, slow) burn: windowed bad fraction over budget fraction.
        Burn 1.0 consumes budget exactly as fast as the SLO allows."""
        return (
            self._burn(self._fast_bad, self._fast_all, now),
            self._burn(self._slow_bad, self._slow_all, now),
        )

    def budget(self) -> dict:
        """Lifetime error-budget account at the current event count."""
        allowed = self.slo.budget_fraction * self.n_events
        return {
            "events": self.n_events,
            "bad_events": self.n_bad,
            "allowed_bad": allowed,
            "remaining": allowed - self.n_bad,
            "remaining_frac": (
                (allowed - self.n_bad) / allowed if allowed > 0 else 1.0
            ),
        }

    # ---- alarm state machine --------------------------------------------

    def update(self, now: float) -> BurnAlarm | None:
        """Advance the trip/clear state machine; return the transition
        alarm when one fires.

        Trip: both burns above ``trip_burn`` with at least ``min_events``
        completions in the fast window (a near-empty window is noise, not
        an overload).  Clear: both burns back below ``clear_burn`` — the
        budget is recovering — with no event-count gate, since an empty
        window after an overload *is* recovery.
        """
        fast, slow = self.burn_rates(now)
        if not self.tripped:
            if (
                fast > self.trip_burn
                and slow > self.trip_burn
                and self._fast_all.total(now) >= self.min_events
            ):
                self.tripped = True
                return self._alarm(now, "trip", fast, slow)
        elif fast < self.clear_burn and slow < self.clear_burn:
            self.tripped = False
            return self._alarm(now, "clear", fast, slow)
        return None

    def _alarm(
        self, now: float, event: str, fast: float, slow: float
    ) -> BurnAlarm:
        alarm = BurnAlarm(
            t=float(now),
            event=event,
            burn_fast=fast,
            burn_slow=slow,
            budget_remaining_frac=self.budget()["remaining_frac"],
            n_events=self.n_events,
        )
        self.alarms.append(alarm)
        return alarm

    def to_dict(self) -> dict:
        return {
            "slo": dataclasses.asdict(self.slo),
            "tripped": self.tripped,
            "trip_burn": self.trip_burn,
            "clear_burn": self.clear_burn,
            "fast_window_s": self._fast_all.window_s,
            "slow_window_s": self._slow_all.window_s,
            "n_alarms": len(self.alarms),
            "alarms": [dataclasses.asdict(a) for a in self.alarms],
            "budget": self.budget(),
        }


@dataclasses.dataclass(frozen=True)
class BurnAlarm:
    """One burn-rate state transition (trip or clear)."""

    t: float
    event: str                    #: "trip" | "clear"
    burn_fast: float
    burn_slow: float
    budget_remaining_frac: float
    n_events: int
