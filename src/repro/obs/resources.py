"""Cluster-wide resource timelines folded from per-job phase records.

The telemetry layer measures resources *per job* (``cpu_s`` /
``net_bytes`` counters at the phase fences); the scheduler prices them
*per cluster* (aggregate shuffle demand vs ``net_capacity``, busy CPU vs
the worker pool).  This module is the fold between the two: it places
every completed job's trace phases on the simulation clock (the same
sequential layout the span exporter uses) and accumulates step-function
series of

* **fabric demand** — aggregate nominal shuffle bytes/s on the shared
  wire.  Nominal, not fair-shared: the series shows what the jobs *asked*
  of the fabric, so over-capacity intervals remain visible even though
  the contention-aware ground truth stretched the jobs until the actual
  rate fit under capacity;
* **busy CPU** — aggregate CPU-seconds per second (busy cores) across
  all running phases.

Consumers: Chrome counter tracks under a dedicated "cluster resources"
process (:func:`repro.obs.spans.to_chrome_trace` emits them
automatically when traces carry resource counters), gauges published
into a :class:`repro.obs.metrics.MetricsRegistry` for the Prometheus
exposition, and an over-capacity *episodes* log next to the fabric's own
per-job contention episodes on :class:`repro.cluster.cluster.
TraceResult`.
"""

from __future__ import annotations

import math

__all__ = ["RESOURCE_PID", "ResourceTimeline"]

#: Chrome trace-event process id for the cluster-resource counter tracks
#: (pid 1 = worker slots, 2 = jobs, 3 = slo control).
RESOURCE_PID = 4


def _series(deltas: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """Cumulative step function from (t, +/-delta) events.

    Decrements sort first at equal timestamps so back-to-back transfers
    don't spike the level above its true concurrent value.
    """
    out: list[tuple[float, float]] = []
    level = 0.0
    for t, d in sorted(deltas, key=lambda x: (x[0], x[1])):
        level += d
        if out and out[-1][0] == t:
            out[-1] = (t, level)
        else:
            out.append((t, level))
    return out


class ResourceTimeline:
    """Step-function resource series for one completed cluster run."""

    def __init__(
        self,
        net: list[tuple[float, float]],
        cpu: list[tuple[float, float]],
        *,
        net_capacity: float | None = None,
        total_workers: int | None = None,
        t0: float = 0.0,
        t1: float = 0.0,
    ):
        self._net = net            # [(t, bytes_per_s)]
        self._cpu = cpu            # [(t, busy_cpu_seconds_per_s)]
        self.net_capacity = net_capacity
        self.total_workers = total_workers
        self.t0 = float(t0)
        self.t1 = float(t1)

    @classmethod
    def from_result(cls, result) -> "ResourceTimeline":
        """Fold a :class:`~repro.cluster.cluster.TraceResult`'s completed
        jobs into cluster-wide series.  Phases are placed sequentially
        from each job's start (the span layout); negative-wall
        bookkeeping phases carry no resources and are skipped."""
        net_d: list[tuple[float, float]] = []
        cpu_d: list[tuple[float, float]] = []
        lo, hi = math.inf, -math.inf
        for rec in result.records:
            if not rec.completed or rec.trace is None:
                continue
            t = rec.start
            for p in rec.trace.phases:
                if p.wall_s <= 0:
                    continue
                p0, p1 = t, t + p.wall_s
                t = p1
                nb = p.counters.get("net_bytes", 0.0)
                if p.phase == "shuffle" and not nb:
                    nb = p.counters.get("bytes_in", 0.0)
                if nb > 0:
                    rate = nb / p.wall_s
                    net_d += [(p0, rate), (p1, -rate)]
                cpu_s = p.counters.get("cpu_s", 0.0)
                if cpu_s > 0:
                    rate = cpu_s / p.wall_s
                    cpu_d += [(p0, rate), (p1, -rate)]
                lo, hi = min(lo, p0), max(hi, p1)
        if not math.isfinite(lo):
            lo = hi = 0.0
        return cls(
            _series(net_d), _series(cpu_d),
            net_capacity=getattr(result, "net_capacity", None),
            total_workers=getattr(result, "total_workers", None),
            t0=lo, t1=hi,
        )

    # ---- queries --------------------------------------------------------

    @property
    def has_data(self) -> bool:
        return bool(self._net or self._cpu)

    def net_series(self) -> list[tuple[float, float]]:
        """Aggregate nominal fabric demand, [(t, bytes/s)] steps."""
        return list(self._net)

    def cpu_series(self) -> list[tuple[float, float]]:
        """Aggregate busy CPU (CPU-seconds per second), [(t, cores)]."""
        return list(self._cpu)

    @staticmethod
    def _peak(series) -> float:
        return max((v for _, v in series), default=0.0)

    def _mean(self, series) -> float:
        """Time-weighted mean level over [t0, t1]."""
        span = self.t1 - self.t0
        if span <= 0 or not series:
            return 0.0
        area = 0.0
        for (ta, va), (tb, _) in zip(series, series[1:]):
            area += va * (tb - ta)
        # Last step runs to the timeline end (its level is 0 by
        # construction when every transfer closed, so this adds nothing
        # for well-formed series).
        area += series[-1][1] * (self.t1 - series[-1][0])
        return area / span

    def over_capacity_episodes(
        self, capacity: float | None = None
    ) -> list[dict]:
        """Merged intervals where nominal fabric demand exceeds capacity
        (default: the run's ``net_capacity``); [] when unlimited."""
        cap = self.net_capacity if capacity is None else float(capacity)
        if cap is None or not self._net:
            return []
        episodes: list[dict] = []
        open_t: float | None = None
        peak = 0.0
        for i, (t, level) in enumerate(self._net):
            if level > cap:
                if open_t is None:
                    open_t = t
                    peak = level
                else:
                    peak = max(peak, level)
            elif open_t is not None:
                episodes.append({
                    "t0": open_t, "t1": t,
                    "peak_bytes_per_s": peak, "capacity": cap,
                })
                open_t = None
        if open_t is not None:
            episodes.append({
                "t0": open_t, "t1": self.t1,
                "peak_bytes_per_s": peak, "capacity": cap,
            })
        return episodes

    def summary(self) -> dict:
        """Headline utilization numbers (what :meth:`publish` exports)."""
        episodes = self.over_capacity_episodes()
        out = {
            "net_peak_bytes_per_s": self._peak(self._net),
            "net_mean_bytes_per_s": self._mean(self._net),
            "cpu_peak_busy": self._peak(self._cpu),
            "cpu_mean_busy": self._mean(self._cpu),
            "n_over_capacity_episodes": len(episodes),
            "over_capacity_s": sum(e["t1"] - e["t0"] for e in episodes),
        }
        if self.net_capacity:
            out["net_peak_utilization"] = (
                out["net_peak_bytes_per_s"] / self.net_capacity
            )
        if self.total_workers:
            out["cpu_peak_utilization"] = (
                out["cpu_peak_busy"] / self.total_workers
            )
        return out

    # ---- exports --------------------------------------------------------

    def publish(self, registry) -> dict:
        """Set fabric/CPU gauges on a :class:`~repro.obs.metrics.
        MetricsRegistry` (Prometheus exposition); returns the summary."""
        s = self.summary()
        for key in (
            "net_peak_bytes_per_s", "net_mean_bytes_per_s",
            "cpu_peak_busy", "cpu_mean_busy",
            "net_peak_utilization", "cpu_peak_utilization",
        ):
            if key in s:
                registry.gauge(f"fabric_{key}" if key.startswith("net")
                               else f"cluster_{key}").set(float(s[key]))
        registry.counter("fabric_over_capacity_episodes").inc(
            s["n_over_capacity_episodes"]
        )
        return s

    def counter_events(self) -> list[dict]:
        """Chrome "C" counter tracks under the "cluster resources"
        process: fabric demand (+ capacity line) and busy CPU."""
        from repro.obs.spans import _ev

        events = [
            _ev("process_name", "M", 0, RESOURCE_PID, 0,
                args={"name": "cluster resources"}),
        ]
        for t, v in self._net:
            events.append(_ev(
                "fabric_bytes_per_s", "C", t, RESOURCE_PID, 0,
                args={"value": round(v, 6)},
            ))
        if self.net_capacity and self._net:
            for t in (self.t0, self.t1):
                events.append(_ev(
                    "fabric_capacity", "C", t, RESOURCE_PID, 0,
                    args={"value": round(self.net_capacity, 6)},
                ))
        for t, v in self._cpu:
            events.append(_ev(
                "busy_cpu", "C", t, RESOURCE_PID, 0,
                args={"value": round(v, 6)},
            ))
        for i, e in enumerate(self.over_capacity_episodes()):
            events.append(_ev(
                f"fabric over capacity #{i}", "i", e["t0"], RESOURCE_PID,
                0, s="t",
                args={k: round(v, 6) for k, v in e.items()},
            ))
        return events
