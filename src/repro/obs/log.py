"""Leveled structured logging for long sim runs.

A :class:`Logger` emits either human text (``[name] message key=value``,
the shape the launch CLIs have always printed) or JSON-lines (one object
per line: ``{"logger", "level", "event", ...fields}``) — the ``--log-json``
flag on ``repro.launch.cluster`` and ``benchmarks.run`` flips the mode, so
a multi-hour trace replay is machine-parseable without changing any call
site.  No handlers, no global registry, no stdlib ``logging`` config: a
logger is a plain object writing to one stream, which keeps bench CSV on
stdout and diagnostics on whatever stream the caller picked.
"""

from __future__ import annotations

import json
import sys

__all__ = ["LEVELS", "Logger", "get_logger"]

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


class Logger:
    """One named log stream, text or JSON-lines.

    ``stream=None`` resolves to ``sys.stderr`` at call time (not at
    construction), so pytest's capture and CLI redirection both see the
    output they expect.
    """

    def __init__(
        self,
        name: str,
        *,
        level: str = "info",
        json_lines: bool = False,
        stream=None,
    ):
        if level not in LEVELS:
            raise ValueError(
                f"unknown level {level!r}; expected one of {sorted(LEVELS)}"
            )
        self.name = name
        self.level = level
        self.json_lines = bool(json_lines)
        self.stream = stream

    def _out(self):
        return self.stream if self.stream is not None else sys.stderr

    def enabled(self, level: str) -> bool:
        return LEVELS[level] >= LEVELS[self.level]

    def log(self, level: str, event: str, **fields) -> None:
        """Emit one record; ``fields`` must be JSON-representable scalars
        (or short lists) — they become ``key=value`` pairs in text mode."""
        if level not in LEVELS:
            raise ValueError(f"unknown level {level!r}")
        if not self.enabled(level):
            return
        out = self._out()
        if self.json_lines:
            rec = {"logger": self.name, "level": level, "event": event}
            rec.update(fields)
            out.write(json.dumps(rec, sort_keys=True, default=str) + "\n")
        else:
            msg = fields.pop("msg", None)
            parts = [f"[{self.name}]", str(msg) if msg is not None else event]
            parts += [f"{k}={v}" for k, v in fields.items()]
            out.write(" ".join(parts) + "\n")
        out.flush()

    def debug(self, event: str, **fields) -> None:
        self.log("debug", event, **fields)

    def info(self, event: str, **fields) -> None:
        self.log("info", event, **fields)

    def warning(self, event: str, **fields) -> None:
        self.log("warning", event, **fields)

    def error(self, event: str, **fields) -> None:
        self.log("error", event, **fields)


def get_logger(
    name: str,
    *,
    level: str = "info",
    json_lines: bool = False,
    stream=None,
) -> Logger:
    """Construct a :class:`Logger` (kept as a function so call sites read
    like the stdlib idiom; there is deliberately no global registry)."""
    return Logger(name, level=level, json_lines=json_lines, stream=stream)
