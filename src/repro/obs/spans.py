"""Span tracing: the causal tree cluster-run → job → segment → wave/phase.

Nothing here instruments the hot path.  A completed
:class:`~repro.cluster.cluster.TraceResult` already contains everything a
trace viewer needs — ``JobRecord.segments`` (elastic grant intervals),
``JobRecord.waves``/``gaps`` (wave boundaries and regrant/suspend holes,
recorded by the elastic sim as it consumes segments), and per-phase
:class:`~repro.telemetry.JobTrace` walls — so :func:`build_span_tree`
assembles the tree post-hoc and :func:`to_chrome_trace` exports Chrome
trace-event JSON loadable in Perfetto / ``chrome://tracing``:

* pid 1, one thread per **worker slot** — job execution intervals placed
  onto concrete slots by a greedy interval assignment (the sim's worker
  conservation guarantees it fits), with wave/phase spans nested inside;
* pid 1 **counter tracks** for queue depth, busy workers, suspended jobs;
* pid 2, one thread per **job** — the causal per-job view: wait span,
  execution segments, regrant/suspended gaps, wave/phase children.

Conservation discipline (same as ``JobTrace.check_conservation``, but
exact): a job span's children — wait + segments + gaps — must tile its
turnaround, and a segment's wave/phase children must tile the segment.
:func:`check_span_tiling` verifies it; the only tolerance granted is float
associativity (sums of exact boundary differences), not modeling slack.
The pipelined mode's negative-wall ``pipeline`` phase participates in the
sums *signed* — overlap is negative exposure — and exports as an instant
event (Chrome ``dur`` must be >= 0).
"""

from __future__ import annotations

import dataclasses
import heapq
import json

__all__ = [
    "Span",
    "SpanRecorder",
    "build_span_tree",
    "check_span_tiling",
    "render_slots",
    "to_chrome_trace",
    "validate_chrome_trace",
]


@dataclasses.dataclass
class Span:
    """One node of the causal tree: a named interval with children.

    ``wall_s`` is *signed*: the pipelined mode's overlap phase contributes
    negative exposure so sibling walls still sum to the parent's wall.
    """

    name: str
    cat: str                  # "run" | "job" | "wait" | "segment" | "gap"
    t0: float                 #      | "wave" | "phase"
    wall_s: float
    args: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)

    @property
    def t1(self) -> float:
        return self.t0 + self.wall_s

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


# --------------------------------------------------------------- assembly


def _phase_children(trace, t0: float) -> list[Span]:
    """Phase spans laid end-to-end from ``t0`` (base-cluster jobs run one
    uninterrupted segment, so sequential placement is exact)."""
    out = []
    cur = t0
    for p in trace.phases:
        out.append(
            Span(name=p.phase, cat="phase", t0=cur, wall_s=p.wall_s,
                 args=dict(p.counters))
        )
        cur += p.wall_s
    return out


def _job_span(rec) -> Span | None:
    spec = rec.spec
    if not rec.completed:
        return None
    job = Span(
        name=f"job {spec.job_id}", cat="job", t0=spec.arrival,
        wall_s=rec.finish - spec.arrival,
        args={
            "job_id": spec.job_id, "app": spec.app, "size": spec.size,
            "backend": rec.plan.backend, "workers": rec.plan.workers,
            "depth": rec.plan.depth, "n_regrants": rec.n_regrants,
            "n_suspends": rec.n_suspends,
        },
    )
    job.children.append(
        Span(name="wait", cat="wait", t0=spec.arrival,
             wall_s=rec.start - spec.arrival)
    )
    if rec.segments:
        waves = list(getattr(rec, "waves", None) or ())
        for idx, (ts, t1, w) in enumerate(rec.segments):
            seg = Span(
                name=f"segment {idx}", cat="segment", t0=ts, wall_s=t1 - ts,
                args={"workers": w},
            )
            seg.children = [
                Span(name=kind, cat="wave", t0=wt0, wall_s=wt1 - wt0,
                     args={"workers": ww})
                for wt0, wt1, kind, ww in waves
                if ts - 1e-12 <= wt0 and wt1 <= t1 + 1e-12
            ]
            job.children.append(seg)
        for gt0, gt1, kind, held in getattr(rec, "gaps", None) or ():
            job.children.append(
                Span(name=kind, cat="gap", t0=gt0, wall_s=gt1 - gt0,
                     args={"workers_held": held})
            )
    else:
        seg = Span(
            name="segment 0", cat="segment", t0=rec.start,
            wall_s=rec.finish - rec.start,
            args={"workers": rec.plan.workers},
        )
        trace = rec.trace
        if trace is not None and getattr(trace, "phases", None):
            seg.children = _phase_children(trace, rec.start)
        job.children.append(seg)
    return job


def build_span_tree(result) -> Span:
    """Assemble the causal tree for one completed cluster run."""
    records = result.records
    done = [r for r in records if r.completed]
    if not done:
        raise ValueError(
            f"result for policy {result.policy!r} has no completed jobs"
        )
    t0 = min(r.spec.arrival for r in records)
    t_end = max(r.finish for r in done)
    root = Span(
        name=f"cluster-run {result.policy}", cat="run", t0=t0,
        wall_s=t_end - t0,
        args={
            "policy": result.policy,
            "total_workers": result.total_workers,
            "n_jobs": len(records),
            "n_completed": len(done),
        },
    )
    root.children = [s for r in done if (s := _job_span(r)) is not None]
    return root


# ------------------------------------------------------------ conservation


def check_span_tiling(
    root: Span, *, rel_tol: float = 1e-6, abs_tol: float = 1e-9
) -> list[str]:
    """Verify the tiling discipline; return violations (empty = healthy).

    * every job span's children (wait + segments + gaps) sum to its
      turnaround;
    * every segment span with children has them summing to its wall;
    * children lie inside their parent's interval — except under a
      negative-wall sibling (pipelined overlap): phases that physically
      overlap are laid out sequentially, so their notional placement may
      poke past the parent while their *signed sum* stays exact.  The sum
      check never relaxes.

    The tolerance covers float associativity only — these are sums of
    exact event-time differences, not modeled quantities.
    """
    bad: list[str] = []

    def tol(x: float) -> float:
        return max(rel_tol * abs(x), abs_tol)

    for span in root.walk():
        if span.cat not in ("job", "segment") or not span.children:
            continue
        total = sum(c.wall_s for c in span.children)
        if abs(total - span.wall_s) > tol(span.wall_s):
            bad.append(
                f"{span.name}: children sum {total:.9f}s != "
                f"wall {span.wall_s:.9f}s"
            )
        overlapped = any(c.wall_s < 0 for c in span.children)
        for c in span.children:
            if not overlapped and c.wall_s >= 0 and (
                c.t0 < span.t0 - tol(span.wall_s)
                or c.t1 > span.t1 + tol(span.wall_s)
            ):
                bad.append(
                    f"{span.name}: child {c.name} "
                    f"[{c.t0:.6f}, {c.t1:.6f}] outside "
                    f"[{span.t0:.6f}, {span.t1:.6f}]"
                )
    return bad


# ------------------------------------------------------------ worker slots


def _hold_intervals(rec) -> list[tuple[float, float, int, str]]:
    """(t0, t1, workers, label) intervals during which ``rec`` holds
    worker slots — execution segments plus the overhead gaps that keep
    their grant (suspended gaps hold zero and are excluded)."""
    out = []
    if rec.segments:
        for ts, t1, w in rec.segments:
            out.append((ts, t1, int(w), "run"))
        for gt0, gt1, kind, held in getattr(rec, "gaps", None) or ():
            if held:
                out.append((gt0, gt1, int(held), kind))
    else:
        out.append((rec.start, rec.finish, int(rec.plan.workers), "run"))
    return sorted(out)


def _assign_slots(intervals, total_workers: int) -> list[list[int]]:
    """Greedy interval-partitioning onto worker slots.  ``intervals`` is
    [(t0, t1, w, job_id, label), ...]; returns the slot list per interval
    (parallel to the input).  The sim's conservation invariant guarantees
    at most ``total_workers`` are held at any instant, so this never
    runs out when intervals ending at t are released before those
    starting at t acquire."""
    order = sorted(
        range(len(intervals)), key=lambda i: (intervals[i][0], intervals[i][3])
    )
    free = list(range(total_workers))
    heapq.heapify(free)
    busy: list[tuple[float, int, list[int]]] = []  # (t1, tiebreak, slots)
    out: list[list[int]] = [[] for _ in intervals]
    for idx in order:
        t0, t1, w, job_id, _ = intervals[idx]
        while busy and busy[0][0] <= t0 + 1e-12:
            _, _, slots = heapq.heappop(busy)
            for s in slots:
                heapq.heappush(free, s)
        if w > len(free):
            raise AssertionError(
                f"slot assignment needs {w} slots for job {job_id} at "
                f"t={t0:.6f} but only {len(free)} are free — worker "
                "conservation violated upstream"
            )
        slots = [heapq.heappop(free) for _ in range(w)]
        out[idx] = slots
        heapq.heappush(busy, (t1, idx, slots))
    return out


# ------------------------------------------------------------ chrome export

_US = 1e6   # trace-event timestamps are microseconds


def _ev(name, ph, ts, pid, tid, **kw) -> dict:
    ev = {"name": name, "ph": ph, "ts": round(ts * _US, 3),
          "pid": pid, "tid": tid}
    ev.update(kw)
    return ev


def _emit_span(events, span: Span, pid: int, tid: int, cat: str) -> None:
    if span.wall_s < 0:
        # Negative exposure (pipeline overlap) cannot be a Chrome complete
        # event; export as an instant carrying the signed wall.
        events.append(_ev(
            span.name, "i", span.t0, pid, tid, s="t",
            args={**span.args, "wall_s": span.wall_s},
        ))
        return
    events.append(_ev(
        span.name, "X", span.t0, pid, tid,
        dur=round(span.wall_s * _US, 3), cat=cat, args=dict(span.args),
    ))


def _counter_events(result, holds) -> list[dict]:
    """Cumulative "C" events for queue depth / busy workers / suspended."""
    deltas: dict[str, list[tuple[float, float]]] = {
        "queue_depth": [], "busy_workers": [], "suspended_jobs": [],
    }
    for rec in result.records:
        deltas["queue_depth"].append((rec.spec.arrival, +1))
        if rec.start is not None:
            deltas["queue_depth"].append((rec.start, -1))
        elif not rec.admitted and getattr(rec, "reject_time", None) is not None:
            deltas["queue_depth"].append((rec.reject_time, -1))
        for gt0, gt1, kind, _held in getattr(rec, "gaps", None) or ():
            if kind == "suspended":
                deltas["suspended_jobs"].append((gt0, +1))
                deltas["suspended_jobs"].append((gt1, -1))
    for t0, t1, w, _job_id, _label in holds:
        deltas["busy_workers"].append((t0, +w))
        deltas["busy_workers"].append((t1, -w))
    events = []
    for name, dd in deltas.items():
        level = 0.0
        # Sort by time with decrements first so instantaneous handoffs
        # don't spike the counter above its true level.
        for t, d in sorted(dd, key=lambda x: (x[0], x[1])):
            level += d
            events.append(_ev(
                name, "C", t, 1, 0, args={"value": round(level, 6)}
            ))
    return events


def _control_events(control_log) -> list[dict]:
    """pid 3 "slo control": one instant event per audited control action
    (trip/clear/shed/suspend/resume) plus burn-rate counter tracks, so the
    overload-control storyline reads directly under the worker timeline."""
    events = [
        _ev("process_name", "M", 0, 3, 0, args={"name": "slo control"}),
        _ev("thread_name", "M", 0, 3, 0, args={"name": "decisions"}),
    ]
    for a in control_log:
        name = (a.action if a.job_id is None
                else f"{a.action} job {a.job_id}")
        events.append(_ev(
            name, "i", a.t, 3, 0, s="t",
            args={
                "action": a.action, "job_id": a.job_id,
                "reason": a.reason,
                "burn_fast": round(a.burn_fast, 6),
                "burn_slow": round(a.burn_slow, 6),
            },
        ))
        for track, value in (
            ("slo_burn_fast", a.burn_fast), ("slo_burn_slow", a.burn_slow),
        ):
            events.append(_ev(
                track, "C", a.t, 3, 0, args={"value": round(value, 6)}
            ))
    return events


def to_chrome_trace(result, *, counters: bool = True, control_log=None,
                    resources=None) -> dict:
    """Export one run as Chrome trace-event JSON (Perfetto-loadable).

    ``control_log`` (a list of :class:`~repro.obs.controller.
    ControlAction`) adds the pid 3 "slo control" tracks.  ``resources``
    (a :class:`~repro.obs.resources.ResourceTimeline`, built from the
    result when omitted) adds the pid 4 "cluster resources" counter
    tracks — fabric bytes/s vs capacity and busy CPU — whenever the
    run's traces carry resource counters."""
    root = build_span_tree(result)
    events: list[dict] = [
        _ev("process_name", "M", 0, 1, 0,
            args={"name": "worker slots"}),
        _ev("process_name", "M", 0, 2, 0, args={"name": "jobs"}),
    ]
    for slot in range(result.total_workers):
        events.append(_ev(
            "thread_name", "M", 0, 1, slot,
            args={"name": f"worker {slot}"},
        ))

    # -- pid 1: worker-slot tracks ------------------------------------
    done = [r for r in result.records if r.completed]
    flat: list[tuple[float, float, int, int, str]] = []
    per_rec: dict[int, list[int]] = {}   # job_id -> indices into flat
    for rec in done:
        for t0, t1, w, label in _hold_intervals(rec):
            per_rec.setdefault(rec.spec.job_id, []).append(len(flat))
            flat.append((t0, t1, w, rec.spec.job_id, label))
    slot_lists = _assign_slots(flat, result.total_workers)
    job_spans = {s.args["job_id"]: s for s in root.children}
    for idx, (t0, t1, w, job_id, label) in enumerate(flat):
        name = (f"job {job_id}" if label == "run"
                else f"job {job_id} [{label}]")
        for slot in slot_lists[idx]:
            events.append(_ev(
                name, "X", t0, 1, slot, dur=round((t1 - t0) * _US, 3),
                cat="slot" if label == "run" else "overhead",
                args={"job_id": job_id, "workers": w, "kind": label},
            ))
        if label == "run":
            # Nest wave/phase children on the interval's first slot.
            jspan = job_spans.get(job_id)
            if jspan is not None and slot_lists[idx]:
                tid = slot_lists[idx][0]
                for seg in jspan.children:
                    if seg.cat != "segment" or not (
                        t0 - 1e-12 <= seg.t0 and seg.t1 <= t1 + 1e-12
                    ):
                        continue
                    for child in seg.children:
                        _emit_span(events, child, 1, tid, child.cat)

    # -- pid 2: per-job causal tracks ---------------------------------
    for jspan in root.children:
        job_id = jspan.args["job_id"]
        events.append(_ev(
            "thread_name", "M", 0, 2, job_id,
            args={"name": f"job {job_id}"},
        ))
        _emit_span(events, jspan, 2, job_id, "job")
        for child in jspan.children:
            _emit_span(events, child, 2, job_id, child.cat)
            for grand in child.children:
                _emit_span(events, grand, 2, job_id, grand.cat)
    for rec in result.records:
        if rec.admitted or getattr(rec, "reject_time", None) is None:
            continue
        events.append(_ev(
            f"reject job {rec.spec.job_id}", "i", rec.reject_time, 2,
            rec.spec.job_id, s="t",
            args={"reason": rec.reject_reason},
        ))

    if counters:
        events += _counter_events(result, flat)
        if resources is None:
            from repro.obs.resources import ResourceTimeline

            resources = ResourceTimeline.from_result(result)
        if resources.has_data:
            events += resources.counter_events()
    if control_log:
        events += _control_events(control_log)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "policy": result.policy,
            "total_workers": result.total_workers,
        },
    }


def validate_chrome_trace(doc) -> list[str]:
    """Well-formedness check on an exported trace; [] = valid."""
    bad: list[str] = []
    if not isinstance(doc, dict) or not isinstance(
        doc.get("traceEvents"), list
    ):
        return ["top level must be a dict with a traceEvents list"]
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"event {i}"
        if not isinstance(ev, dict):
            bad.append(f"{where}: not a dict")
            continue
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                bad.append(f"{where}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in ("X", "C", "M", "i"):
            bad.append(f"{where}: unknown phase {ph!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                bad.append(f"{where}: X event needs dur >= 0, got {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not all(
                isinstance(v, (int, float)) for v in args.values()
            ):
                bad.append(f"{where}: C event needs numeric args")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            bad.append(f"{where}: non-numeric ts {ts!r}")
    return bad


# ------------------------------------------------------------- text render

_SYMBOLS = "0123456789abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"


def render_slots(result, width: int = 64) -> str:
    """Perfetto-screenshot-equivalent text view: one row per worker slot,
    one column per time bucket; job symbols fill execution intervals,
    ``~`` marks regrant/restore overhead, ``.`` is idle."""
    done = [r for r in result.records if r.completed]
    if not done:
        return "(no completed jobs)"
    t0 = min(r.spec.arrival for r in result.records)
    t_end = max(r.finish for r in done)
    span = max(t_end - t0, 1e-9)
    flat: list[tuple[float, float, int, int, str]] = []
    for rec in done:
        for a, b, w, label in _hold_intervals(rec):
            flat.append((a, b, w, rec.spec.job_id, label))
    slot_lists = _assign_slots(flat, result.total_workers)
    grid = [["."] * width for _ in range(result.total_workers)]
    symbol = {
        r.spec.job_id: _SYMBOLS[i % len(_SYMBOLS)]
        for i, r in enumerate(sorted(done, key=lambda r: r.spec.job_id))
    }
    for idx, (a, b, _w, job_id, label) in enumerate(flat):
        c0 = int((a - t0) / span * width)
        c1 = max(c0 + 1, int((b - t0) / span * width))
        ch = symbol[job_id] if label == "run" else "~"
        for slot in slot_lists[idx]:
            for c in range(c0, min(c1, width)):
                grid[slot][c] = ch
    lines = [
        f"t=[{t0:.2f}s, {t_end:.2f}s]  one column ≈ {span / width:.3f}s  "
        "(~ = regrant/restore overhead, . = idle)"
    ]
    lines += [
        f"slot {slot:>2} |{''.join(row)}|" for slot, row in enumerate(grid)
    ]
    legend = "  ".join(
        f"{symbol[j]}=job{j}" for j in sorted(symbol)[:16]
    )
    lines.append(f"jobs: {legend}" + (" …" if len(symbol) > 16 else ""))
    return "\n".join(lines)


# --------------------------------------------------------------- recorder


class SpanRecorder:
    """Assembles and retains span trees for completed cluster runs.

    The recorder is pull-based: nothing registers callbacks into the sims
    (hot paths stay untouched); call :meth:`record` with a finished
    :class:`TraceResult` and the causal tree is built from the records.

    ``max_jobs`` bounds retention for service-mode runs whose streams are
    open-ended: only the *last* ``max_jobs`` completed jobs (by finish
    time) enter the tree — a ring over the completion stream — and
    everything older is dropped on arrival, tallied in
    ``n_dropped_jobs`` / ``n_dropped_spans`` so truncation is visible, not
    silent.  Tiling (:meth:`check`) then holds on the retained window.
    ``record(..., control_log=…)`` attaches an overload-control audit log
    that :meth:`chrome` renders as the "slo control" tracks.
    """

    def __init__(self, max_jobs: int | None = None):
        if max_jobs is not None and max_jobs < 1:
            raise ValueError(f"max_jobs must be >= 1, got {max_jobs}")
        self.max_jobs = max_jobs
        self.n_dropped_jobs = 0
        self.n_dropped_spans = 0
        self._runs: list[tuple[object, Span, object]] = []

    def __len__(self) -> int:
        return len(self._runs)

    def _prune(self, result):
        """Retain the last ``max_jobs`` completed jobs (plus any
        not-completed records inside the retained arrival window)."""
        done = sorted(
            (r for r in result.records if r.completed),
            key=lambda r: (r.finish, r.spec.job_id),
        )
        kept = {r.spec.job_id for r in done[-self.max_jobs:]}
        if len(kept) == len(result.records):
            return result
        cutoff = min(
            (r.spec.arrival for r in done[-self.max_jobs:]),
            default=float("-inf"),
        )
        records = []
        for r in result.records:
            if (r.spec.job_id in kept
                    or (not r.completed and r.spec.arrival >= cutoff)):
                records.append(r)
                continue
            self.n_dropped_jobs += 1
            if r.completed:
                span = _job_span(r)
                self.n_dropped_spans += sum(1 for _ in span.walk())
        if len(records) == len(result.records):
            return result
        return dataclasses.replace(result, records=records)

    def record(self, result, control_log=None) -> Span:
        if self.max_jobs is not None:
            result = self._prune(result)
        root = build_span_tree(result)
        self._runs.append((result, root, control_log))
        return root

    @property
    def roots(self) -> list[Span]:
        return [root for _, root, _ in self._runs]

    def check(self, **tol) -> list[str]:
        """Tiling violations across every recorded run ([] = healthy)."""
        bad: list[str] = []
        for result, root, _ in self._runs:
            bad += [
                f"{result.policy}: {v}" for v in check_span_tiling(root, **tol)
            ]
        return bad

    def chrome(self, index: int = -1, **kw) -> dict:
        result, _, control_log = self._runs[index]
        kw.setdefault("control_log", control_log)
        return to_chrome_trace(result, **kw)

    def validate(self, index: int = -1, **kw) -> list[str]:
        """Well-formedness issues of the exported doc ([] = valid)."""
        return validate_chrome_trace(self.chrome(index, **kw))

    def save_chrome(self, path: str, index: int = -1, **kw) -> dict:
        doc = self.chrome(index, **kw)
        with open(path, "w") as fp:
            json.dump(doc, fp)
        return doc
