"""Prediction ledger + drift alarms: watch the models the schedulers trust.

Every dispatch carries a prediction (``Plan.predicted_time``) and every
completion realizes a wall clock — one free accuracy experiment per job,
per (app, backend, depth) category.  :class:`PredictionLedger` records the
pairs and maintains two EWMAs per category:

* the **absolute relative error** ``|pred - real| / real`` — when it
  crosses ``threshold`` (after ``min_samples`` observations) the category
  has drifted and a :class:`DriftAlarm` fires;
* the **realized/predicted ratio** — its value at alarm time is the
  ``scale_hint``: for a multiplicative platform shift (the canonical
  drift: same machine, different load factor) rescaling the category's
  model by this hint is already the maximum-likelihood correction, which
  is what :meth:`repro.cluster.online.OnlineRefiner.refit_category` applies
  when too few post-shift rows exist for a full refit.

After an alarm both EWMAs reset (re-arm), so a persistent shift raises a
short *sequence* of alarms whose hints converge multiplicatively on the
true factor instead of one alarm followed by silence — and a recovered
category stops alarming entirely.

Samples whose ratio falls outside ``ratio_clip`` never touch the EWMAs:
drift worth auto-correcting is multiplicative and modest (a platform
getting 1.6x slower), not three orders of magnitude.  A 400x ratio means
the *prediction* was pathological — typically the polynomial dipped <= 0
at an argmin-chosen corner and the policy clamped it to its floor — and a
clamped prediction carries no scale information at all.  Such samples are
tallied (``n_outliers``) and kept in the entry history, but letting them
into the hint would command a 400x rescale and the correction loop would
oscillate instead of converging.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = ["DriftAlarm", "LEDGER_SCHEMA_VERSION", "PredictionLedger"]

#: Serialization schema for :meth:`PredictionLedger.to_json` (same
#: convention as ``telemetry.TRACE_SCHEMA_VERSION``): bump on breaking
#: layout changes so old readers fail loudly instead of misparsing.
LEDGER_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class DriftAlarm:
    """One drift detection: a category's EWMA error crossed threshold."""

    t: float                  #: sim time of the triggering completion
    app: str
    category: str             #: policy category key ("backend[@dD]")
    ewma_abs_rel_err: float
    scale_hint: float         #: EWMA of realized/predicted at alarm time
    n: int                    #: observations since the last (re-)arm


@dataclasses.dataclass
class _CatState:
    ewma_err: float | None = None
    ewma_ratio: float | None = None
    n: int = 0


class PredictionLedger:
    """Per-(app, category) record of predicted vs realized times."""

    def __init__(
        self,
        *,
        alpha: float = 0.4,
        threshold: float = 0.25,
        min_samples: int = 3,
        keep_last: int = 64,
        ratio_clip: tuple[float, float] = (0.25, 4.0),
    ):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if threshold <= 0.0:
            raise ValueError("threshold must be > 0")
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        lo, hi = float(ratio_clip[0]), float(ratio_clip[1])
        if not 0.0 < lo < 1.0 < hi:
            raise ValueError(
                f"ratio_clip must straddle 1.0 with 0 < lo < 1 < hi, "
                f"got {ratio_clip!r}"
            )
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.keep_last = int(keep_last)
        self.ratio_clip = (lo, hi)
        self.n_outliers = 0
        self._state: dict[tuple[str, str], _CatState] = {}
        #: bounded (t, predicted, realized) history per category.
        self._entries: dict[tuple[str, str], list[tuple]] = {}
        self.alarms: list[DriftAlarm] = []
        self.n_records = 0

    def record(
        self,
        app: str,
        category: str,
        predicted: float,
        realized: float,
        t: float = 0.0,
    ) -> DriftAlarm | None:
        """Record one (prediction, realization) pair; return the alarm if
        this observation pushed the category over threshold."""
        predicted = float(predicted)
        realized = float(realized)
        err = abs(predicted - realized) / max(abs(realized), 1e-12)
        ratio = realized / max(predicted, 1e-12)
        key = (app, category)
        entries = self._entries.setdefault(key, [])
        entries.append((float(t), predicted, realized))
        if len(entries) > self.keep_last:
            del entries[: len(entries) - self.keep_last]
        self.n_records += 1
        lo, hi = self.ratio_clip
        if not lo <= ratio <= hi:
            # Untrusted sample (see module docstring): recorded above,
            # but it must not steer the alarm or the scale hint.
            self.n_outliers += 1
            return None
        st = self._state.setdefault(key, _CatState())
        a = self.alpha
        st.ewma_err = (
            err if st.ewma_err is None else a * err + (1 - a) * st.ewma_err
        )
        st.ewma_ratio = (
            ratio if st.ewma_ratio is None
            else a * ratio + (1 - a) * st.ewma_ratio
        )
        st.n += 1
        if st.n >= self.min_samples and st.ewma_err > self.threshold:
            alarm = DriftAlarm(
                t=float(t), app=app, category=category,
                ewma_abs_rel_err=st.ewma_err, scale_hint=st.ewma_ratio,
                n=st.n,
            )
            self.alarms.append(alarm)
            # Re-arm: the next alarm's hint is estimated purely from
            # post-correction observations.
            self._state[key] = _CatState()
            return alarm
        return None

    # ---- queries ---------------------------------------------------------

    def ewma_error(self, app: str, category: str) -> float | None:
        st = self._state.get((app, category))
        return st.ewma_err if st else None

    def categories(self) -> list[tuple[str, str]]:
        return sorted(self._entries)

    def category_mae_pct(self, app: str, category: str) -> float | None:
        """Plain MAE% over the retained history (reporting, not alarming)."""
        entries = self._entries.get((app, category))
        if not entries:
            return None
        errs = [
            abs(p - r) / max(abs(r), 1e-12) * 100.0 for _, p, r in entries
        ]
        return sum(errs) / len(errs)

    # ---- serialization ---------------------------------------------------

    def state_dict(self) -> dict:
        """Full resumable state (unlike :meth:`to_dict`, which is a report
        summary).  Keys are ``"app/category"`` strings; the layout is
        versioned by the embedded ``schema`` field."""
        return {
            "schema": LEDGER_SCHEMA_VERSION,
            "config": {
                "alpha": self.alpha,
                "threshold": self.threshold,
                "min_samples": self.min_samples,
                "keep_last": self.keep_last,
                "ratio_clip": list(self.ratio_clip),
            },
            "n_records": self.n_records,
            "n_outliers": self.n_outliers,
            "state": {
                f"{app}/{cat}": dataclasses.asdict(st)
                for (app, cat), st in sorted(self._state.items())
            },
            "entries": {
                f"{app}/{cat}": [list(e) for e in entries]
                for (app, cat), entries in sorted(self._entries.items())
            },
            "alarms": [dataclasses.asdict(a) for a in self.alarms],
        }

    def to_json(self, **dumps_kwargs) -> str:
        dumps_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.state_dict(), **dumps_kwargs)

    @staticmethod
    def from_state_dict(d: dict) -> "PredictionLedger":
        if not isinstance(d, dict):
            raise ValueError(
                f"ledger state must be a dict, got {type(d).__name__}"
            )
        # Pre-versioning dumps carried no schema field: read them as v1.
        version = int(d.get("schema", 1))
        if not 1 <= version <= LEDGER_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported ledger schema version {version}; this build "
                f"reads versions 1..{LEDGER_SCHEMA_VERSION}"
            )
        cfg = d.get("config", {})
        led = PredictionLedger(
            alpha=cfg.get("alpha", 0.4),
            threshold=cfg.get("threshold", 0.25),
            min_samples=cfg.get("min_samples", 3),
            keep_last=cfg.get("keep_last", 64),
            ratio_clip=tuple(cfg.get("ratio_clip", (0.25, 4.0))),
        )
        led.n_records = int(d.get("n_records", 0))
        led.n_outliers = int(d.get("n_outliers", 0))
        for key, st in d.get("state", {}).items():
            app, _, cat = key.partition("/")
            led._state[(app, cat)] = _CatState(
                ewma_err=st.get("ewma_err"),
                ewma_ratio=st.get("ewma_ratio"),
                n=int(st.get("n", 0)),
            )
        for key, entries in d.get("entries", {}).items():
            app, _, cat = key.partition("/")
            led._entries[(app, cat)] = [
                (float(t), float(p), float(r)) for t, p, r in entries
            ]
        led.alarms = [DriftAlarm(**a) for a in d.get("alarms", [])]
        return led

    @staticmethod
    def from_json(s: str) -> "PredictionLedger":
        return PredictionLedger.from_state_dict(json.loads(s))

    def to_dict(self) -> dict:
        return {
            "n_records": self.n_records,
            "n_outliers": self.n_outliers,
            "threshold": self.threshold,
            "alpha": self.alpha,
            "alarms": [dataclasses.asdict(a) for a in self.alarms],
            "categories": {
                f"{app}/{cat}": {
                    "n_entries": len(self._entries[(app, cat)]),
                    "ewma_abs_rel_err": self.ewma_error(app, cat),
                    "mae_pct": self.category_mae_pct(app, cat),
                }
                for app, cat in self.categories()
            },
        }
