"""Alarm-driven overload control: burn-rate alarms become cluster actions.

:class:`~repro.obs.slo.SLOMonitor` says *when* the service is burning its
error budget; this module decides *what to do about it*.  The loop closes
through the existing policy interface — :class:`ControlledPolicy` wraps
any scheduling policy and consults an admission controller before every
``select``/``idle`` call, so no simulator change is needed:

* **shed** — while the alarm is tripped, queued jobs are rejected from
  the *head* of the queue down to ``queue_floor``.  Drop-head, not
  drop-tail: under overload the oldest queued job carries the deepest
  sunk delay and is already doomed to blow the target, so shedding it
  (rather than a fresh arrival that can still finish good) converts
  doomed waits into rejections instead of bad completions.
* **suspend** — with an elastic cluster bound, up to ``max_suspended``
  running best-effort jobs are throttled through the suspend-to-disk
  valve (``Regrant(job, 0)``), freeing whole grants for the backlog.
* **resume** — once the alarm clears (budget recovering), or whenever the
  queue is empty (drain safety: a suspended job must never outlive the
  run), suspended jobs are regranted oldest-first from the free pool.

Every decision lands in an auditable log of :class:`ControlAction`\\ s —
trips, clears, and each shed/suspend/resume with the burn rates that
justified it — which ``to_chrome_trace(control_log=…)`` renders as
instant events plus burn-rate counter tracks.

:class:`StaticAdmission` is the experimental control: the same wrapper
driving a fixed queue cap with no alarm, the strawman the service
benchmark (``benchmarks/service_bench.py``) requires burn-rate control
to strictly beat on both p99 turnaround and goodput.
"""

from __future__ import annotations

import dataclasses

from repro.cluster.cluster import Reject
from repro.obs.slo import SLOMonitor

__all__ = [
    "ControlAction",
    "ControlledPolicy",
    "OverloadController",
    "StaticAdmission",
]


@dataclasses.dataclass(frozen=True)
class ControlAction:
    """One audited control decision (or alarm transition)."""

    t: float
    action: str            #: "trip" | "clear" | "shed" | "suspend" | "resume"
    job_id: int | None
    reason: str
    burn_fast: float
    burn_slow: float


class OverloadController:
    """Burn-rate-driven admission/suspend controller.

    ``decide`` is consulted before the wrapped policy on every scheduling
    event and returns at most one cluster action (``Reject`` /
    ``Regrant`` / ``None``); the simulator's select loop re-asks until it
    returns ``None``, so a deep backlog sheds one job per iteration, each
    with its own audit entry.
    """

    name = "burn-control"

    def __init__(
        self,
        monitor: SLOMonitor,
        *,
        queue_floor: int = 4,
        max_suspended: int = 2,
        suspend: bool = True,
    ):
        if queue_floor < 0 or max_suspended < 0:
            raise ValueError("queue_floor and max_suspended must be >= 0")
        self.monitor = monitor
        self.queue_floor = int(queue_floor)
        self.max_suspended = int(max_suspended)
        self.suspend = bool(suspend)
        self.log: list[ControlAction] = []
        self._cluster = None

    # ------------------------------------------------------------- wiring

    def bind(self, cluster) -> None:
        """Learn the cluster; the suspend valve needs elastic support."""
        self._cluster = (
            cluster if getattr(cluster, "supports_elastic", False) else None
        )

    def observe(self, record) -> None:
        if record.finish is None:
            return
        self.monitor.observe(
            record.finish, record.turnaround, record.met_deadline
        )

    # ------------------------------------------------------------ decision

    def _log(self, t, action, job_id, reason, fast, slow) -> None:
        self.log.append(ControlAction(
            t=float(t), action=action, job_id=job_id, reason=reason,
            burn_fast=fast, burn_slow=slow,
        ))

    def decide(self, queue, free_workers: int, now: float):
        """One control decision for the current scheduling event."""
        alarm = self.monitor.update(now)
        fast, slow = self.monitor.burn_rates(now)
        if alarm is not None:
            self._log(
                now, alarm.event, None,
                f"burn fast={alarm.burn_fast:.2f} "
                f"slow={alarm.burn_slow:.2f} vs "
                f"trip>{self.monitor.trip_burn:g} "
                f"clear<{self.monitor.clear_burn:g}",
                alarm.burn_fast, alarm.burn_slow,
            )
        if self.monitor.tripped and queue:
            if len(queue) > self.queue_floor:
                # Drop-head: the oldest queued job has the deepest sunk
                # delay and is already doomed to blow the target, while a
                # fresh arrival behind a short queue can still finish
                # good — shedding it would burn budget for nothing.
                victim = queue[0]
                self._log(
                    now, "shed", victim.job_id,
                    f"queue {len(queue)} > floor {self.queue_floor} "
                    "while burn alarm tripped",
                    fast, slow,
                )
                return Reject(victim, "shed by burn-rate overload control")
            action = self._try_suspend(now, fast, slow)
            if action is not None:
                return action
        if not self.monitor.tripped:
            # Budget recovered: pull suspended jobs back.
            return self._try_resume(now, free_workers, fast, slow)
        if not queue and self._cluster is not None and (
            free_workers >= self._cluster.total_workers
        ):
            # Drain safety while still tripped: a fully idle cluster has
            # nothing left but its suspended jobs, so resume them even
            # under alarm — both to avoid stranding them at stream end
            # and because holding capacity idle sheds nothing.  (Merely
            # *momentary* empty queues mid-overload don't qualify; they
            # would churn the valve.)
            return self._try_resume(now, free_workers, fast, slow)
        return None

    def _try_suspend(self, now, fast, slow):
        if not self.suspend or self._cluster is None:
            return None
        if fast <= self.monitor.trip_burn:
            # The valve is emergency pressure relief: open it only under
            # *active* fast burn, not merely while the alarm is latched —
            # otherwise the long tripped tail after an overload cycles
            # jobs through suspend/resume for nothing.
            return None
        from repro.elastic.sim import Regrant

        running = self._cluster.running_jobs(now)
        n_susp = len(self._cluster.suspended_jobs()) + sum(
            1 for r in running if r.pending_workers == 0
        )
        if n_susp >= self.max_suspended:
            return None
        victims = [
            r for r in running
            if r.spec.deadline is None          # best-effort only
            and r.pending_workers is None       # no regrant in flight
            and r.steps_remaining >= 2          # suspend needs a boundary
        ]
        if not victims:
            return None
        victim = max(victims, key=lambda r: (r.steps_remaining, r.job_id))
        self._log(
            now, "suspend", victim.job_id,
            f"valve open ({n_susp}/{self.max_suspended} suspended), "
            f"frees {victim.workers} workers",
            fast, slow,
        )
        return Regrant(
            victim.job_id, 0, reason="overload: suspend-to-disk valve"
        )

    def _try_resume(self, now, free_workers, fast, slow):
        if self._cluster is None or free_workers < 1:
            return None
        from repro.elastic.sim import Regrant

        suspended = self._cluster.suspended_jobs(now)
        if not suspended:
            return None
        job = suspended[0]                      # oldest first
        workers = min(free_workers, job.workers_before)
        self._log(
            now, "resume", job.job_id,
            f"restoring {workers}/{job.workers_before} workers "
            f"(suspended at t={job.suspended_at:.2f})",
            fast, slow,
        )
        return Regrant(job.job_id, workers, reason="budget recovered: resume")


class StaticAdmission:
    """The no-telemetry baseline: reject the newest arrival whenever the
    queue exceeds a fixed cap, always, overloaded or not.  Same decision
    interface and audit log as :class:`OverloadController` so the two sit
    symmetrically in benchmarks."""

    name = "static-admission"

    def __init__(self, queue_cap: int = 8):
        if queue_cap < 0:
            raise ValueError("queue_cap must be >= 0")
        self.queue_cap = int(queue_cap)
        self.log: list[ControlAction] = []

    def bind(self, cluster) -> None:
        del cluster

    def observe(self, record) -> None:
        del record

    def decide(self, queue, free_workers: int, now: float):
        del free_workers
        if len(queue) > self.queue_cap:
            victim = queue[-1]
            self.log.append(ControlAction(
                t=float(now), action="shed", job_id=victim.job_id,
                reason=f"queue {len(queue)} > static cap {self.queue_cap}",
                burn_fast=0.0, burn_slow=0.0,
            ))
            return Reject(victim, "shed by static admission cap")
        return None


class ControlledPolicy:
    """Wrap any scheduling policy with an admission controller.

    The controller speaks first at every ``select``/``idle`` event; only
    when it has nothing to say does the inner policy see the queue.
    Completions flow to both (controller first, so the burn windows are
    current before the inner policy's online refinement runs).
    """

    def __init__(self, inner, controller):
        self.inner = inner
        self.controller = controller
        self.name = f"{inner.name}+{controller.name}"

    def prepare(self, cluster, apps) -> None:
        self.controller.bind(cluster)
        self.inner.prepare(cluster, apps)

    def select(self, queue, free_workers: int, now: float):
        action = self.controller.decide(queue, free_workers, now)
        if action is not None:
            return action
        return self.inner.select(queue, free_workers, now)

    def idle(self, free_workers: int, now: float):
        action = self.controller.decide((), free_workers, now)
        if action is None or isinstance(action, Reject):
            inner_idle = getattr(self.inner, "idle", None)
            return None if inner_idle is None else inner_idle(
                free_workers, now
            )
        return action

    def observe(self, record) -> None:
        self.controller.observe(record)
        self.inner.observe(record)

    def observe_overhead(self, save_s: float, restore_s: float) -> None:
        hook = getattr(self.inner, "observe_overhead", None)
        if hook is not None:
            hook(save_s, restore_s)
