"""Int8 gradient compression with error feedback (distributed-optimization
trick for DP all-reduce traffic).

Quantize per-tensor to int8 with a shared fp32 scale before the data-parallel
reduction, keep the quantization residual locally, and add it back into the
next step's gradient (error feedback makes the compression unbiased over
time).  At 4x fewer gradient bytes the DP all-reduce term of the roofline
drops ~4x — used as an opt-in in ``train/step.py`` and exercised in §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g, bits: int = 8):
    """Per-tensor symmetric quantization. Returns (q_int8, scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compress_tree(grads, error_state):
    """Apply error feedback + quantize every leaf.

    Returns (quantized tree of (q, scale), new_error_state).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32)
        deq = dequantize(q, scale)
        return (q, scale), g32 - deq

    out = jax.tree.map(one, grads, error_state)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    qtree = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    etree = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return qtree, etree


def decompress_tree(qtree):
    is_q = lambda x: isinstance(x, tuple) and len(x) == 2
    return jax.tree.map(
        lambda qs: dequantize(qs[0], qs[1]), qtree, is_leaf=is_q
    )


def psum_compressed(grads, error_state, axis_name):
    """shard_map helper: quantize -> psum int32 -> dequantize.

    int8 sums can overflow int8, so the reduction runs in int32 while the
    wire format (what the collective moves) is the int8 payload in practice;
    the roofline credit is taken on payload bytes.  Scales are all-reduced
    (max) so dequantization is consistent.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32)
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq_local = dequantize(q, scale)
        return total.astype(jnp.float32) * scale, g32 - deq_local

    out = jax.tree.map(one, grads, error_state)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    g = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    e = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return g, e
