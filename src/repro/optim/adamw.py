"""AdamW with dtype-configurable state (ZeRO-friendly) + gradient clipping.

States (m, v, and optional fp32 master copy) inherit the parameter sharding
specs, so under FSDP the optimizer is ZeRO-3-sharded for free.  ``state_dtype
= bfloat16`` halves optimizer HBM — the lever that fits arctic-480b on a
16 GB/chip pod (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # bfloat16 halves optimizer memory
    master_fp32: bool = False      # keep fp32 master params (bf16 models)


def init_state(cfg: AdamWConfig, params) -> dict:
    sdt = jnp.dtype(cfg.state_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.reduce(
        lambda acc, g: acc + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree,
        jnp.float32(0.0),
    )
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale), grads
        )
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    sdt = jnp.dtype(cfg.state_dtype)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, master=None):
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        base = master if master is not None else p
        base32 = base.astype(jnp.float32)
        new32 = base32 - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base32
        )
        return new32, m32.astype(sdt), v32.astype(sdt)

    if cfg.master_fp32:
        out = jax.tree.map(
            upd, params, grads, state["m"], state["v"], state["master"]
        )
        new32 = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda p, n: n.astype(p.dtype), params, new32
        )
        new_state = {"step": step, "m": new_m, "v": new_v, "master": new32}
    else:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(
            lambda p, o: o[0].astype(p.dtype), params, out,
            is_leaf=lambda x: isinstance(x, tuple),
        )
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"step": step, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_params, new_state, metrics


def cosine_schedule(step, *, base_lr=1.0, warmup=100, total=10000,
                    min_frac=0.1):
    """LR scale factor (multiply by cfg.lr)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
