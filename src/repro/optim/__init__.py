from repro.optim.adamw import (
    AdamWConfig,
    apply_updates,
    cosine_schedule,
    global_norm,
    init_state,
)
from repro.optim import grad_compress

__all__ = [
    "AdamWConfig",
    "apply_updates",
    "cosine_schedule",
    "global_norm",
    "init_state",
    "grad_compress",
]
