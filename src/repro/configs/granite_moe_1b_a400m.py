"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per-expert) vocab=49155,
MoE 32 experts top-8 on every layer.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    ffn_type="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=32,
        top_k=8,
        d_ff_expert=512,
        every_n_layers=1,
    ),
    param_dtype="bfloat16",
)
