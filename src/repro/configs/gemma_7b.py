"""gemma-7b [arXiv:2403.08295].

28L d_model=3072 16H (kv=16, i.e. MHA) d_ff=24576 GeGLU head_dim=256
vocab=256000 (tied embeddings) — the 256k vocab makes the unembed/loss the
memory hot spot (see logits-chunked loss in §Perf).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    ffn_type="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
)
