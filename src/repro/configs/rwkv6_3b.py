"""rwkv6-3b (Finch) [arXiv:2404.05892].

32L d_model=2560, attention-free (WKV6 time-mix with data-dependent decay +
channel-mix), d_ff=8960, vocab=65536, head_size=64 (40 heads).
Runs the long_500k cell: decode state is O(1) in context length.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_size=64,
    param_dtype="bfloat16",
)
