"""Architecture registry: ``get_config(arch)``, smoke variants, input specs."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    applicable_shapes,
)

from repro.configs import (  # noqa: E402
    arctic_480b,
    gemma_7b,
    granite_moe_1b_a400m,
    hubert_xlarge,
    internvl2_26b,
    jamba_v0_1_52b,
    llama3_8b,
    qwen3_0_6b,
    qwen3_32b,
    rwkv6_3b,
)

_REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_moe_1b_a400m,
        arctic_480b,
        internvl2_26b,
        gemma_7b,
        qwen3_0_6b,
        qwen3_32b,
        llama3_8b,
        rwkv6_3b,
        hubert_xlarge,
        jamba_v0_1_52b,
    )
}

ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return _REGISTRY[arch]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (real forward/step)."""
    cfg = get_config(arch)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            n_experts=4,
            top_k=min(moe.top_k, 2),
            d_ff_expert=64,
            d_ff_dense=64 if moe.dense_residual else 0,
            n_groups=1,
            # headroom so tiny smoke batches never drop tokens (capacity
            # dropping at the production factor is exercised separately)
            capacity_factor=8.0,
        )
    return dataclasses.replace(
        cfg,
        n_layers=2 * cfg.pattern_period,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=503 if cfg.family == "audio" else 512,
        moe=moe,
        embed_in_dim=24 if cfg.input_kind == "embeddings" or cfg.family == "vlm" else 0,
        n_patches=4 if cfg.family == "vlm" else 0,
        rwkv_head_size=16,
        mamba_d_state=4,
        mamba_d_conv=4,
        param_dtype="float32",
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation) + concrete batches
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct pytree for every model input of one shape cell.

    train/prefill: the full (B, S) batch.  decode: (B, 1) new tokens (the
    KV cache / SSM state is part of the step signature, built separately).
    """
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    f32 = jnp.float32
    i32 = jnp.int32
    if cfg.family == "vlm":
        n_txt = max(S - cfg.n_patches, 1) if shape.kind != "decode" else 1
        spec = {
            "tokens": jax.ShapeDtypeStruct((B, n_txt), i32),
            "patches": jax.ShapeDtypeStruct(
                (B, cfg.n_patches if shape.kind != "decode" else 0,
                 cfg.embed_in_dim),
                f32,
            ),
        }
        if shape.kind == "decode":
            # decoding continues the text stream; no new patches
            spec = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                    "patches": jax.ShapeDtypeStruct((B, 0, cfg.embed_in_dim), f32)}
        return spec
    if cfg.input_kind == "embeddings":
        spec = {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.embed_in_dim), f32),
        }
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return spec
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Small real batch matching input_specs (for smoke tests/examples)."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size
            out[name] = jax.random.randint(k, s.shape, 0, hi, dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, dtype=s.dtype)
    return out


__all__ = [
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "applicable_shapes",
    "get_config",
    "smoke_config",
    "input_specs",
    "concrete_batch",
]
