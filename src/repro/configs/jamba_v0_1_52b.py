"""jamba-v0.1-52b [arXiv:2403.19887] — Mamba+attention 1:7 hybrid with MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts
top-2 on every other layer.  Period-8 block pattern with one attention
layer per period (position 4, per the paper's l=8, a=1 layout).
Runs the long_500k cell (only 4 of 32 layers carry a KV cache).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    ffn_type="swiglu",
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba",
        "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        d_ff_expert=14336,
        every_n_layers=2,
        offset=1,
        n_groups=16,
    ),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    param_dtype="bfloat16",
)
