"""arctic-480b [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a parallel dense-residual FFN per layer
(Arctic's dense-MoE hybrid design).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    ffn_type="swiglu",
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        every_n_layers=1,
        dense_residual=True,
        d_ff_dense=4864,
        n_groups=16,
    ),
    param_dtype="bfloat16",
)
