"""Model / run configuration dataclasses shared across the framework."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every_n_layers: int = 1        # MoE on layers where (idx % every_n) == offset
    offset: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    d_ff_dense: int = 0            # width of the parallel dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    n_groups: int = 1              # dispatch groups (== expected data shards)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    ffn_type: str = "swiglu"       # swiglu | geglu | relu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    # Layer pattern: period-P list of block kinds ("attn" | "mamba" | "rwkv"),
    # tiled to n_layers.  Homogeneous archs use ("attn",) etc.
    block_pattern: tuple[str, ...] = ("attn",)
    # Input modality: "tokens" (int ids) or "embeddings" (stub frontend
    # supplies pre-computed frame/patch embeddings of width embed_in_dim).
    input_kind: str = "tokens"
    embed_in_dim: int = 0
    # VLM: number of image patch embeddings prepended to the text sequence.
    n_patches: int = 0
    # SSM geometry.
    rwkv_head_size: int = 64
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    norm_eps: float = 1e-6
    # Numerics.
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to a multiple of 256 so the vocab
        dimension shards evenly on any mesh axis up to 256.  Pad logits are
        masked to -inf in ``unembed``; pad embedding rows are never
        gathered (token ids < vocab_size)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups_of_layers(self) -> int:
        if self.n_layers % self.pattern_period:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {self.pattern_period}"
            )
        return self.n_layers // self.pattern_period

    def layer_kinds(self) -> list[str]:
        return [
            self.block_pattern[i % self.pattern_period]
            for i in range(self.n_layers)
        ]

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe.every_n_layers == self.moe.offset

    def active_params(self) -> float:
        """Parameters touched per token (MoE counts top_k experts only)."""
        return self._param_count(active_only=True)

    def total_params(self) -> float:
        return self._param_count(active_only=False)

    def _param_count(self, active_only: bool) -> float:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = float(self.vocab_size * d)  # embed
        if not self.tie_embeddings and self.input_kind == "tokens":
            total += self.vocab_size * d   # lm_head
        if self.input_kind == "embeddings":
            total += self.embed_in_dim * d
        per_ffn = (
            3 * d * self.d_ff
            if self.ffn_type in ("swiglu", "geglu")
            else 2 * d * self.d_ff
        )
        for i, kind in enumerate(self.layer_kinds()):
            if kind == "attn":
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            elif kind == "mamba":
                d_in = self.mamba_expand * d
                total += (
                    d * 2 * d_in                    # in_proj
                    + d_in * self.mamba_d_conv      # conv
                    + d_in * (2 * self.mamba_d_state + 1)  # B,C,dt proj (approx)
                    + d_in                          # A diag (per-channel) + D
                    + d_in * d                      # out_proj
                )
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o projections (+ small mixes)
            if self.is_moe_layer(i):
                m = self.moe
                e = m.top_k if active_only else m.n_experts
                per_expert = (
                    3 * d * m.d_ff_expert
                    if self.ffn_type in ("swiglu", "geglu")
                    else 2 * d * m.d_ff_expert
                )
                total += e * per_expert + d * m.n_experts  # + router
                if m.dense_residual and m.d_ff_dense:
                    total += 3 * d * m.d_ff_dense
            elif kind == "rwkv":
                # channel-mix: W_k (d x d_ff), W_v (d_ff x d), W_r (d x d)
                total += 2 * d * self.d_ff + d * d
            else:
                total += per_ffn
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str           # train_4k | prefill_32k | decode_32k | long_500k
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells that apply to an arch (skips per brief, see DESIGN.md)."""
    shapes = ["train_4k", "prefill_32k"]
    encoder_only = not cfg.causal
    if not encoder_only:
        shapes.append("decode_32k")
        subquadratic = any(k in ("mamba", "rwkv") for k in cfg.block_pattern)
        if subquadratic:
            shapes.append("long_500k")
    return shapes
