"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B family].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, qk_norm,
explicit head_dim=128 (q projects 1024 -> 2048).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    ffn_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
)
