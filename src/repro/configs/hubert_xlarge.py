"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio transformer.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets).
The CNN waveform frontend is a STUB per the brief: ``input_specs()``
supplies precomputed frame embeddings (width 512).  Encoder-only: no
decode shapes (see DESIGN.md skips).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    ffn_type="gelu",
    causal=False,
    input_kind="embeddings",
    embed_in_dim=512,
    param_dtype="bfloat16",
)
