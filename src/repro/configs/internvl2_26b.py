"""internvl2-26b [arXiv:2404.16821] — InternViT (stub) + InternLM2-20B.

Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The vision frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed patch embeddings (width 3200, InternViT-6B hidden size) which
the model projects into the LM and prepends to the text sequence.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    ffn_type="swiglu",
    rope_theta=1_000_000.0,
    embed_in_dim=3200,
    n_patches=256,
    param_dtype="bfloat16",
)
