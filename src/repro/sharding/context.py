"""Ambient mesh context so model modules can apply sharding constraints
without threading mesh objects through every call signature.

``cells.py`` (and any launcher) activates the mesh around tracing/lowering;
``constraint(x, *spec)`` is a no-op when no mesh is active (smoke tests,
single-device runs), so model code can sprinkle constraints freely.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh):
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)


def current_mesh():
    return _MESH.get()


def constraint(x, *spec):
    """with_sharding_constraint(x, P(*spec)) under the ambient mesh.

    Spec entries naming axes absent from the ambient mesh are dropped
    (e.g. "pod" on a single-pod mesh); no-op without an ambient mesh.
    """
    mesh = _MESH.get()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    clean = [keep(e) for e in spec]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean))
    )
