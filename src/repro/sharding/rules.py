"""PartitionSpec rules: DP / FSDP / TP / EP / SP over the (pod, data, model)
production mesh.

``param_specs(cfg, params, mesh_axes, fsdp=...)`` walks the parameter pytree
and assigns a spec per leaf by path pattern:

* TP  — attention heads / ffn hidden / vocab on ``model``;
* EP  — MoE expert dimension on ``model``;
* FSDP — remaining large axes additionally sharded on ``data`` (ZeRO-3
  parameter sharding; required to fit arctic-480b in 16 GB/chip);
* stacked block params (leading n_repeats axis from the layer scan) get a
  leading ``None``.

Batch/activations ride on ``dp_axes`` = ("pod","data") multi-pod else
("data",).  KV caches shard batch on dp and kv-heads on model.
"""

from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: tuple[str, ...] = ("data",)   # dp axes (includes "pod" if present)
    model: str = "model"
    fsdp: str = "data"                  # axis used for ZeRO param sharding

    @property
    def dp(self) -> tuple[str, ...]:
        return self.data


# (path regex, spec WITHOUT the stacked leading axis). First match wins.
# Specs are written for the unstacked parameter.
_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                 ("model", None)),     # vocab-sharded embed
    (r"lm_head$",               (None, "model")),     # column-parallel unembed
    (r"in_proj$",               (None, "model")),     # stub frontend proj / mamba in
    (r"attn/w[qkv]$",           (None, "model")),
    (r"attn/wo$",               ("model", None)),
    (r"(q|k)_norm/w$",          (None,)),
    (r"ffn/w_(gate|up)$",       (None, "model")),
    (r"ffn/w_down$",            ("model", None)),
    (r"moe/router$",            (None, None)),
    (r"moe/w_(gate|up)$",       ("model", None, None)),   # EP: experts
    (r"moe/w_down$",            ("model", None, None)),
    (r"mamba/in_proj$",         (None, "model")),
    (r"mamba/conv_w$",          (None, "model")),
    (r"mamba/conv_b$",          ("model",)),
    (r"mamba/x_proj$",          ("model", None)),
    (r"mamba/dt_bias$",         ("model",)),
    (r"mamba/A_log$",           ("model", None)),
    (r"mamba/D$",               ("model",)),
    (r"mamba/out_proj$",        ("model", None)),
    (r"rwkv/mix$",              (None, None)),
    (r"rwkv/w[rkvg]$",          (None, "model")),
    (r"rwkv/wo$",               ("model", None)),
    (r"rwkv/w0$",               ("model",)),
    (r"rwkv/wA$",               (None, None)),
    (r"rwkv/wB$",               (None, "model")),
    (r"rwkv/u$",                (None, None)),   # (H, hs): H=40 not 16-divisible
    (r"rwkv/ln_w$",             (None, None)),
    (r"rwkv/cm_k$",             (None, "model")),
    (r"rwkv/cm_v$",             ("model", None)),
    (r"rwkv/cm_r$",             (None, "model")),
    (r"norm\d?/w$",             (None,)),
    (r"final_norm/w$",          (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _base_spec(path_s: str) -> tuple | None:
    for pat, spec in _RULES:
        if re.search(pat, path_s):
            return spec
    return None


def _apply_fsdp(spec: list, shape: tuple[int, ...], axes: MeshAxes,
                min_size: int) -> list:
    """Shard the largest still-unsharded axis on the fsdp axis."""
    if axes.fsdp in spec:
        return spec
    cand = [
        (shape[i], i) for i in range(len(spec))
        if spec[i] is None and shape[i] >= min_size
    ]
    if not cand:
        return spec
    _, idx = max(cand)
    spec[idx] = axes.fsdp
    return spec


def _axis_size(mesh_shape: dict | None, axis) -> int:
    if mesh_shape is None:
        return 1  # unknown -> assume divisible (caller validates)
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_shape.get(a, 1)
        return n
    return mesh_shape.get(axis, 1)


def _sanitize(spec: list, shape: tuple, mesh_shape: dict | None) -> list:
    """Drop axis assignments whose dimension isn't shard-divisible."""
    out = []
    for s, dim in zip(spec, shape):
        if s is None:
            out.append(None)
        elif dim % _axis_size(mesh_shape, s) == 0:
            out.append(s)
        else:
            out.append(None)
    return out


def param_specs(params, axes: MeshAxes = MeshAxes(), *,
                fsdp: bool = False, fsdp_min_size: int = 1024,
                mesh_shape: dict | None = None):
    """Pytree of PartitionSpec matching ``params``.

    Block params (under ``blocks/``) are stacked (leading n_repeats axis from
    the layer scan) -> a leading None is prepended to their rule spec.
    ``mesh_shape`` ({axis: size}) enables divisibility sanitization: any
    assignment whose dimension doesn't divide evenly degrades to None.
    """

    def assign(path, leaf):
        path_s = _path_str(path)
        stacked = path_s.startswith("blocks/")
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        eff_shape = shape[1:] if stacked else shape
        base = _base_spec(path_s)
        if base is None:
            base = (None,) * len(eff_shape)
        spec = [b if isinstance(b, str) or b is None else None for b in base]
        spec = [s if s != "model" else axes.model for s in spec]
        spec = _sanitize(spec, tuple(eff_shape), mesh_shape)
        if fsdp:
            spec = _apply_fsdp(list(spec), tuple(eff_shape), axes,
                               fsdp_min_size)
            spec = _sanitize(spec, tuple(eff_shape), mesh_shape)
        if stacked:
            spec = [None] + list(spec)
        if len(spec) != len(shape):
            raise ValueError(
                f"spec rank mismatch at {path_s}: spec {spec} vs shape {shape}"
            )
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, params)


def batch_specs(batch_like, axes: MeshAxes = MeshAxes(),
                mesh_shape: dict | None = None):
    """Batch inputs: leading (global batch) dim on the dp axes.

    If the batch doesn't divide (e.g. long_500k B=1), the dp assignment is
    dropped; the sequence axis picks up (data, model) sequence parallelism
    in the decode-state specs instead.
    """
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def assign(path, leaf):
        shape = leaf.shape
        spec = [dp] + [None] * (len(shape) - 1)
        spec = _sanitize(spec, tuple(shape), mesh_shape)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, batch_like)


def decode_state_specs(state_like, axes: MeshAxes = MeshAxes(),
                       mesh_shape: dict | None = None):
    """KV caches / SSM states with divisibility-aware fallbacks.

    Preferred layouts (stacked leading n_rep):
      kv k/v    : (n_rep, B, S_max, n_kv, hd)
                  batch on dp; kv-heads on model if divisible, else the
                  *sequence* axis takes model (context-parallel decode: XLA
                  turns the masked softmax over a sharded KV axis into the
                  flash-decode partial-softmax + tiny all-reduce pattern);
                  if batch itself is unshardable (long_500k B=1), sequence
                  takes (dp..., model) — full sequence parallelism.
      mamba h   : (n_rep, B, d_in, ds)   batch dp, channels model
      mamba conv: (n_rep, B, k-1, d_in)  batch dp, channels model
      rwkv S    : (n_rep, B, H, hs, hs)  batch dp, heads model if divisible
      x_prev    : (n_rep, B, D)          batch dp, D model
    """
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]
    m = axes.model

    def div(dim: int, axis) -> bool:
        return dim % _axis_size(mesh_shape, axis) == 0

    def assign(path, leaf):
        path_s = _path_str(path)
        shape = leaf.shape
        if path_s.endswith("pos"):
            return P(*([None] * len(shape)))
        if re.search(r"kv/(k|v)$", path_s):
            _, B, S, H, D = shape
            batch_ok = div(B, dp)
            spec = [None, dp if batch_ok else None, None, None, None]
            if batch_ok and div(H, m):
                spec[3] = m
            elif batch_ok and div(S, m):
                spec[2] = m
            elif not batch_ok:
                seq_axes = tuple(
                    (list(dp) if isinstance(dp, tuple) else [dp]) + [m]
                )
                if div(S, seq_axes):
                    spec[2] = seq_axes
                elif div(S, m):
                    spec[2] = m
            return P(*_sanitize(spec, shape, mesh_shape))
        if re.search(r"mamba/h$", path_s):
            spec = [None, dp, m, None]
        elif re.search(r"mamba/conv$", path_s):
            spec = [None, dp, None, m]
        elif re.search(r"rwkv/S$", path_s):
            spec = [None, dp, m, None, None]
        elif re.search(r"x_prev", path_s):
            spec = [None, dp, m]
        else:
            spec = [None] * len(shape)
        return P(*_sanitize(spec, shape, mesh_shape))

    return jax.tree_util.tree_map_with_path(assign, state_like)
