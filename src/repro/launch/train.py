"""Production training loop: sharded step, checkpoint/restart, failure
retry, elastic resume, step-time profiling hooks.

Usable as a module (``run_training``) or CLI::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Fault tolerance model (single-controller JAX):
* every ``ckpt_every`` steps the full train state (params, optimizer, data
  cursor) is checkpointed asynchronously with atomic publish;
* a transient step failure (injected or real) triggers restore-from-latest
  and replay — the data pipeline is stateless-per-step so replay is exact;
* on restart (new process, possibly different device count) the loop
  resumes from LATEST with re-sharding onto the current mesh.

The per-step wall times collected here are exactly the profiling phase of
the paper: ``run_training(..., time_log=...)`` returns them so callers can
fit config->time models over launcher knobs.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import ModelConfig, get_config, smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models import transformer as tf
from repro.optim import adamw
from repro.sharding import rules
from repro.train import step as step_mod


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str | None = None
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    fail_at_step: int | None = None   # failure injection (tests/demos)
    max_retries: int = 2


def _make_sharded_step(cfg, optim_cfg, step_cfg, mesh):
    axes = rules.MeshAxes(
        data=tuple(a for a in mesh.axis_names if a != "model")
        or ("data",),
    )
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    params_like = jax.eval_shape(
        lambda k: tf.init_params(cfg, k), jax.random.PRNGKey(0)
    )
    pspec = rules.param_specs(params_like, axes, mesh_shape=mesh_shape)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    opt_like = jax.eval_shape(
        lambda p: adamw.init_state(optim_cfg, p), params_like
    )
    ospec = {"step": P(), "m": pspec, "v": pspec}
    if "master" in opt_like:
        ospec["master"] = pspec
    osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospec,
                       is_leaf=lambda x: isinstance(x, P))
    fn = step_mod.build_train_step(cfg, optim_cfg, step_cfg)
    jitted = jax.jit(fn, donate_argnums=(0, 1))
    return jitted, psh, osh


def run_training(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    loop: TrainLoopConfig = TrainLoopConfig(),
    step_cfg: step_mod.StepConfig = step_mod.StepConfig(),
    optim_cfg: adamw.AdamWConfig | None = None,
    mesh=None,
) -> dict:
    """Returns {"losses": [...], "step_seconds": [...], "last_step": int}."""
    optim_cfg = optim_cfg or adamw.AdamWConfig(lr=loop.lr)
    if mesh is None:
        from repro.compat import make_mesh as _make_mesh

        mesh = _make_mesh((1, jax.device_count()), ("data", "model"))
    jitted, psh, osh = _make_sharded_step(cfg, optim_cfg, step_cfg, mesh)
    pipeline = TokenPipeline(data_cfg)

    mgr = (
        CheckpointManager(loop.ckpt_dir, keep=loop.keep)
        if loop.ckpt_dir else None
    )
    params = tf.init_params(cfg, jax.random.PRNGKey(loop.seed))
    opt_state = adamw.init_state(optim_cfg, params)
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        # elastic resume: restore re-shards onto the *current* mesh
        (params, opt_state), start_step = mgr.restore(
            None, (params, opt_state), shardings=(psh, osh)
        )
        print(f"[train] resumed from checkpoint at step {start_step}")
    else:
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)

    losses: list[float] = []
    times: list[float] = []
    injected_failures = {loop.fail_at_step} if loop.fail_at_step else set()
    step = start_step
    retries = 0
    while step < loop.steps:
        batch = pipeline.batch_at(step)
        t0 = time.perf_counter()
        try:
            if step in injected_failures:
                injected_failures.discard(step)
                raise RuntimeError("injected node failure")
            params, opt_state, metrics = jitted(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        except Exception as e:  # noqa: BLE001 — failure-retry boundary
            retries += 1
            if mgr is None or retries > loop.max_retries:
                raise
            print(f"[train] step {step} failed ({e}); "
                  f"restoring from latest checkpoint")
            mgr.wait()
            params = tf.init_params(cfg, jax.random.PRNGKey(loop.seed))
            opt_state = adamw.init_state(optim_cfg, params)
            if mgr.latest_step() is not None:
                (params, opt_state), step = mgr.restore(
                    None, (params, opt_state), shardings=(psh, osh)
                )
            else:
                step = 0
                params = jax.device_put(params, psh)
                opt_state = jax.device_put(opt_state, osh)
            continue
        dt = time.perf_counter() - t0
        losses.append(float(metrics["loss"]))
        times.append(dt)
        step += 1
        if loop.log_every and step % loop.log_every == 0:
            print(
                f"[train] step {step}/{loop.steps} "
                f"loss={losses[-1]:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"{dt * 1e3:.0f}ms/step"
            )
        if mgr is not None and step % loop.ckpt_every == 0:
            mgr.save_async(step, (params, opt_state))
    if mgr is not None:
        mgr.wait()
        mgr.save(step, (params, opt_state))
    return {"losses": losses, "step_seconds": times, "last_step": step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
    )
    out = run_training(
        cfg, data_cfg,
        TrainLoopConfig(
            steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, lr=args.lr,
            fail_at_step=args.fail_at,
        ),
    )
    print(
        f"final loss {out['losses'][-1]:.4f} "
        f"(first {out['losses'][0]:.4f}); "
        f"median step {np.median(out['step_seconds']) * 1e3:.0f}ms"
    )


if __name__ == "__main__":
    main()
