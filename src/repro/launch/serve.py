"""Batched serving driver: continuous decode loop with request batching,
KV-cache management, and SLO-aware batch sizing driven by the paper's
config->time model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 32 --slo-ms 50

The scheduler profiles decode latency at a few batch sizes, fits the cubic
regression, and picks the largest batch whose *predicted* per-token latency
meets the SLO — the paper's "smarter scheduler" use case, implemented.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import fit
from repro.models import transformer as tf
from repro.train import StepConfig, build_decode_step


class BatchedServer:
    def __init__(self, cfg, params, *, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.decode = jax.jit(
            build_decode_step(cfg, StepConfig()), donate_argnums=(1,)
        )

    def serve(self, prompts: jnp.ndarray, new_tokens: int):
        """prompts: (B, P) int32 -> (B, new_tokens) int32, seconds/token."""
        B = prompts.shape[0]
        state = tf.init_decode_state(self.cfg, B, self.max_len)
        logits, state = self.decode(self.params, state,
                                    {"tokens": prompts})
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(new_tokens - 1):
            logits, state = self.decode(self.params, state, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        dt = (time.perf_counter() - t0) / max(new_tokens - 1, 1)
        return jnp.concatenate(out, axis=1), dt

    def profile_latency_model(self, sizes=(1, 2, 4, 8), prompt_len=8,
                              repeats=2):
        """Paper phase 1+2 on the serving knob: batch size -> s/token."""
        rows, times = [], []
        for b in sizes:
            prompts = jnp.zeros((b, prompt_len), jnp.int32)
            self.serve(prompts, 4)  # compile
            ts = [self.serve(prompts, 8)[1] for _ in range(repeats)]
            rows.append([float(b)])
            times.append(float(np.mean(ts)))
        return fit(np.asarray(rows), np.asarray(times), degree=2,
                   scale=True, lam=1e-9)

    def pick_batch_for_slo(self, model, slo_s: float,
                           candidates=range(1, 65)) -> int:
        preds = np.asarray(
            model.predict(np.asarray([[float(b)] for b in candidates]))
        ).ravel()
        ok = [b for b, p in zip(candidates, preds) if p <= slo_s]
        return max(ok) if ok else min(candidates)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params)
    print("profiling decode latency vs batch size ...")
    model = server.profile_latency_model()
    batch = server.pick_batch_for_slo(model, args.slo_ms / 1e3)
    print(f"SLO {args.slo_ms}ms/token -> predicted max batch {batch}")
    done = 0
    while done < args.requests:
        b = min(batch, args.requests - done)
        prompts = jax.random.randint(
            jax.random.PRNGKey(done), (b, 8), 0, cfg.vocab_size, jnp.int32)
        toks, per_tok = server.serve(prompts, args.new_tokens)
        done += b
        print(f"served {b} requests ({per_tok * 1e3:.2f}ms/token, "
              f"{done}/{args.requests} done)")


if __name__ == "__main__":
    main()
