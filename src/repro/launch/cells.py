"""Dry-run cell construction: (arch x shape x mesh) -> lowered/compiled step.

Shared by ``launch/dryrun.py`` (512-device production meshes) and the smoke
dry-run tests (small meshes).  No jax device state is touched at import.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, SHAPES, get_config, input_specs
from repro.configs.base import ShapeConfig
from repro.core import costmodel
from repro.models import transformer as tf
from repro.optim import adamw
from repro.sharding import rules
from repro.sharding.context import use_mesh
from repro.train import step as step_mod


@dataclasses.dataclass(frozen=True)
class CellConfig:
    """Per-cell runtime knobs (the §Perf hillclimb levers)."""

    remat: str = "full"
    logits_chunk: int = 0
    microbatch: int = 1
    fsdp: bool = False
    unroll_layers: bool = False    # shallow probes set this (see analyze)
    opt_state_dtype: str = "float32"
    master_fp32: bool = False
    cache_dtype: str = "bfloat16"
    moe_n_groups: int | None = None   # override cfg.moe.n_groups


def default_cell_config(cfg: ModelConfig, shape: ShapeConfig) -> CellConfig:
    """Baseline knobs: remat-full for train, FSDP for >16B-total archs."""
    if shape.kind == "train":
        return CellConfig(
            remat="full",
            fsdp=cfg.total_params() * 2 > 32e9,  # bf16 bytes over ~2GB/chip TP
        )
    return CellConfig(remat="none")


def _apply_overrides(cfg: ModelConfig, cell: CellConfig, mesh) -> ModelConfig:
    if cfg.moe is not None:
        # default dispatch groups = number of data shards, so each group is
        # shard-local at the production sharding
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
        n_groups = cell.moe_n_groups or dp_total
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_groups=n_groups)
        )
    return cfg


def _mesh_axes(mesh) -> rules.MeshAxes:
    names = tuple(mesh.axis_names)
    if "pod" in names:
        return rules.MeshAxes(data=("pod", "data"), model="model")
    return rules.MeshAxes(data=("data",), model="model")


def _sharding(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape_name: str, mesh, *,
               cell: CellConfig | None = None, cfg: ModelConfig | None = None):
    """Build (jitted_fn, example_args, donate) for one dry-run cell.

    Returns dict with fn/args/meta; caller lowers with
    ``fn.lower(*args)`` (args are ShapeDtypeStructs — no allocation).
    """
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell or default_cell_config(cfg, shape)
    cfg = _apply_overrides(cfg, cell, mesh)
    axes = _mesh_axes(mesh)

    step_cfg = step_mod.StepConfig(
        remat=cell.remat,
        logits_chunk=cell.logits_chunk,
        microbatch=cell.microbatch,
        cache_dtype=cell.cache_dtype,
        unroll_layers=cell.unroll_layers,
    )
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    params_shapes = jax.eval_shape(
        partial(tf.init_params, cfg), jax.random.PRNGKey(0)
    )
    param_spec = rules.param_specs(
        params_shapes, axes, fsdp=cell.fsdp, mesh_shape=mesh_shape
    )
    param_sh = _sharding(mesh, param_spec)
    batch_shapes = input_specs(cfg, shape)
    batch_spec = rules.batch_specs(batch_shapes, axes, mesh_shape=mesh_shape)
    batch_sh = _sharding(mesh, batch_spec)

    meta = {
        "arch": cfg.name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "cell_config": dataclasses.asdict(cell),
        "total_params": cfg.total_params(),
        "active_params": cfg.active_params(),
    }

    if shape.kind == "train":
        optim_cfg = adamw.AdamWConfig(
            state_dtype=cell.opt_state_dtype, master_fp32=cell.master_fp32
        )
        opt_shapes = jax.eval_shape(
            partial(adamw.init_state, optim_cfg), params_shapes
        )
        opt_spec = _opt_specs(opt_shapes, param_spec)
        opt_sh = _sharding(mesh, opt_spec)
        fn = step_mod.build_train_step(cfg, optim_cfg, step_cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        args = (params_shapes, opt_shapes, batch_shapes)
        meta["model_flops"] = train_model_flops(cfg, shape)
    elif shape.kind == "prefill":
        fn = step_mod.build_prefill_step(cfg, shape.seq_len, step_cfg)
        jitted = jax.jit(
            fn, in_shardings=(param_sh, batch_sh),
        )
        args = (params_shapes, batch_shapes)
        meta["model_flops"] = serve_model_flops(cfg, shape, prefill=True)
    elif shape.kind == "decode":
        fn = step_mod.build_decode_step(cfg, step_cfg)
        state_shapes = step_mod.decode_state_shapes(
            cfg, shape.global_batch, shape.seq_len, step_cfg
        )
        state_spec = rules.decode_state_specs(
            state_shapes, axes, mesh_shape=mesh_shape
        )
        state_sh = _sharding(mesh, state_spec)
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, state_sh, batch_sh),
            out_shardings=(None, state_sh),
            donate_argnums=(1,),
        )
        args = (params_shapes, state_shapes, batch_shapes)
        meta["model_flops"] = serve_model_flops(cfg, shape, prefill=False)
    else:
        raise ValueError(shape.kind)
    return {"jitted": jitted, "args": args, "meta": meta}


def _opt_specs(opt_shapes, param_spec):
    """Optimizer state specs mirror the param specs (m, v, master)."""
    spec = {
        "step": P(),
        "m": param_spec,
        "v": param_spec,
    }
    if "master" in opt_shapes:
        spec["master"] = param_spec
    return spec


# ---------------------------------------------------------------------------
# MODEL_FLOPS accounting (global, for the useful-compute ratio)
# ---------------------------------------------------------------------------


def train_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6 * N_active * tokens (+ attention context flops)."""
    tokens = shape.global_batch * shape.seq_len
    base = 6.0 * cfg.active_params() * tokens
    base += 3.0 * _attention_context_flops(cfg, shape.seq_len, tokens)
    return base


def serve_model_flops(cfg: ModelConfig, shape: ShapeConfig,
                      *, prefill: bool) -> float:
    if prefill:
        tokens = shape.global_batch * shape.seq_len
        return (
            2.0 * cfg.active_params() * tokens
            + _attention_context_flops(cfg, shape.seq_len, tokens)
        )
    tokens = shape.global_batch  # one new token per sequence
    base = 2.0 * cfg.active_params() * tokens
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    hd = cfg.resolved_head_dim
    # decode attention: q @ K^T + p @ V over the full cache
    base += tokens * n_attn * cfg.n_heads * hd * shape.seq_len * 2 * 2
    return base


def _attention_context_flops(cfg: ModelConfig, seq: int,
                             tokens: float) -> float:
    """2 * (qk + pv) flops for causal attention over the sequence."""
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    hd = cfg.resolved_head_dim
    ctx = seq / 2 if cfg.causal else seq
    return tokens * n_attn * cfg.n_heads * hd * ctx * 2 * 2


def analyze_cell(built, *, n_devices: int, mesh=None):
    """lower + compile + roofline report for one cell."""
    if mesh is not None:
        with use_mesh(mesh):
            lowered = built["jitted"].lower(*built["args"])
    else:
        lowered = built["jitted"].lower(*built["args"])
    compiled = lowered.compile()
    report = costmodel.roofline_from_compiled(
        compiled,
        n_devices=n_devices,
        model_flops=built["meta"]["model_flops"],
    )
    mem = compiled.memory_analysis()
    return {
        "meta": built["meta"],
        "roofline": report.to_dict(),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (
                mem.argument_size_in_bytes
                + mem.temp_size_in_bytes
                + mem.output_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
    }


def estimate_step_time(arch: str, shape_name: str, mesh, *,
                       cell: CellConfig | None = None,
                       cfg: ModelConfig | None = None) -> dict:
    """Cheap step-time estimate: shallow probes + extrapolation only (no
    full-depth compile).  This is the profiler backend for the
    paper's-config->time autotuner over launcher knobs (§Perf-llama3)."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell or default_cell_config(cfg, shape)
    n_rep = cfg.n_groups_of_layers
    period = cfg.pattern_period
    n_devices = mesh.devices.size
    probe_cell = dataclasses.replace(cell, unroll_layers=True)
    probes = []
    peak = 0
    for depth_groups in (1, 2):
        cfg_p = dataclasses.replace(cfg, n_layers=depth_groups * period)
        built = build_cell(arch, shape_name, mesh, cell=probe_cell,
                           cfg=cfg_p)
        with use_mesh(mesh):
            lowered = built["jitted"].lower(*built["args"])
        compiled = lowered.compile()
        probes.append(_raw_costs(compiled, n_devices))
        mem = compiled.memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    p1, p2 = probes
    tot = {k: p1[k] + (n_rep - 1) * (p2[k] - p1[k])
           for k in ("flops", "bytes", "collective_bytes")}
    compute_s = tot["flops"] / costmodel.PEAK_FLOPS_BF16
    memory_s = tot["bytes"] / costmodel.HBM_BW
    collective_s = tot["collective_bytes"] / costmodel.ICI_BW
    return {
        "step_s": max(compute_s, memory_s) + collective_s,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "probe2_peak_bytes": peak,
    }


def _raw_costs(compiled, n_devices):
    from repro.compat import cost_analysis_dict

    cost = cost_analysis_dict(compiled)
    coll = costmodel.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll.total_bytes),
        "collectives": coll,
    }


def analyze_cell_extrapolated(arch: str, shape_name: str, mesh, *,
                              cell: CellConfig | None = None,
                              cfg: ModelConfig | None = None):
    """Depth-exact roofline via secant extrapolation over layer groups.

    XLA's cost_analysis counts `lax.scan` bodies ONCE regardless of trip
    count, so a scanned L-layer model under-reports compute/bytes/collective
    by ~L x.  Unrolling the full depth is compile-prohibitive at 512 devices.
    Instead we compile two SHALLOW UNROLLED probes — depth = 1 period and
    2 periods — whose cost difference is the exact per-group cost (groups
    are homogeneous), then extrapolate:

        total = probe1 + (n_rep - 1) * (probe2 - probe1)

    The full-depth scan compile still provides memory_analysis (peak HBM is
    reported correctly for scans) and proves the production graph compiles.

    Residual known under-count: sequence-chunk scans INSIDE a block (rwkv
    wkv / mamba ssm inner scans) are still costed once per block; bounded
    at <~6% of block flops for rwkv6-3b, <1% elsewhere (DESIGN.md).
    """
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell or default_cell_config(cfg, shape)
    n_rep = cfg.n_groups_of_layers
    period = cfg.pattern_period
    n_devices = mesh.devices.size

    # 1) full-depth scan compile: memory + compile proof
    built_full = build_cell(arch, shape_name, mesh, cell=cell, cfg=cfg)
    with use_mesh(mesh):
        lowered_full = built_full["jitted"].lower(*built_full["args"])
    compiled_full = lowered_full.compile()
    mem = compiled_full.memory_analysis()

    # 2) shallow unrolled probes
    probe_cell = dataclasses.replace(cell, unroll_layers=True)
    probes = []
    for depth_groups in (1, 2):
        cfg_p = dataclasses.replace(cfg, n_layers=depth_groups * period)
        built = build_cell(arch, shape_name, mesh, cell=probe_cell, cfg=cfg_p)
        with use_mesh(mesh):
            lowered_p = built["jitted"].lower(*built["args"])
        probes.append(_raw_costs(lowered_p.compile(), n_devices))

    p1, p2 = probes
    extrap = {
        k: p1[k] + (n_rep - 1) * (p2[k] - p1[k])
        for k in ("flops", "bytes", "collective_bytes")
    }
    coll_by_kind = {
        kind: (
            p1["collectives"].bytes_by_kind[kind]
            + (n_rep - 1) * (
                p2["collectives"].bytes_by_kind[kind]
                - p1["collectives"].bytes_by_kind[kind]
            )
        )
        for kind in p1["collectives"].bytes_by_kind
    }
    model_flops = built_full["meta"]["model_flops"]
    report = costmodel.RooflineReport(
        flops=extrap["flops"],
        hbm_bytes=extrap["bytes"],
        collective_bytes=extrap["collective_bytes"],
        compute_s=extrap["flops"] / costmodel.PEAK_FLOPS_BF16,
        memory_s=extrap["bytes"] / costmodel.HBM_BW,
        collective_s=extrap["collective_bytes"] / costmodel.ICI_BW,
        peak_hbm_bytes=float(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
        ),
        dominant="",
        model_flops=model_flops,
        useful_ratio=(
            model_flops / (extrap["flops"] * n_devices)
            if extrap["flops"] else None
        ),
        n_devices=n_devices,
    )
    terms = {
        "compute": report.compute_s,
        "memory": report.memory_s,
        "collective": report.collective_s,
    }
    report = dataclasses.replace(report, dominant=max(terms, key=terms.get))
    rdict = report.to_dict()
    rdict["collective_bytes_by_kind"] = coll_by_kind
    return {
        "meta": built_full["meta"],
        "roofline": rdict,
        "probe_group_cost": {
            k: p2[k] - p1[k] for k in ("flops", "bytes", "collective_bytes")
        },
        "scan_compile_costs": _raw_costs(compiled_full, n_devices)
        | {"collectives": None},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes
            ),
        },
    }
