import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run entrypoint.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
first two lines above pin 512 host placeholder devices BEFORE any jax
import, so ``make_production_mesh`` can build the 16x16 single-pod and
2x16x16 multi-pod meshes.  Smoke tests and benchmarks must NOT import this
module (they should see 1 device).

For every (architecture x applicable input shape x mesh):
    jit(step).lower(**ShapeDtypeStructs).compile()
then record memory_analysis / cost_analysis / parsed collective bytes into
``experiments/dryrun/<mesh>/<arch>__<shape>.json`` for EXPERIMENTS.md.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import ARCH_IDS, applicable_shapes, get_config  # noqa: E402
from repro.launch import cells                                     # noqa: E402
from repro.launch.mesh import make_production_mesh                 # noqa: E402


def run_cell(arch: str, shape_name: str, mesh, out_path: str,
             cell_cfg=None) -> dict:
    t0 = time.time()
    result = cells.analyze_cell_extrapolated(
        arch, shape_name, mesh, cell=cell_cfg
    )
    result["compile_seconds"] = time.time() - t0
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=1)
    os.replace(tmp, out_path)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape cell name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"expected 512 placeholder devices, got {jax.device_count()} — "
        f"dryrun must own the process (XLA_FLAGS set before jax import)"
    )

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    n_ok = n_fail = n_skip = 0
    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = (
                applicable_shapes(cfg)
                if args.shape == "all"
                else [args.shape]
            )
            for shape_name in shapes:
                out_path = os.path.join(
                    args.out, mesh_name, f"{arch}__{shape_name}.json"
                )
                if args.skip_existing and os.path.exists(out_path):
                    n_skip += 1
                    continue
                tag = f"[{mesh_name}] {arch} x {shape_name}"
                try:
                    r = run_cell(arch, shape_name, mesh, out_path)
                    roof = r["roofline"]
                    print(
                        f"OK   {tag}: dominant={roof['dominant']} "
                        f"compute={roof['compute_s']:.4f}s "
                        f"memory={roof['memory_s']:.4f}s "
                        f"collective={roof['collective_s']:.4f}s "
                        f"peak={r['memory']['peak_bytes'] / 2**30:.2f}GiB/dev "
                        f"(compile {r['compile_seconds']:.0f}s)",
                        flush=True,
                    )
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    for tag, err in failures:
        print(f"  FAILED: {tag}: {err}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
