"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (smoke tests, tuner factorization sweeps)."""
    return compat.make_mesh(shape, axes)


def mesh_axes_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
