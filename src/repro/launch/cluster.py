"""CLI driver for the predictive cluster scheduler.

    PYTHONPATH=src python -m repro.launch.cluster \
        --jobs 60 --workers 16 --policies fifo-static,predict-sjf

Runs the named scheduling policies over one shared deterministic trace and
prints a comparison table plus the online-refinement error trajectory.
``--save-models`` persists the fitted per-(app, platform, backend) models
(the paper's model database) so a later run — or a real long-lived
scheduler — can ``--load-models`` and skip the bootstrap profiling phase.
``--oracle engine`` wall-clocks the live MapReduce engine instead of the
analytic cost (small traces only: every distinct config compiles once).
``--overlap-depth 1,2,4`` widens every predictive policy's category grid
with the pipelined execution mode's overlap depth, so plans carry a
per-job depth choice (the ``depths`` column histograms what was picked).
``--combiner`` widens the grid along the map-side-combine axis instead:
each predictive policy profiles every backend with the combiner off *and*
on and chooses per job (the ``comb`` column histograms the choice; the
``predict-combine`` policy tunes this axis even without the flag).
``--elastic`` runs the trace on the :class:`repro.elastic.ElasticCluster`,
where the ``predict-elastic`` policy may preempt running jobs at wave
boundaries and shrink/grow their worker grants (``--ckpt-overhead`` /
``--restore-overhead`` price each move); other policies run unchanged on
the elastic simulator, so the comparison stays apples-to-apples.

``--service`` switches from draining a fixed trace to *serving* an
open-ended arrival stream (``--stream flash|diurnal|bursty|constant``)
until ``--duration`` sim seconds and/or ``--until-jobs`` arrivals::

    PYTHONPATH=src python -m repro.launch.cluster \
        --service --elastic --duration 900 --stream flash \
        --slo-p99 6 --admission burn,static --health-every 60

Each ``--admission`` arm (``burn`` = SLO burn-rate overload control,
``static`` = fixed queue cap, ``none`` = admit everything) serves the
identical stream; a health line prints every ``--health-every`` sim
seconds with queue/worker gauges and the windowed p99, and the final
table compares exact p99 turnaround and SLO-good goodput per arm.
``--metrics-out x.prom`` writes Prometheus text exposition instead of
JSON (both modes).
"""

from __future__ import annotations

import argparse
import json
import math
import os

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    EngineOracle,
    JobStream,
    POLICIES,
    PoissonProcess,
    PredictivePolicy,
    RenewalProcess,
    assign_deadlines,
    constant_rate,
    diurnal_rate,
    flash_crowd_rate,
    generate_workload,
    get_policy,
)
from repro.core.predictor import ModelDatabase
from repro.obs import (
    ClusterMetrics,
    ControlledPolicy,
    OverloadController,
    PredictionLedger,
    SLOMonitor,
    SLOPolicy,
    SpanRecorder,
    StaticAdmission,
    get_logger,
    render_slots,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.cluster",
        description="Prediction-driven multi-job MapReduce scheduling",
    )
    ap.add_argument("--jobs", type=int, default=60)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--policies", default="all",
                    help="comma list of policy names, or 'all'")
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "uniform", "bursty"))
    ap.add_argument("--mean-interarrival", type=float, default=0.12)
    ap.add_argument("--size-min", type=int, default=1 << 14)
    ap.add_argument("--size-max", type=int, default=1 << 18)
    ap.add_argument("--deadline-fraction", type=float, default=0.6,
                    help="fraction of jobs carrying an SLO deadline")
    ap.add_argument("--slack", type=float, nargs=2, default=(1.2, 6.0),
                    metavar=("LO", "HI"),
                    help="deadline slack multiplier range")
    ap.add_argument("--noise", type=float, default=0.02,
                    help="analytic-oracle runtime noise (lognormal sigma)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--oracle", default="analytic",
                    choices=("analytic", "engine", "engine-traced",
                             "engine-sharded"),
                    help="'engine-traced' wall-clocks the live engine "
                         "through the telemetry path: completed jobs carry "
                         "per-phase traces and the online refiner fits "
                         "decomposed per-phase models; 'engine-sharded' "
                         "schedules the real shard_map mesh path (each "
                         "grant W runs on a W-device mesh — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for CPU "
                         "emulation), traced, so per-phase wall times come "
                         "from the sharded engine")
    ap.add_argument("--overlap-depth", default=None, metavar="D1,D2,...",
                    help="overlap-depth grid for predictive policies "
                         "(e.g. '1,2,4'): each depth becomes one more "
                         "profiled category and plans carry the chosen "
                         "depth per job (default: policy-specific — "
                         "predict-pipeline tunes 1,2,4; others stay at 1)")
    ap.add_argument("--combiner", action="store_true",
                    help="widen every predictive policy's category grid "
                         "with the map-side combine axis: each backend is "
                         "profiled with the combiner off and on, and plans "
                         "carry a per-job combiner choice (the 'comb' "
                         "column histograms what was picked; default: "
                         "policy-specific — predict-combine tunes off+on, "
                         "others stay off)")
    ap.add_argument("--net-capacity", type=float, default=None,
                    help="shared shuffle-fabric bytes/s budget: the "
                         "simulated ground truth fair-share-stretches "
                         "overlapping shuffles past it (contention shows "
                         "up in every policy's makespan and in the "
                         "exported trace), and the predict-resource "
                         "policy schedules against it (default: "
                         "unconstrained fabric)")
    ap.add_argument("--elastic", action="store_true",
                    help="run on the ElasticCluster: running jobs may be "
                         "preempted at wave boundaries and regranted "
                         "(the predict-elastic policy exploits this; "
                         "other policies behave as on the base cluster)")
    ap.add_argument("--ckpt-overhead", type=float, default=0.02,
                    help="simulated snapshot cost per preemption, seconds "
                         "(engine oracles override this with measured "
                         "save_snapshot walls)")
    ap.add_argument("--restore-overhead", type=float, default=0.02,
                    help="simulated restore cost per preemption, seconds "
                         "(engine oracles override this with measured "
                         "load_snapshot walls)")
    ap.add_argument("--suspend", action="store_true",
                    help="with --elastic: let predict-elastic suspend "
                         "best-effort jobs to disk (grant 0) when "
                         "shrinking cannot free enough workers for a "
                         "starved deadline job")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="export each policy's run as Chrome trace-event "
                         "JSON (open in Perfetto / chrome://tracing); with "
                         "several policies the policy name is suffixed "
                         "onto the stem.  Also prints the per-worker-slot "
                         "ASCII timeline for small clusters")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="write per-policy service metrics (streaming "
                         "p50/p99 turnaround + wait, goodput, regrant "
                         "overhead) as one JSON object keyed by policy")
    ap.add_argument("--drift-ledger", action="store_true",
                    help="attach a PredictionLedger to every predictive "
                         "policy: records predicted-vs-realized per "
                         "category, raises drift alarms, and triggers "
                         "category-targeted refits")
    svc = ap.add_argument_group(
        "service mode", "serve an open-ended arrival stream instead of "
        "draining a fixed trace; see module docstring for an example"
    )
    svc.add_argument("--service", action="store_true",
                     help="run in service mode: jobs come from --stream "
                          "until --duration / --until-jobs, admission is "
                          "per --admission, and a health line prints "
                          "every --health-every sim seconds")
    svc.add_argument("--duration", type=float, default=None,
                     help="service horizon in sim seconds (arrivals stop "
                          "here; admitted jobs drain to completion)")
    svc.add_argument("--until-jobs", type=int, default=None,
                     help="stop the stream after this many arrivals "
                          "(composes with --duration: first bound wins)")
    svc.add_argument("--stream", default="flash",
                     choices=("constant", "diurnal", "bursty", "flash"),
                     help="arrival process: constant/diurnal/flash are "
                          "Poisson (flash = diurnal base hit by --crowd "
                          "windows), bursty is the renewal process")
    svc.add_argument("--rate", type=float, default=0.85,
                     help="base arrival rate, jobs/s")
    svc.add_argument("--crowd", type=float, nargs=3, action="append",
                     metavar=("T0", "T1", "FACTOR"), default=None,
                     help="flash-crowd window: rate multiplies by FACTOR "
                          "for t in [T0, T1); repeatable (default: one "
                          "4.5x crowd at 120..200 s)")
    svc.add_argument("--admission", default="burn,static",
                     help="comma list of admission arms to serve the "
                          "same stream: burn (SLO burn-rate overload "
                          "control), static (fixed queue cap), none")
    svc.add_argument("--slo-p99", type=float, default=6.0,
                     help="SLO: good = turnaround within this, seconds")
    svc.add_argument("--slo-objective", type=float, default=0.95,
                     help="fraction of completions that must be good")
    svc.add_argument("--queue-floor", type=int, default=4,
                     help="burn arm sheds queued jobs down to this depth "
                          "while the alarm is tripped")
    svc.add_argument("--static-cap", type=int, default=12,
                     help="static arm rejects arrivals beyond this "
                          "queue depth, alarm or no alarm")
    svc.add_argument("--health-every", type=float, default=60.0,
                     help="health-line period, sim seconds (0 disables)")
    svc.add_argument("--window", type=float, default=60.0,
                     help="sliding-window width for the windowed "
                          "p50/p99/rate gauges in health lines")
    svc.add_argument("--retain-jobs", type=int, default=None,
                     help="with --trace-out: SpanRecorder ring retention "
                          "— keep spans for only the last N completed "
                          "jobs (default: keep everything)")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    ap.add_argument("--log-json", action="store_true",
                    help="emit status lines as JSON objects (one per "
                         "line) on stderr instead of human-readable text")
    ap.add_argument("--save-models", metavar="PATH",
                    help="persist the fitted ModelDatabase as JSON")
    ap.add_argument("--load-models", metavar="PATH",
                    help="warm-start predictive policies from a saved "
                         "ModelDatabase (skips bootstrap profiling)")
    ap.add_argument("--json", metavar="PATH",
                    help="also dump per-policy metrics as JSON")
    return ap


def _trace_path(base: str, policy: str, many: bool) -> str:
    if not many:
        return base
    root, ext = os.path.splitext(base)
    return f"{root}.{policy}{ext or '.json'}"


# --------------------------------------------------------------- service mode


def _build_stream(args) -> JobStream:
    """One seeded open-ended stream per --stream choice; every arm
    re-iterates it from scratch, so all arms see the identical jobs."""
    if args.stream == "bursty":
        process = RenewalProcess(
            "bursty", mean_interarrival=1.0 / args.rate, seed=args.seed
        )
    else:
        if args.stream == "constant":
            rate_fn, peak = constant_rate(args.rate), args.rate
        else:
            rate_fn = diurnal_rate(args.rate, amplitude=0.3, period_s=600.0)
            peak = args.rate * 1.3
            if args.stream == "flash":
                crowds = [
                    tuple(c) for c in (args.crowd or [[120.0, 200.0, 4.5]])
                ]
                rate_fn = flash_crowd_rate(rate_fn, crowds)
                peak *= max(f for _, _, f in crowds)
        process = PoissonProcess(rate_fn, peak_rate=peak, seed=args.seed)
    return JobStream(
        process, seed=args.seed,
        size_range=(args.size_min, args.size_max),
    )


def _service_arm(kind: str, args, inner):
    """(policy, controller, monitor) for one --admission arm."""
    if kind == "none":
        return inner, None, None
    if kind == "static":
        ctrl = StaticAdmission(args.static_cap)
        return ControlledPolicy(inner, ctrl), ctrl, None
    if kind == "burn":
        monitor = SLOMonitor(
            SLOPolicy(args.slo_p99, objective=args.slo_objective)
        )
        ctrl = OverloadController(monitor, queue_floor=args.queue_floor)
        return ControlledPolicy(inner, ctrl), ctrl, monitor
    raise SystemExit(
        f"unknown --admission arm {kind!r}; expected burn|static|none"
    )


def _exact_quantile(xs, q: float):
    """ceil-index order statistic (the convention the P² windows target)."""
    if not xs:
        return None
    xs = sorted(xs)
    return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]


def _fabric_kwargs(args, oracle, log) -> dict:
    """Validated ``net_capacity`` kwarg for cluster construction.

    A fabric budget is only honest when the ground truth can price it:
    the elastic simulator has no shared-fabric event loop, and an oracle
    without ``prices_contention`` (an untraced engine oracle) yields no
    per-phase shuffle windows to stretch.  Refusing loudly beats running
    a silently uncontended "contended" experiment.
    """
    if args.net_capacity is None:
        return {}
    if args.elastic:
        log.warning(
            "net_capacity_rejected", capacity=args.net_capacity,
            reason="elastic",
            msg="--net-capacity needs the base cluster's shared-fabric "
                "event loop; the elastic simulator does not price "
                "contention",
        )
        raise SystemExit("--net-capacity is incompatible with --elastic")
    if not getattr(oracle, "prices_contention", False):
        log.warning(
            "net_capacity_rejected", capacity=args.net_capacity,
            reason="oracle", oracle=oracle.platform,
            msg=f"oracle {oracle.platform!r} cannot price fabric "
                "contention (no per-phase shuffle windows); use the "
                "analytic oracle or a traced engine oracle",
        )
        raise SystemExit(
            f"--net-capacity rejected: oracle {oracle.platform!r} cannot "
            "price contention"
        )
    return {"net_capacity": args.net_capacity}


def _run_service(args, oracle, log) -> None:
    if args.duration is None and args.until_jobs is None:
        raise SystemExit("--service needs --duration and/or --until-jobs")
    if args.rate <= 0:
        raise SystemExit("--rate must be > 0")
    arms = [a.strip() for a in args.admission.split(",") if a.strip()]
    if not arms:
        raise SystemExit("--admission must name at least one arm")
    inner_name = ("fifo-static" if args.policies == "all"
                  else args.policies.split(",")[0])
    log.info(
        "service",
        msg=f"serving --stream {args.stream} at base {args.rate:g} jobs/s "
            f"on {args.workers} workers, policy {inner_name}, "
            f"arms: {', '.join(arms)}",
        stream=args.stream, rate=args.rate, policy=inner_name, arms=arms,
    )
    fabric_kwargs = _fabric_kwargs(args, oracle, log)
    out: dict[str, dict] = {}
    registries: dict[str, object] = {}
    for kind in arms:
        kwargs: dict = {}
        if issubclass(POLICIES[inner_name], PredictivePolicy):
            kwargs["seed"] = args.seed
            if args.combiner:
                kwargs["combiner_grid"] = (False, True)
        policy, ctrl, monitor = _service_arm(
            kind, args, get_policy(inner_name, **kwargs)
        )
        metrics = ClusterMetrics(window_s=args.window or None)
        if args.elastic:
            from repro.elastic import ElasticCluster

            cluster = ElasticCluster(
                args.workers, oracle,
                snapshot_overhead_s=args.ckpt_overhead,
                restore_overhead_s=args.restore_overhead,
            )
        else:
            cluster = Cluster(args.workers, oracle, **fabric_kwargs)
        cluster.metrics = metrics

        def on_health(now, snap, kind=kind):
            w = snap.get("windowed") or {}
            p99 = w.get("p99_turnaround_s")
            log.info(
                "health", arm=kind, t=round(now, 1),
                queue=snap["queue_depth"], busy=snap["busy_workers"],
                suspended=snap["suspended_jobs"], windowed_p99_s=p99,
                msg=f"[{kind:>6}] t={now:8.1f}  "
                    f"queue={snap['queue_depth']:>3}  "
                    f"busy={snap['busy_workers']:>2}/{args.workers}  "
                    f"susp={snap['suspended_jobs']}  win p99="
                    f"{'n/a' if p99 is None else format(p99, '.2f') + 's'}",
            )

        result = cluster.run_service(
            _build_stream(args), policy,
            until_time=args.duration, until_jobs=args.until_jobs,
            health_every=args.health_every or None,
            on_health=on_health if args.health_every else None,
        )

        done = [r for r in result.records if r.completed]
        turn = [r.turnaround for r in done]
        good = [r for r in done if r.turnaround <= args.slo_p99]
        t0 = min((r.spec.arrival for r in result.records), default=0.0)
        t_end = max((r.finish for r in done), default=t0)
        alarms = monitor.alarms if monitor is not None else []
        for a in alarms:
            log.info(
                "alarm", arm=kind, transition=a.event, t=round(a.t, 2),
                msg=f"[{kind:>6}] {a.event:<5} at t={a.t:8.1f}  "
                    f"burn fast={a.burn_fast:.2f} slow={a.burn_slow:.2f}",
            )
        out[kind] = {
            "arm": policy.name,
            "n_arrived": len(result.records),
            "n_completed": len(done),
            "n_rejected": sum(
                1 for r in result.records if not r.admitted
            ),
            "n_good": len(good),
            "p50_turnaround_s": _exact_quantile(turn, 0.5),
            "p99_turnaround_s": _exact_quantile(turn, 0.99),
            # SLO-good tokens per second: completions that blew the
            # target spent capacity without serving anyone in time.
            "goodput_tokens_per_s": (
                sum(r.spec.size for r in good) / (t_end - t0)
                if t_end > t0 else None
            ),
            "n_sheds": (
                sum(1 for a in ctrl.log if a.action == "shed")
                if ctrl is not None else 0
            ),
            "n_suspends": (
                sum(1 for a in ctrl.log if a.action == "suspend")
                if ctrl is not None else 0
            ),
            "n_alarms": len(alarms),
            "budget_remaining_frac": (
                monitor.budget()["remaining_frac"]
                if monitor is not None else None
            ),
            "service": metrics.summary(),
        }
        registries[kind] = metrics.registry
        if args.trace_out:
            rec = SpanRecorder(max_jobs=args.retain_jobs)
            rec.record(
                result,
                control_log=ctrl.log if ctrl is not None else None,
            )
            violations = rec.check()
            if violations:
                log.warning(
                    "span_tiling", arm=kind, n=len(violations),
                    msg=f"{kind}: {len(violations)} span-tiling "
                        f"violations (trace still exported)",
                )
            path = _trace_path(args.trace_out, kind, len(arms) > 1)
            rec.save_chrome(path)
            log.info(
                "trace_out", arm=kind, path=path,
                msg=f"{kind}: wrote Chrome trace -> {path}",
            )

    def f(x, nd=2):
        return "n/a" if x is None else f"{x:.{nd}f}"

    hdr = (
        f"{'arm':<30} {'done':>5} {'rej':>5} {'good':>5} {'p50':>7} "
        f"{'p99':>7} {'goodput':>9} {'shed':>5} {'susp':>5} "
        f"{'alarms':>6} {'budget':>7}"
    )
    print(hdr)
    print("-" * len(hdr))
    for kind in arms:
        m = out[kind]
        print(
            f"{m['arm']:<30} {m['n_completed']:>5} {m['n_rejected']:>5} "
            f"{m['n_good']:>5} {f(m['p50_turnaround_s']):>7} "
            f"{f(m['p99_turnaround_s']):>7} "
            f"{f(m['goodput_tokens_per_s'], 0):>9} {m['n_sheds']:>5} "
            f"{m['n_suspends']:>5} {m['n_alarms']:>6} "
            f"{f(m['budget_remaining_frac'], 3):>7}"
        )
    if args.metrics_out:
        if args.metrics_out.endswith(".prom"):
            for kind in arms:
                path = _trace_path(args.metrics_out, kind, len(arms) > 1)
                registries[kind].save_prom(path)
                log.info(
                    "metrics_out", arm=kind, path=path,
                    msg=f"{kind}: wrote Prometheus text -> {path}",
                )
        else:
            with open(args.metrics_out, "w") as fp:
                json.dump(out, fp, indent=1, sort_keys=True)
            log.info(
                "metrics_out", path=args.metrics_out,
                msg=f"wrote service metrics -> {args.metrics_out}",
            )
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(out, fp, indent=1, sort_keys=True)
        log.info(
            "json_out", path=args.json,
            msg=f"wrote metrics -> {args.json}",
        )


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    log = get_logger(
        "cluster", level=args.log_level, json_lines=args.log_json
    )
    depth_grid = None
    if args.overlap_depth is not None:
        depth_grid = tuple(
            int(d) for d in args.overlap_depth.split(",") if d.strip()
        )
    deep = depth_grid is not None and max(depth_grid) > 1
    if args.oracle in ("engine", "engine-traced", "engine-sharded"):
        if deep and args.oracle == "engine-sharded":
            raise SystemExit(
                "--overlap-depth > 1 is a single-controller schedule; "
                "it does not compose with --oracle engine-sharded"
            )
        oracle = EngineOracle(
            traced=args.oracle in ("engine-traced", "engine-sharded"),
            sharded=args.oracle == "engine-sharded",
            pipelined=deep,
        )
        log.info(
            "engine_oracle",
            msg="note: the engine oracle compiles every distinct "
                "(app, size, backend, M, R, W) once — predictive policies' "
                "bootstrap profiling alone is ~100+ compiles at the default "
                "grids; keep traces tiny and grids small",
        )
    else:
        oracle = AnalyticOracle(noise=args.noise, seed=args.seed)

    if args.service:
        _run_service(args, oracle, log)
        return

    jobs = generate_workload(
        args.jobs, seed=args.seed, arrival=args.arrival,
        mean_interarrival=args.mean_interarrival,
        size_range=(args.size_min, args.size_max),
    )
    if args.deadline_fraction > 0:
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=tuple(args.slack), fraction=args.deadline_fraction,
            seed=args.seed + 1,
        )
    names = (sorted(POLICIES) if args.policies == "all"
             else args.policies.split(","))
    fabric_kwargs = _fabric_kwargs(args, oracle, log)
    if args.elastic:
        from repro.elastic import ElasticCluster

        cluster = ElasticCluster(
            args.workers, oracle,
            snapshot_overhead_s=args.ckpt_overhead,
            restore_overhead_s=args.restore_overhead,
        )
    else:
        cluster = Cluster(args.workers, oracle, **fabric_kwargs)

    header = (
        f"{'policy':<18} {'makespan':>9} {'wait':>7} {'turnaround':>10} "
        f"{'util':>5} {'SLO':>5} {'rej':>4} {'rgr':>4} {'MAE%':>6} "
        f"{'MAE% 1st→2nd half':>18} {'depths':>12} {'comb':>11}"
    )
    log.info(
        "run",
        msg=f"{args.jobs} jobs, {args.workers} workers, "
            f"arrival={args.arrival}, oracle={oracle.platform}",
        jobs=args.jobs, workers=args.workers, arrival=args.arrival,
        oracle=oracle.platform,
    )
    print(header)
    print("-" * len(header))
    all_metrics: dict[str, dict] = {}
    service: dict[str, dict] = {}
    prom_registries: dict[str, object] = {}
    save_db = None
    for name in names:
        kwargs: dict = {}
        ledger = None
        if issubclass(POLICIES[name], PredictivePolicy):
            kwargs["seed"] = args.seed
            if depth_grid is not None:
                kwargs["depth_grid"] = depth_grid
            if args.combiner:
                kwargs["combiner_grid"] = (False, True)
            if name == "predict-resource" and args.net_capacity is not None:
                kwargs["net_capacity"] = args.net_capacity
            if name == "predict-elastic" and args.suspend:
                kwargs["suspend"] = True
            if args.drift_ledger:
                ledger = PredictionLedger()
                kwargs["ledger"] = ledger
            if args.load_models:
                # Fresh copy per policy: online refits mutate the db, and
                # a shared instance would make the comparison depend on
                # policy iteration order.
                kwargs["db"] = ModelDatabase.load(args.load_models)
        policy = get_policy(name, **kwargs)
        metrics = ClusterMetrics()
        cluster.metrics = metrics
        result = cluster.run(jobs, policy)
        m = result.metrics()
        all_metrics[name] = m
        service[name] = metrics.summary()
        service[name]["drift_alarms"] = getattr(policy, "n_drift_alarms", 0)
        if args.metrics_out:
            prom_registries[name] = metrics.registry
            all_metrics[name]["service"] = metrics.to_dict()
            if ledger is not None:
                all_metrics[name]["drift"] = ledger.to_dict()
        if args.trace_out:
            rec = SpanRecorder()
            rec.record(result)
            violations = rec.check()
            if violations:
                log.warning(
                    "span_tiling", policy=name, n=len(violations),
                    msg=f"{name}: {len(violations)} span-tiling "
                        f"violations (trace still exported)",
                )
            path = _trace_path(args.trace_out, name, len(names) > 1)
            rec.save_chrome(path)
            log.info(
                "trace_out", policy=name, path=path,
                msg=f"{name}: wrote Chrome trace -> {path}",
            )

        def f(x, nd=2):
            return "  n/a" if x is None else f"{x:.{nd}f}"

        halves = (
            f"{f(m['pred_mae_pct_first_half'], 1)}→"
            f"{f(m['pred_mae_pct_second_half'], 1)}"
            if m["pred_mae_pct"] is not None else "n/a"
        )
        depths = "+".join(
            f"{d}:{n}" for d, n in sorted(
                m["depth_histogram"].items(), key=lambda kv: int(kv[0])
            )
        )
        comb = "+".join(
            f"{k}:{n}" for k, n in sorted(m["combiner_histogram"].items())
        )
        print(
            f"{name:<18} {f(m['makespan_s']):>9} {f(m['mean_wait_s']):>7} "
            f"{f(m['mean_turnaround_s']):>10} {f(m['utilization']):>5} "
            f"{f(m['slo_attainment']):>5} {m['n_rejected']:>4} "
            f"{m['n_regrants']:>4} {f(m['pred_mae_pct'], 1):>6} "
            f"{halves:>18} {depths:>12} {comb:>11}"
        )
        if hasattr(policy, "db"):
            save_db = policy.db

    def g(x, nd=3):
        return "  n/a" if x is None else f"{x:.{nd}f}"

    shdr = (
        f"{'policy':<18} {'p50 trn':>8} {'p99 trn':>8} {'p50 wait':>8} "
        f"{'p99 wait':>8} {'goodput':>9} {'rgr ovh':>8} {'alarms':>6}"
    )
    print("\nservice metrics (streaming quantiles):")
    print(shdr)
    print("-" * len(shdr))
    for name, s in service.items():
        print(
            f"{name:<18} {g(s['p50_turnaround_s']):>8} "
            f"{g(s['p99_turnaround_s']):>8} {g(s['p50_wait_s']):>8} "
            f"{g(s['p99_wait_s']):>8} {g(s['goodput_tokens_per_s'], 0):>9} "
            f"{g(s['regrant_overhead_total_s']):>8} "
            f"{s['drift_alarms']:>6}"
        )
    if args.trace_out and args.workers <= 32:
        print("\nper-slot timeline (last policy):")
        print(render_slots(result))
    if args.save_models:
        if save_db is None or len(save_db) == 0:
            log.warning(
                "save_models",
                msg="no fitted models to save (only baseline policies ran)",
            )
        else:
            save_db.save(args.save_models)
            log.info(
                "save_models", n=len(save_db), path=args.save_models,
                msg=f"saved {len(save_db)} models -> {args.save_models}",
            )
    if args.metrics_out:
        if args.metrics_out.endswith(".prom"):
            for name in names:
                path = _trace_path(args.metrics_out, name, len(names) > 1)
                prom_registries[name].save_prom(path)
                log.info(
                    "metrics_out", policy=name, path=path,
                    msg=f"{name}: wrote Prometheus text -> {path}",
                )
        else:
            with open(args.metrics_out, "w") as fp:
                json.dump(
                    {n: all_metrics[n] for n in names}, fp,
                    indent=1, sort_keys=True,
                )
            log.info(
                "metrics_out", path=args.metrics_out,
                msg=f"wrote service metrics -> {args.metrics_out}",
            )
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(all_metrics, fp, indent=1, sort_keys=True)
        log.info(
            "json_out", path=args.json,
            msg=f"wrote metrics -> {args.json}",
        )


if __name__ == "__main__":
    main()
