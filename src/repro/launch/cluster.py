"""CLI driver for the predictive cluster scheduler.

    PYTHONPATH=src python -m repro.launch.cluster \
        --jobs 60 --workers 16 --policies fifo-static,predict-sjf

Runs the named scheduling policies over one shared deterministic trace and
prints a comparison table plus the online-refinement error trajectory.
``--save-models`` persists the fitted per-(app, platform, backend) models
(the paper's model database) so a later run — or a real long-lived
scheduler — can ``--load-models`` and skip the bootstrap profiling phase.
``--oracle engine`` wall-clocks the live MapReduce engine instead of the
analytic cost (small traces only: every distinct config compiles once).
``--overlap-depth 1,2,4`` widens every predictive policy's category grid
with the pipelined execution mode's overlap depth, so plans carry a
per-job depth choice (the ``depths`` column histograms what was picked).
``--elastic`` runs the trace on the :class:`repro.elastic.ElasticCluster`,
where the ``predict-elastic`` policy may preempt running jobs at wave
boundaries and shrink/grow their worker grants (``--ckpt-overhead`` /
``--restore-overhead`` price each move); other policies run unchanged on
the elastic simulator, so the comparison stays apples-to-apples.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    EngineOracle,
    POLICIES,
    PredictivePolicy,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.core.predictor import ModelDatabase
from repro.obs import (
    ClusterMetrics,
    PredictionLedger,
    SpanRecorder,
    get_logger,
    render_slots,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.cluster",
        description="Prediction-driven multi-job MapReduce scheduling",
    )
    ap.add_argument("--jobs", type=int, default=60)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--policies", default="all",
                    help="comma list of policy names, or 'all'")
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "uniform", "bursty"))
    ap.add_argument("--mean-interarrival", type=float, default=0.12)
    ap.add_argument("--size-min", type=int, default=1 << 14)
    ap.add_argument("--size-max", type=int, default=1 << 18)
    ap.add_argument("--deadline-fraction", type=float, default=0.6,
                    help="fraction of jobs carrying an SLO deadline")
    ap.add_argument("--slack", type=float, nargs=2, default=(1.2, 6.0),
                    metavar=("LO", "HI"),
                    help="deadline slack multiplier range")
    ap.add_argument("--noise", type=float, default=0.02,
                    help="analytic-oracle runtime noise (lognormal sigma)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--oracle", default="analytic",
                    choices=("analytic", "engine", "engine-traced",
                             "engine-sharded"),
                    help="'engine-traced' wall-clocks the live engine "
                         "through the telemetry path: completed jobs carry "
                         "per-phase traces and the online refiner fits "
                         "decomposed per-phase models; 'engine-sharded' "
                         "schedules the real shard_map mesh path (each "
                         "grant W runs on a W-device mesh — set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for CPU "
                         "emulation), traced, so per-phase wall times come "
                         "from the sharded engine")
    ap.add_argument("--overlap-depth", default=None, metavar="D1,D2,...",
                    help="overlap-depth grid for predictive policies "
                         "(e.g. '1,2,4'): each depth becomes one more "
                         "profiled category and plans carry the chosen "
                         "depth per job (default: policy-specific — "
                         "predict-pipeline tunes 1,2,4; others stay at 1)")
    ap.add_argument("--net-capacity", type=float, default=None,
                    help="fabric bytes/s budget for the predict-resource "
                         "policy (default: unconstrained = pure SJF)")
    ap.add_argument("--elastic", action="store_true",
                    help="run on the ElasticCluster: running jobs may be "
                         "preempted at wave boundaries and regranted "
                         "(the predict-elastic policy exploits this; "
                         "other policies behave as on the base cluster)")
    ap.add_argument("--ckpt-overhead", type=float, default=0.02,
                    help="simulated snapshot cost per preemption, seconds "
                         "(engine oracles override this with measured "
                         "save_snapshot walls)")
    ap.add_argument("--restore-overhead", type=float, default=0.02,
                    help="simulated restore cost per preemption, seconds "
                         "(engine oracles override this with measured "
                         "load_snapshot walls)")
    ap.add_argument("--suspend", action="store_true",
                    help="with --elastic: let predict-elastic suspend "
                         "best-effort jobs to disk (grant 0) when "
                         "shrinking cannot free enough workers for a "
                         "starved deadline job")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="export each policy's run as Chrome trace-event "
                         "JSON (open in Perfetto / chrome://tracing); with "
                         "several policies the policy name is suffixed "
                         "onto the stem.  Also prints the per-worker-slot "
                         "ASCII timeline for small clusters")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="write per-policy service metrics (streaming "
                         "p50/p99 turnaround + wait, goodput, regrant "
                         "overhead) as one JSON object keyed by policy")
    ap.add_argument("--drift-ledger", action="store_true",
                    help="attach a PredictionLedger to every predictive "
                         "policy: records predicted-vs-realized per "
                         "category, raises drift alarms, and triggers "
                         "category-targeted refits")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    ap.add_argument("--log-json", action="store_true",
                    help="emit status lines as JSON objects (one per "
                         "line) on stderr instead of human-readable text")
    ap.add_argument("--save-models", metavar="PATH",
                    help="persist the fitted ModelDatabase as JSON")
    ap.add_argument("--load-models", metavar="PATH",
                    help="warm-start predictive policies from a saved "
                         "ModelDatabase (skips bootstrap profiling)")
    ap.add_argument("--json", metavar="PATH",
                    help="also dump per-policy metrics as JSON")
    return ap


def _trace_path(base: str, policy: str, many: bool) -> str:
    if not many:
        return base
    root, ext = os.path.splitext(base)
    return f"{root}.{policy}{ext or '.json'}"


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    log = get_logger(
        "cluster", level=args.log_level, json_lines=args.log_json
    )
    depth_grid = None
    if args.overlap_depth is not None:
        depth_grid = tuple(
            int(d) for d in args.overlap_depth.split(",") if d.strip()
        )
    deep = depth_grid is not None and max(depth_grid) > 1
    if args.oracle in ("engine", "engine-traced", "engine-sharded"):
        if deep and args.oracle == "engine-sharded":
            raise SystemExit(
                "--overlap-depth > 1 is a single-controller schedule; "
                "it does not compose with --oracle engine-sharded"
            )
        oracle = EngineOracle(
            traced=args.oracle in ("engine-traced", "engine-sharded"),
            sharded=args.oracle == "engine-sharded",
            pipelined=deep,
        )
        log.info(
            "engine_oracle",
            msg="note: the engine oracle compiles every distinct "
                "(app, size, backend, M, R, W) once — predictive policies' "
                "bootstrap profiling alone is ~100+ compiles at the default "
                "grids; keep traces tiny and grids small",
        )
    else:
        oracle = AnalyticOracle(noise=args.noise, seed=args.seed)

    jobs = generate_workload(
        args.jobs, seed=args.seed, arrival=args.arrival,
        mean_interarrival=args.mean_interarrival,
        size_range=(args.size_min, args.size_max),
    )
    if args.deadline_fraction > 0:
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=tuple(args.slack), fraction=args.deadline_fraction,
            seed=args.seed + 1,
        )
    names = (sorted(POLICIES) if args.policies == "all"
             else args.policies.split(","))
    if args.elastic:
        from repro.elastic import ElasticCluster

        cluster = ElasticCluster(
            args.workers, oracle,
            snapshot_overhead_s=args.ckpt_overhead,
            restore_overhead_s=args.restore_overhead,
        )
    else:
        cluster = Cluster(args.workers, oracle)

    header = (
        f"{'policy':<18} {'makespan':>9} {'wait':>7} {'turnaround':>10} "
        f"{'util':>5} {'SLO':>5} {'rej':>4} {'rgr':>4} {'MAE%':>6} "
        f"{'MAE% 1st→2nd half':>18} {'depths':>12}"
    )
    log.info(
        "run",
        msg=f"{args.jobs} jobs, {args.workers} workers, "
            f"arrival={args.arrival}, oracle={oracle.platform}",
        jobs=args.jobs, workers=args.workers, arrival=args.arrival,
        oracle=oracle.platform,
    )
    print(header)
    print("-" * len(header))
    all_metrics: dict[str, dict] = {}
    service: dict[str, dict] = {}
    save_db = None
    for name in names:
        kwargs: dict = {}
        ledger = None
        if issubclass(POLICIES[name], PredictivePolicy):
            kwargs["seed"] = args.seed
            if depth_grid is not None:
                kwargs["depth_grid"] = depth_grid
            if name == "predict-resource" and args.net_capacity is not None:
                kwargs["net_capacity"] = args.net_capacity
            if name == "predict-elastic" and args.suspend:
                kwargs["suspend"] = True
            if args.drift_ledger:
                ledger = PredictionLedger()
                kwargs["ledger"] = ledger
            if args.load_models:
                # Fresh copy per policy: online refits mutate the db, and
                # a shared instance would make the comparison depend on
                # policy iteration order.
                kwargs["db"] = ModelDatabase.load(args.load_models)
        policy = get_policy(name, **kwargs)
        metrics = ClusterMetrics()
        cluster.metrics = metrics
        result = cluster.run(jobs, policy)
        m = result.metrics()
        all_metrics[name] = m
        service[name] = metrics.summary()
        service[name]["drift_alarms"] = getattr(policy, "n_drift_alarms", 0)
        if args.metrics_out:
            all_metrics[name]["service"] = metrics.to_dict()
            if ledger is not None:
                all_metrics[name]["drift"] = ledger.to_dict()
        if args.trace_out:
            rec = SpanRecorder()
            rec.record(result)
            violations = rec.check()
            if violations:
                log.warning(
                    "span_tiling", policy=name, n=len(violations),
                    msg=f"{name}: {len(violations)} span-tiling "
                        f"violations (trace still exported)",
                )
            path = _trace_path(args.trace_out, name, len(names) > 1)
            rec.save_chrome(path)
            log.info(
                "trace_out", policy=name, path=path,
                msg=f"{name}: wrote Chrome trace -> {path}",
            )

        def f(x, nd=2):
            return "  n/a" if x is None else f"{x:.{nd}f}"

        halves = (
            f"{f(m['pred_mae_pct_first_half'], 1)}→"
            f"{f(m['pred_mae_pct_second_half'], 1)}"
            if m["pred_mae_pct"] is not None else "n/a"
        )
        depths = "+".join(
            f"{d}:{n}" for d, n in sorted(
                m["depth_histogram"].items(), key=lambda kv: int(kv[0])
            )
        )
        print(
            f"{name:<18} {f(m['makespan_s']):>9} {f(m['mean_wait_s']):>7} "
            f"{f(m['mean_turnaround_s']):>10} {f(m['utilization']):>5} "
            f"{f(m['slo_attainment']):>5} {m['n_rejected']:>4} "
            f"{m['n_regrants']:>4} {f(m['pred_mae_pct'], 1):>6} "
            f"{halves:>18} {depths:>12}"
        )
        if hasattr(policy, "db"):
            save_db = policy.db

    def g(x, nd=3):
        return "  n/a" if x is None else f"{x:.{nd}f}"

    shdr = (
        f"{'policy':<18} {'p50 trn':>8} {'p99 trn':>8} {'p50 wait':>8} "
        f"{'p99 wait':>8} {'goodput':>9} {'rgr ovh':>8} {'alarms':>6}"
    )
    print("\nservice metrics (streaming quantiles):")
    print(shdr)
    print("-" * len(shdr))
    for name, s in service.items():
        print(
            f"{name:<18} {g(s['p50_turnaround_s']):>8} "
            f"{g(s['p99_turnaround_s']):>8} {g(s['p50_wait_s']):>8} "
            f"{g(s['p99_wait_s']):>8} {g(s['goodput_tokens_per_s'], 0):>9} "
            f"{g(s['regrant_overhead_total_s']):>8} "
            f"{s['drift_alarms']:>6}"
        )
    if args.trace_out and args.workers <= 32:
        print("\nper-slot timeline (last policy):")
        print(render_slots(result))
    if args.save_models:
        if save_db is None or len(save_db) == 0:
            log.warning(
                "save_models",
                msg="no fitted models to save (only baseline policies ran)",
            )
        else:
            save_db.save(args.save_models)
            log.info(
                "save_models", n=len(save_db), path=args.save_models,
                msg=f"saved {len(save_db)} models -> {args.save_models}",
            )
    if args.metrics_out:
        with open(args.metrics_out, "w") as fp:
            json.dump(
                {n: all_metrics[n] for n in names}, fp,
                indent=1, sort_keys=True,
            )
        log.info(
            "metrics_out", path=args.metrics_out,
            msg=f"wrote service metrics -> {args.metrics_out}",
        )
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(all_metrics, fp, indent=1, sort_keys=True)
        log.info(
            "json_out", path=args.json,
            msg=f"wrote metrics -> {args.json}",
        )


if __name__ == "__main__":
    main()
