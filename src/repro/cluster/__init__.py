"""Predictive cluster scheduling: the multi-job layer the paper motivates.

The paper models (config → total execution time) so that a scheduler can
make smarter decisions; this package is that scheduler.  Layering:

    workload.py — deterministic heterogeneous job traces (arrival
                  processes, log-uniform sizes, optional deadlines)
    streams.py  — open-ended arrival streams for service mode (diurnal /
                  bursty / flash-crowd rates, Poisson thinning,
                  ``JobStream``) consumed by ``Cluster.run_service``
    oracle.py   — "true" runtime sources: AnalyticOracle (closed-form,
                  Hadoop-shaped, per-job deterministic noise) and
                  EngineOracle (wall-clocks the live MapReduce engine)
    cluster.py  — event-driven simulator: W shared workers, per-job
                  grants, lifecycle accounting, invariant enforcement
    policies.py — FIFO baseline + prediction-driven policies (SJF,
                  deadline admission control) on a shared ModelDatabase,
                  with a name registry
    online.py   — continuous profiling: completed jobs refit the models

Entry points: ``python -m repro.launch.cluster`` (CLI),
``python -m benchmarks.run --sections cluster`` (policy comparison),
``examples/cluster_sim.py`` (walkthrough).
"""

from repro.cluster.cluster import (
    Cluster,
    Dispatch,
    JobRecord,
    Plan,
    Reject,
    TraceResult,
)
from repro.cluster.online import OnlineRefiner
from repro.cluster.streams import (
    JobStream,
    PoissonProcess,
    RenewalProcess,
    constant_rate,
    diurnal_rate,
    flash_crowd_rate,
    merge_processes,
    take,
)
from repro.cluster.oracle import AnalyticOracle, EngineOracle
from repro.cluster.policies import (
    POLICIES,
    DeadlineAware,
    ElasticDeadline,
    PredictedSJF,
    PredictiveFIFO,
    PredictivePolicy,
    ResourceAware,
    SchedulingPolicy,
    StaticFIFO,
    get_policy,
    register_policy,
)
from repro.cluster.workload import (
    APPS,
    JobSpec,
    assign_deadlines,
    generate_workload,
)

__all__ = [
    "APPS",
    "AnalyticOracle",
    "Cluster",
    "DeadlineAware",
    "Dispatch",
    "ElasticDeadline",
    "EngineOracle",
    "JobRecord",
    "JobSpec",
    "JobStream",
    "OnlineRefiner",
    "POLICIES",
    "Plan",
    "PoissonProcess",
    "PredictedSJF",
    "PredictiveFIFO",
    "PredictivePolicy",
    "Reject",
    "RenewalProcess",
    "ResourceAware",
    "SchedulingPolicy",
    "StaticFIFO",
    "TraceResult",
    "assign_deadlines",
    "constant_rate",
    "diurnal_rate",
    "flash_crowd_rate",
    "generate_workload",
    "get_policy",
    "merge_processes",
    "register_policy",
    "take",
]
