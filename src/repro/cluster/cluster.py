"""Job queue + cluster state: an event-driven multi-job simulator.

``Cluster`` owns W worker slots shared across concurrent jobs.  Time
advances event-to-event (job arrival / job completion); at every event the
active :mod:`scheduling policy <repro.cluster.policies>` is offered the
queue of arrived-but-undispatched jobs and the free-worker count, and
answers with dispatch decisions (job + :class:`Plan`) or admission-control
rejections until nothing more fits.  A dispatched job's *true* runtime
comes from the :mod:`runtime oracle <repro.cluster.oracle>`; the policy's
*predicted* runtime is recorded next to it, which is how every trace doubles
as an accuracy experiment (paper Fig. 3, per job instead of per config).

Invariants enforced here, not trusted to policies: a plan never exceeds
free workers, every job ends exactly once, worker accounting conserves, and
a policy that strands undispatchable jobs fails loudly instead of spinning.
"""

from __future__ import annotations

import dataclasses
import heapq
import math

from repro.cluster.workload import JobSpec


@dataclasses.dataclass(frozen=True)
class Plan:
    """Dispatch decision for one job: execution config + worker grant."""

    backend: str
    mappers: int
    reducers: int
    workers: int                      # worker slots granted from the pool
    predicted_time: float | None = None  # policy's prediction, if it made one
    depth: int = 1                    # pipelined overlap depth (1 = serial)
    combiner: bool = False            # map-side combine stage on/off

    def __post_init__(self):
        if self.mappers < 1 or self.reducers < 1 or self.workers < 1:
            raise ValueError(f"bad plan {self}")
        if self.depth < 1:
            raise ValueError(f"bad plan {self}")


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """Policy answer: run ``job`` now under ``plan``."""

    job: JobSpec
    plan: Plan


@dataclasses.dataclass(frozen=True)
class Reject:
    """Policy answer: admission control refuses ``job`` (e.g. its deadline
    is infeasible at every configuration)."""

    job: JobSpec
    reason: str


@dataclasses.dataclass
class JobRecord:
    """Full lifecycle accounting for one job."""

    spec: JobSpec
    plan: Plan | None = None
    admitted: bool = True
    reject_reason: str | None = None
    reject_time: float | None = None
    start: float | None = None
    finish: float | None = None
    true_time: float | None = None
    #: per-phase JobTrace from the oracle (when it supports take_trace),
    #: consumed by the online per-phase refit loop.
    trace: object | None = None
    #: elastic accounting (ElasticCluster): execution segments as
    #: [t_start, t_end, workers] triples (grant changes split segments;
    #: checkpoint/restore gaps between them hold workers but do no work),
    #: the number of regrants applied, and the total overhead paid.
    segments: list | None = None
    #: executed wave intervals [t0, t1, kind, workers] and non-executing
    #: holes [t0, t1, kind, workers_held] between segments (regrant /
    #: suspended), recorded by the elastic sim for the span exporter —
    #: together with ``segments`` they tile [start, finish] exactly.
    waves: list | None = None
    gaps: list | None = None
    n_regrants: int = 0
    n_suspends: int = 0
    overhead_s: float = 0.0
    #: seconds this job's shuffle was stretched by shared-fabric
    #: contention (0.0 on uncontended runs / capacity-unlimited clusters);
    #: audited in the trace as its own ``contention`` phase.
    contention_s: float = 0.0

    @property
    def completed(self) -> bool:
        return self.finish is not None

    @property
    def wait(self) -> float | None:
        return None if self.start is None else self.start - self.spec.arrival

    @property
    def turnaround(self) -> float | None:
        return None if self.finish is None else self.finish - self.spec.arrival

    @property
    def met_deadline(self) -> bool | None:
        """True/False for deadline jobs (rejected/unfinished = missed);
        None when the job has no deadline."""
        if self.spec.deadline is None:
            return None
        return self.completed and self.finish <= self.spec.deadline

    @property
    def prediction_error_pct(self) -> float | None:
        """|predicted - true| / true in percent (paper's error metric)."""
        if (
            self.plan is None
            or self.plan.predicted_time is None
            or self.true_time is None
        ):
            return None
        return abs(self.plan.predicted_time - self.true_time) / max(
            self.true_time, 1e-12
        ) * 100.0


@dataclasses.dataclass
class TraceResult:
    """One policy's run over one trace, plus derived summary metrics."""

    policy: str
    total_workers: int
    records: list[JobRecord]          # arrival order
    #: fabric capacity the run was priced against (None = unlimited) and
    #: the over-capacity episodes the shared fabric logged — carried on
    #: the result so the span/resource exporters need no extra plumbing.
    net_capacity: float | None = None
    contention_episodes: list = dataclasses.field(default_factory=list)

    def completed(self) -> list[JobRecord]:
        return [r for r in self.records if r.completed]

    def rejected(self) -> list[JobRecord]:
        return [r for r in self.records if not r.admitted]

    def prediction_errors(self) -> list[float]:
        """Per-job |pred-true|/true %, in completion order — the in-trace
        error trajectory the online-refinement loop is judged on."""
        done = sorted(self.completed(), key=lambda r: r.finish)
        return [
            e for r in done if (e := r.prediction_error_pct) is not None
        ]

    def metrics(self) -> dict:
        done = self.completed()
        if not done:
            raise RuntimeError(f"policy {self.policy!r} completed no jobs")
        t0 = min(r.spec.arrival for r in self.records)
        t_end = max(r.finish for r in done)
        makespan = t_end - t0
        # Elastic jobs carry per-segment grants; busy area sums actual
        # (duration x granted workers) per segment, excluding the
        # checkpoint/restore gaps (workers held but idle).
        busy_area = sum(
            sum((t1 - ts) * w for ts, t1, w in r.segments)
            if r.segments else r.true_time * r.plan.workers
            for r in done
        )
        deadline_jobs = [
            r for r in self.records if r.spec.deadline is not None
        ]
        errs = self.prediction_errors()
        half = len(errs) // 2
        mean = lambda xs: sum(xs) / len(xs) if xs else None  # noqa: E731
        return {
            "policy": self.policy,
            "n_jobs": len(self.records),
            "n_completed": len(done),
            "n_rejected": len(self.rejected()),
            "makespan_s": makespan,
            "mean_wait_s": mean([r.wait for r in done]),
            "mean_turnaround_s": mean([r.turnaround for r in done]),
            "utilization": busy_area / (self.total_workers * makespan),
            "slo_attainment": (
                mean([1.0 if r.met_deadline else 0.0 for r in deadline_jobs])
                if deadline_jobs else None
            ),
            "n_deadline_jobs": len(deadline_jobs),
            "pred_mae_pct": mean(errs),
            "pred_mae_pct_first_half": mean(errs[:half]),
            "pred_mae_pct_second_half": mean(errs[half:]),
            # Which overlap depths the policy actually dispatched (all 1s
            # for depth-unaware policies).
            "depth_histogram": {
                str(r.plan.depth): sum(
                    1 for q in done if q.plan.depth == r.plan.depth
                )
                for r in done
            },
            # Combiner-choice split (all "off" for combiner-unaware
            # policies) — how often the map-side combine axis paid off.
            "combiner_histogram": {
                ("on" if r.plan.combiner else "off"): sum(
                    1 for q in done if q.plan.combiner == r.plan.combiner
                )
                for r in done
            },
            # Elastic accounting (0 / 0.0 on inelastic runs).
            "n_regrants": sum(r.n_regrants for r in self.records),
            "n_preempted_jobs": sum(
                1 for r in self.records if r.n_regrants > 0
            ),
            "n_suspends": sum(r.n_suspends for r in self.records),
            "regrant_overhead_s": sum(r.overhead_s for r in self.records),
            # Shared-fabric contention accounting (zeros when the run had
            # no finite net_capacity).
            "contention_s_total": sum(
                r.contention_s for r in self.records
            ),
            "n_contended_jobs": sum(
                1 for r in self.records if r.contention_s > 0
            ),
            "n_contention_episodes": len(self.contention_episodes),
        }


class _JobSource:
    """Peekable, order-validated view over a (possibly unbounded) JobSpec
    iterator — the arrival side of the event loops.  Finite traces wrap a
    sorted list; service mode wraps an open-ended stream bounded by a
    horizon, and the loop materializes one arrival of lookahead at a
    time instead of the whole trace."""

    __slots__ = ("_it", "_next", "_seen", "_last_arrival")

    def __init__(self, jobs):
        self._it = iter(jobs)
        self._seen: set[int] = set()
        self._last_arrival = -math.inf
        self._next: JobSpec | None = None
        self._advance()

    def _advance(self) -> None:
        nxt = next(self._it, None)
        if nxt is not None:
            if nxt.job_id in self._seen:
                raise ValueError("duplicate job_id in trace")
            if nxt.arrival < self._last_arrival:
                raise ValueError(
                    f"job {nxt.job_id} arrives at {nxt.arrival:.6f}, "
                    "before its predecessor — streams must be time-ordered"
                )
            self._seen.add(nxt.job_id)
            self._last_arrival = nxt.arrival
        self._next = nxt

    def peek(self) -> JobSpec | None:
        return self._next

    def pop(self) -> JobSpec:
        job = self._next
        self._advance()
        return job


def _bounded(stream, until_time, until_jobs):
    """Cut an open-ended stream at the service horizon: stop *admitting*
    after ``until_jobs`` arrivals or the first arrival past
    ``until_time`` (whichever comes first); the sim then drains."""
    n = 0
    for job in stream:
        if until_jobs is not None and n >= until_jobs:
            return
        if until_time is not None and job.arrival > until_time:
            return
        n += 1
        yield job


class Cluster:
    """W worker slots + a runtime oracle; runs (trace, policy) -> result.

    With a finite ``net_capacity`` (bytes/s) concurrent jobs share one
    shuffle fabric: each dispatched job's shuffle transfer is priced on a
    :class:`repro.cluster.oracle.SharedFabric`, and when aggregate demand
    exceeds capacity the job's shuffle stretches by the fair-share
    slowdown.  The stretch is added to the job's true time and audited in
    its trace as a ``contention`` phase, so phase walls still sum to the
    turnaround and span tiling closes.  This requires an oracle whose
    completed jobs carry per-phase traces with net counters
    (``prices_contention``); the constructor refuses the combination
    otherwise instead of silently skipping the charge.
    """

    def __init__(self, total_workers: int, oracle, *, metrics=None,
                 net_capacity: float | None = None):
        if total_workers < 1:
            raise ValueError("total_workers must be >= 1")
        self.total_workers = int(total_workers)
        self.oracle = oracle
        #: optional :class:`repro.obs.metrics.ClusterMetrics` hook object;
        #: None (the default) keeps every event unobserved at the cost of
        #: one ``if`` per event.
        self.metrics = metrics
        self.net_capacity = (
            None if net_capacity is None or math.isinf(net_capacity)
            else float(net_capacity)
        )
        if self.net_capacity is not None:
            if not self.net_capacity > 0:
                raise ValueError(
                    f"net_capacity must be > 0, got {net_capacity!r}"
                )
            if not getattr(oracle, "prices_contention", False):
                platform = getattr(
                    oracle, "platform", type(oracle).__name__
                )
                raise ValueError(
                    f"net_capacity set, but oracle {platform!r} cannot "
                    "price contention: completed jobs carry no per-phase "
                    "net counters (use the analytic oracle or a traced "
                    "engine oracle)"
                )

    def run(self, jobs: list[JobSpec], policy) -> TraceResult:
        jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
        if len({j.job_id for j in jobs}) != len(jobs):
            raise ValueError("duplicate job_id in trace")
        return self._run(jobs, policy, sorted({j.app for j in jobs}))

    def run_service(
        self,
        stream,
        policy,
        *,
        until_time: float | None = None,
        until_jobs: int | None = None,
        apps: list[str] | None = None,
        health_every: float | None = None,
        on_health=None,
    ) -> TraceResult:
        """Serve an open-ended arrival stream up to a horizon, then drain.

        ``stream`` is any time-ordered iterable of :class:`JobSpec`
        (see :mod:`repro.cluster.streams`); arrivals stop at
        ``until_time`` sim seconds and/or after ``until_jobs`` arrivals —
        at least one bound is required — and jobs already admitted run to
        completion.  Jobs are materialized incrementally (one of
        lookahead), so memory tracks the *live* set, not the horizon.
        ``apps`` defaults to the stream's ``apps`` attribute (needed for
        ``policy.prepare`` before any job exists).  Every
        ``health_every`` sim seconds ``on_health(now, snapshot)`` fires
        with queue/worker/suspension gauges — the CLI's periodic health
        table and the natural place to read windowed SLO metrics.
        """
        if until_time is None and until_jobs is None:
            raise ValueError(
                "run_service needs until_time and/or until_jobs — an "
                "unbounded service never returns"
            )
        if apps is None:
            apps = list(getattr(stream, "apps", ()) or ())
        if not apps:
            raise ValueError(
                "run_service needs the app universe up front: pass "
                "apps=[...] or use a stream with an .apps attribute"
            )
        if health_every is not None and health_every <= 0:
            raise ValueError("health_every must be > 0")
        return self._run(
            _bounded(stream, until_time, until_jobs), policy, sorted(apps),
            health_every=health_every, on_health=on_health,
        )

    def _health_snapshot(
        self, now: float, pending, free: int, suspended: int = 0
    ) -> dict:
        snap = {
            "t": now,
            "queue_depth": len(pending),
            "busy_workers": self.total_workers - free,
            "free_workers": free,
            "suspended_jobs": suspended,
        }
        if self.metrics is not None:
            windowed = self.metrics.windowed_summary(now)
            if windowed is not None:
                snap["windowed"] = windowed
        return snap

    def _run(
        self, jobs, policy, apps, *, health_every=None, on_health=None
    ) -> TraceResult:
        source = _JobSource(jobs)
        records: dict[int, JobRecord] = {}
        order: list[int] = []         # job_ids in arrival order
        fabric = None
        if self.net_capacity is not None:
            from repro.cluster.oracle import SharedFabric

            # Per-run state: one run's transfers must not price another's.
            fabric = SharedFabric(self.net_capacity)
        policy.prepare(self, apps)

        pending: list[JobSpec] = []   # arrived, not yet dispatched (FIFO order)
        running: list[tuple[float, int, int]] = []  # (finish, seq, job_id)
        free = self.total_workers
        seq = 0     # heap tiebreak
        first = source.peek()
        now = first.arrival if first is not None else 0.0
        next_health = (
            now + health_every if health_every is not None else None
        )
        metrics = self.metrics
        if metrics is not None:
            metrics.on_run_start(now)

        while source.peek() is not None or pending or running:
            nxt = source.peek()
            next_arrival = nxt.arrival if nxt is not None else math.inf
            next_finish = running[0][0] if running else math.inf
            if pending and not running and next_arrival == math.inf:
                # Nothing can ever free workers or arrive: the policy has
                # stranded jobs it will never dispatch.
                stuck = [j.job_id for j in pending]
                raise RuntimeError(
                    f"policy {policy.name!r} stranded jobs {stuck}: no "
                    f"dispatch at free={free}/{self.total_workers} workers"
                )
            now = min(next_arrival, next_finish)

            while (nxt := source.peek()) is not None and nxt.arrival <= now:
                job = source.pop()
                records[job.job_id] = JobRecord(spec=job)
                order.append(job.job_id)
                pending.append(job)
                if metrics is not None:
                    metrics.on_arrival(job.arrival, job)
            while running and running[0][0] <= now:
                _, _, done_id = heapq.heappop(running)
                rec = records[done_id]
                rec.finish = rec.start + rec.true_time
                free += rec.plan.workers
                if metrics is not None:
                    metrics.on_finish(rec.finish, rec)
                policy.observe(rec)

            while pending:
                decision = policy.select(tuple(pending), free, now)
                if decision is None:
                    break
                if isinstance(decision, Reject):
                    rec = records[decision.job.job_id]
                    rec.admitted = False
                    rec.reject_reason = decision.reason
                    rec.reject_time = now
                    pending.remove(decision.job)
                    if metrics is not None:
                        metrics.on_reject(now, rec)
                    continue
                if not isinstance(decision, Dispatch):
                    raise TypeError(
                        f"policy returned {type(decision).__name__}; "
                        "expected Dispatch, Reject, or None"
                    )
                job, plan = decision.job, decision.plan
                if job not in pending:
                    raise ValueError(
                        f"policy dispatched job {job.job_id} not in queue"
                    )
                if plan.workers > free:
                    raise ValueError(
                        f"plan for job {job.job_id} wants {plan.workers} "
                        f"workers but only {free} are free"
                    )
                pending.remove(job)
                rec = records[job.job_id]
                rec.plan = plan
                rec.start = now
                # Off-default knobs stay out of the call so knob-unaware
                # oracle stand-ins (tests, stubs) keep narrow signatures.
                extra = {"depth": plan.depth} if plan.depth != 1 else {}
                if plan.combiner:
                    extra["combiner"] = True
                rec.true_time = self.oracle.time(
                    job.app, plan.backend, job.size,
                    plan.mappers, plan.reducers, plan.workers,
                    job_id=job.job_id, **extra,
                )
                take_trace = getattr(self.oracle, "take_trace", None)
                if take_trace is not None:
                    rec.trace = take_trace()
                if fabric is not None:
                    _charge_contention(fabric, rec, now)
                free -= plan.workers
                seq += 1
                heapq.heappush(running, (now + rec.true_time, seq, job.job_id))
                if metrics is not None:
                    metrics.on_dispatch(now, rec)
            if fabric is not None:
                fabric.prune(now)
            if metrics is not None:
                metrics.sample(
                    now, len(pending), self.total_workers - free, 0,
                    net_bytes_per_s=(
                        fabric.demand_at(now) if fabric is not None
                        else None
                    ),
                    net_capacity=self.net_capacity,
                )
            if next_health is not None and now >= next_health:
                if on_health is not None:
                    on_health(
                        now, self._health_snapshot(now, pending, free)
                    )
                while next_health <= now:
                    next_health += health_every

        assert free == self.total_workers, "worker accounting leaked"
        return TraceResult(
            policy=policy.name,
            total_workers=self.total_workers,
            records=[records[job_id] for job_id in order],
            net_capacity=self.net_capacity,
            contention_episodes=(
                list(fabric.episodes) if fabric is not None else []
            ),
        )


def _charge_contention(fabric, rec: JobRecord, now: float) -> float:
    """Price ``rec``'s shuffle transfer on the shared fabric at dispatch.

    The transfer window opens after the phases recorded ahead of the
    shuffle entry (the map phase) and nominally lasts the shuffle wall.
    Any fair-share stretch is added to the job's true time and audited as
    a ``contention`` phase right after the shuffle — walls still sum to
    the turnaround, so conservation and span tiling keep closing.  Jobs
    without a usable trace (no shuffle entry, zero net bytes) simply
    don't occupy the fabric.
    """
    trace = rec.trace
    if trace is None or "shuffle" not in trace.phase_names():
        return 0.0
    sh = trace.phase("shuffle")
    nbytes = sh.counters.get(
        "net_bytes", sh.counters.get("bytes_in", 0.0)
    )
    if nbytes <= 0 or sh.wall_s <= 0:
        return 0.0
    pre = 0.0
    for p in trace.phases:
        if p.phase == "shuffle":
            break
        pre += max(0.0, p.wall_s)
    stretch = fabric.admit(
        rec.spec.job_id, now + pre, sh.wall_s, nbytes
    )
    if stretch <= 0.0:
        return 0.0
    # Audited stall: no fabric bytes of its own, no CPU burned — the job
    # is waiting on its fair share of the wire.
    trace.record_phase(
        "contention", stretch,
        net_bytes=0.0, cpu_s=0.0, cpu_workers=1.0,
        fabric_capacity=fabric.capacity,
    )
    trace.phases.insert(
        trace.phases.index(sh) + 1, trace.phases.pop()
    )
    if trace.total_s is not None:
        trace.finish(trace.total_s + stretch)
    rec.contention_s = stretch
    rec.true_time += stretch
    return stretch
