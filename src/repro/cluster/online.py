"""Online model refinement: the paper's profiling phase made continuous.

The paper profiles, fits, predicts — once.  A running cluster gets a free
profiling experiment with *every completed job*: the (config, observed
runtime) pair is exactly one row of the paper's experiment set.
``OnlineRefiner`` accumulates those rows per (application, backend), refits
the regression incrementally, and republishes the model into the shared
:class:`~repro.core.predictor.ModelDatabase` — so the very next scheduling
decision uses a model trained on everything the cluster has seen so far,
and prediction error shrinks over the trace (measured by
``TraceResult.metrics()['pred_mae_pct_first_half' / '_second_half']``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import regression
from repro.core.features import fit_feature_spec
from repro.core.predictor import ModelDatabase

#: fit options shared with ``core.tuner.tune`` defaults: the refiner must be
#: robust unattended, so scaling + tiny ridge + cross terms are on.
DEFAULT_FIT_KWARGS = dict(degree=3, scale=True, lam=1e-6, cross_terms=True)

#: per-phase time models use a leaner basis: each phase is individually
#: smoother than the total (the non-monotonic wave-quantization kinks live
#: mostly in map/reduce, not in every phase), and live traces accumulate
#: slowly — a quadratic no-cross basis (9 features for 4 params) reaches
#: the 2x determinacy margin within a realistic trace.
DEFAULT_PHASE_FIT_KWARGS = dict(degree=2, scale=True, lam=1e-6,
                                cross_terms=False)


class OnlineRefiner:
    """Accumulate per-(app, backend) observations; refit into the shared db.

    ``seed_profiles`` installs the bootstrap profiling set (the offline
    phase); ``observe`` appends one completed job and refits every
    ``refit_every`` observations once the running total can determine the
    feature count.  ``max_points`` optionally keeps only the most recent
    window (bootstrap rows are never evicted — they anchor the fit in
    regions the live workload hasn't visited yet).
    """

    def __init__(
        self,
        db: ModelDatabase,
        platform: str,
        *,
        refit_every: int = 1,
        max_points: int | None = None,
        fit_kwargs: dict | None = None,
        phase_fit_kwargs: dict | None = None,
        phase_refit_every: int | None = None,
    ):
        if refit_every < 1:
            raise ValueError("refit_every must be >= 1")
        self.db = db
        self.platform = platform
        self.refit_every = refit_every
        self.max_points = max_points
        self.fit_kwargs = dict(fit_kwargs or DEFAULT_FIT_KWARGS)
        self.phase_fit_kwargs = dict(
            phase_fit_kwargs or DEFAULT_PHASE_FIT_KWARGS
        )
        # Phase models never drive plan selection, so they refit at a
        # slower cadence than the dispatch-critical total-time model —
        # one fit per phase per cadence, on the full history, is the cost.
        self.phase_refit_every = (
            max(5, refit_every) if phase_refit_every is None
            else phase_refit_every
        )
        if self.phase_refit_every < 1:
            raise ValueError("phase_refit_every must be >= 1")
        # (app, backend) -> [bootstrap rows (np.ndarray), ...], observations
        self._seed: dict[tuple[str, str], tuple[np.ndarray, np.ndarray]] = {}
        self._obs: dict[tuple[str, str], list[tuple[np.ndarray, float]]] = {}
        self._since_refit: dict[tuple[str, str], int] = {}
        self.n_refits = 0
        self.n_drift_refits = 0
        # (app, backend, phase) -> per-phase time observations (telemetry).
        self._phase_obs: dict[
            tuple[str, str, str], list[tuple[np.ndarray, float]]
        ] = {}
        self._phase_since_refit: dict[tuple[str, str], int] = {}
        self.n_phase_refits = 0

    def seed_profiles(
        self, app: str, backend: str, params: np.ndarray, times: np.ndarray
    ) -> None:
        self._seed[(app, backend)] = (
            np.asarray(params, dtype=np.float64),
            np.asarray(times, dtype=np.float64),
        )
        self._obs.setdefault((app, backend), [])

    def training_set(
        self, app: str, backend: str
    ) -> tuple[np.ndarray, np.ndarray]:
        """Bootstrap profiles + live observations, as fit-ready arrays."""
        key = (app, backend)
        obs = self._obs.get(key, [])
        if self.max_points is not None:
            obs = obs[-self.max_points:]
        rows = [row for row, _ in obs]
        times = [t for _, t in obs]
        if key in self._seed:
            seed_p, seed_t = self._seed[key]
            rows = list(seed_p) + rows
            times = list(seed_t) + times
        return np.asarray(rows, dtype=np.float64), np.asarray(
            times, dtype=np.float64
        )

    def n_observations(self, app: str, backend: str) -> int:
        return len(self._obs.get((app, backend), []))

    def observe(
        self, app: str, backend: str, params_row, observed_time: float
    ) -> bool:
        """Record one completed job; refit + republish when due.

        Returns True when the database model was actually updated, so the
        caller (a scheduling policy) can invalidate cached predictions.
        """
        key = (app, backend)
        self._obs.setdefault(key, []).append(
            (np.asarray(params_row, dtype=np.float64), float(observed_time))
        )
        self._since_refit[key] = self._since_refit.get(key, 0) + 1
        if self._since_refit[key] < self.refit_every:
            return False
        params, times = self.training_set(app, backend)
        spec_probe = fit_feature_spec(
            params,
            degree=self.fit_kwargs.get("degree", 3),
            cross_terms=self.fit_kwargs.get("cross_terms", False),
        )
        # Without bootstrap rows to anchor the fit (warm-started from a
        # saved ModelDatabase), live observations cluster at the few
        # argmin-chosen configs and can leave the design matrix badly
        # rank-deficient even once it is square — demand a 2x margin
        # before replacing a loaded model.
        min_rows = spec_probe.n_features * (1 if key in self._seed else 2)
        if params.shape[0] < min_rows:
            return False  # still underdetermined; keep the current model
        model = regression.fit(params, times, **self.fit_kwargs)
        self.db.put(app, self.platform, model, backend=backend)
        self._since_refit[key] = 0
        self.n_refits += 1
        return True

    # ---- drift response (repro.obs.drift alarms) ------------------------

    def refit_category(
        self,
        app: str,
        category: str,
        *,
        keep_last: int | None = None,
        drop_seed: bool = True,
        scale_hint: float | None = None,
    ) -> bool:
        """Category-targeted refit in response to a drift alarm.

        A drifted category means its historical rows — above all the
        bootstrap seed anchors, profiled *before* the shift — now describe
        a platform that no longer exists, so the every-completion
        :meth:`observe` path (which keeps them as anchors) cannot recover:
        a handful of post-shift rows never outweighs hundreds of stale
        ones.  This method evicts the seed anchors (``drop_seed``), trims
        the live history to the most recent ``keep_last`` rows, and refits
        from what remains.  When too few rows survive for a determinable
        fit, the published model's coefficient vector is rescaled by
        ``scale_hint`` (the ledger's EWMA of realized/predicted) instead —
        predictions are linear in ``coef``, so for the canonical
        multiplicative platform shift this one-line correction is already
        the right answer, available from the very first alarm.

        Returns True when the database model was updated (the caller must
        invalidate cached plans).
        """
        key = (app, category)
        if drop_seed:
            self._seed.pop(key, None)
        if keep_last is not None and key in self._obs:
            self._obs[key] = self._obs[key][-int(keep_last):]
        params, times = self.training_set(app, category)
        if params.shape[0]:
            spec_probe = fit_feature_spec(
                params,
                degree=self.fit_kwargs.get("degree", 3),
                cross_terms=self.fit_kwargs.get("cross_terms", False),
            )
            if params.shape[0] >= 2 * spec_probe.n_features:
                model = regression.fit(params, times, **self.fit_kwargs)
                self.db.put(app, self.platform, model, backend=category)
                self._since_refit[key] = 0
                self.n_drift_refits += 1
                return True
        if scale_hint is None or scale_hint <= 0:
            return False
        try:
            current = self.db.get(app, self.platform, backend=category)
        except KeyError:
            return False
        rescaled = dataclasses.replace(
            current,
            coef=np.asarray(current.coef, dtype=np.float64) * scale_hint,
        )
        self.db.put(app, self.platform, rescaled, backend=category)
        self._since_refit[key] = 0
        self.n_drift_refits += 1
        return True

    # ---- per-phase refinement (telemetry traces) ------------------------

    def observe_phases(
        self,
        app: str,
        backend: str,
        params_row,
        phase_times: dict[str, float],
    ) -> bool:
        """Record one completed job's per-phase wall times; refit the
        decomposed per-phase time models when due.

        Every completed job whose oracle returns a
        :class:`repro.telemetry.JobTrace` contributes one row per phase;
        once enough rows accumulate, one
        :class:`~repro.core.regression.RegressionModel` per phase is
        (re)fitted and published into the database under the telemetry
        layer's resource-qualified keys (``"<phase>:time_s"``) — the
        continuous analogue of ``telemetry.models.fit_phase_models``.
        Returns True when the models were republished.
        """
        from repro.telemetry.models import phase_resource_key

        row = np.asarray(params_row, dtype=np.float64)
        for phase, t in phase_times.items():
            self._phase_obs.setdefault((app, backend, phase), []).append(
                (row, float(t))
            )
        key = (app, backend)
        self._phase_since_refit[key] = self._phase_since_refit.get(key, 0) + 1
        if self._phase_since_refit[key] < self.phase_refit_every:
            return False
        phases = sorted(
            p for (a, b, p) in self._phase_obs if (a, b) == key
        )
        if not phases:
            return False
        refitted = False
        for phase in phases:
            obs = self._phase_obs[(app, backend, phase)]
            if self.max_points is not None:
                obs = obs[-self.max_points:]
            params = np.asarray([r for r, _ in obs], dtype=np.float64)
            times = np.asarray([t for _, t in obs], dtype=np.float64)
            spec_probe = fit_feature_spec(
                params,
                degree=self.phase_fit_kwargs.get("degree", 2),
                cross_terms=self.phase_fit_kwargs.get("cross_terms", False),
            )
            # No bootstrap anchor rows exist for phases: always demand the
            # 2x determinacy margin (see ``observe``).
            if params.shape[0] < 2 * spec_probe.n_features:
                continue
            model = regression.fit(params, times, **self.phase_fit_kwargs)
            self.db.put(
                app, self.platform, model, backend=backend,
                resource=phase_resource_key(phase),
            )
            refitted = True
        if refitted:
            self._phase_since_refit[key] = 0
            self.n_phase_refits += 1
        return refitted
