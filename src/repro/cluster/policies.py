"""Scheduling policies: where a prediction becomes a decision.

Every policy answers one question at every scheduling event: *given the
queue of arrived jobs and the free workers, what runs next and under which
configuration?*  The baseline answers it the way the paper's motivation
section says real clusters do — first-come-first-served with a static
config.  The prediction-driven policies close the paper's loop instead:

* bootstrap: :func:`repro.core.tuner.tune_categorical` profiles the runtime
  oracle per (application, backend) over a (M, R, W-share, size) space and
  publishes the per-backend fitted models into a shared
  :class:`~repro.core.predictor.ModelDatabase` (paper Fig. 2a+2b, one slot
  per category);
* per job: the stored models are evaluated over the configuration grid at
  the job's size and the joint (backend, M, R, W) argmin becomes the
  dispatch :class:`~repro.cluster.cluster.Plan`, with its predicted time
  attached — prediction before dispatch, the paper's "smarter scheduler";
* online: every completion flows through
  :class:`~repro.cluster.online.OnlineRefiner`, so the models sharpen as
  the cluster runs.

Policies register by name (same idiom as the MapReduce backend
registries): ``@register_policy`` + ``get_policy(name, **kwargs)``.
"""

from __future__ import annotations

import itertools
import math

import numpy as np

from repro.cluster.cluster import Dispatch, Plan, Reject
from repro.cluster.online import DEFAULT_FIT_KWARGS, OnlineRefiner
from repro.cluster.oracle import PROFILE_JOB_ID
from repro.cluster.workload import JobSpec
from repro.core.predictor import ModelDatabase
from repro.core.regression import RegressionModel, fit as regression_fit
from repro.core.tuner import tune_categorical

#: size feature is in kilotokens: same order of magnitude as M/R/W, which
#: keeps the scaled polynomial basis well-conditioned.
SIZE_UNIT = 1024.0


def _cat_key(backend: str, depth: int = 1, combiner: bool = False) -> str:
    """Category key for the (backend, overlap_depth, combiner) model slot.

    Depth and the combiner join the model database the same way the
    backend did: as *categorical* axes — one polynomial model per
    category value (the paper's numeric basis can't embed them; see
    ``tune_categorical``).  Depth 1 keys as the bare backend and the
    combiner-off key carries no suffix, so existing on-disk databases
    and every depth/combiner-unaware policy keep their exact legacy
    keys; combiner-on appends ``+c`` (``"xla@d2+c"``, ``"jnp+c"``)."""
    d = int(depth)
    key = backend if d == 1 else f"{backend}@d{d}"
    return f"{key}+c" if combiner else key


def _parse_cat(key: str) -> tuple[str, int, bool]:
    """Inverse of :func:`_cat_key`: ``"xla@d2+c" -> ("xla", 2, True)``."""
    combiner = key.endswith("+c")
    if combiner:
        key = key[:-2]
    backend, _, d = key.partition("@d")
    return backend, int(d) if d else 1, combiner


def _np_design(spec, rows: np.ndarray) -> np.ndarray:
    """Numpy twin of ``features.design_matrix`` for hot scheduler loops.

    The jnp version pays device-dispatch latency per call; the scheduler
    evaluates tiny (≤ a few hundred rows) grids thousands of times per
    trace, where numpy is orders of magnitude faster.  Kept in lockstep
    with ``FeatureSpec`` by ``tests/test_cluster.py``.
    """
    p = np.asarray(rows, dtype=np.float64)
    if p.ndim == 1:
        p = p[None, :]
    if spec.scale:
        lo = np.asarray(spec.lo)
        hi = np.asarray(spec.hi)
        p = (p - lo) / (hi - lo)
    cols = [np.ones((p.shape[0], 1))]
    for i in range(spec.n_params):
        pi = p[:, i:i + 1]
        acc = pi
        for _ in range(spec.degree):
            cols.append(acc)
            acc = acc * pi
    if spec.cross_terms:
        for i in range(spec.n_params):
            for j in range(i + 1, spec.n_params):
                cols.append(p[:, i:i + 1] * p[:, j:j + 1])
    return np.concatenate(cols, axis=1)


def _np_predict(model: RegressionModel, rows: np.ndarray) -> np.ndarray:
    return _np_design(model.spec, rows) @ np.asarray(
        model.coef, dtype=np.float64
    )


class SchedulingPolicy:
    """Interface the :class:`~repro.cluster.cluster.Cluster` drives."""

    name: str = "abstract"

    def prepare(self, cluster, apps: list[str]) -> None:
        """Called once before the trace with the cluster and its app set."""

    def select(self, queue: tuple[JobSpec, ...], free_workers: int, now: float):
        """Return Dispatch/Reject/None for the current queue state."""
        raise NotImplementedError

    def observe(self, record) -> None:
        """Called on every job completion (online-refinement hook)."""


POLICIES: dict[str, type[SchedulingPolicy]] = {}


def register_policy(cls: type[SchedulingPolicy]) -> type[SchedulingPolicy]:
    if not cls.name or cls.name == "abstract":
        raise ValueError(f"policy {cls.__name__} needs a concrete name")
    POLICIES[cls.name] = cls
    return cls


def get_policy(name: str, **kwargs) -> SchedulingPolicy:
    if name not in POLICIES:
        raise KeyError(
            f"unknown policy {name!r}; registered: {sorted(POLICIES)}"
        )
    return POLICIES[name](**kwargs)


@register_policy
class StaticFIFO(SchedulingPolicy):
    """Baseline: first-come-first-served, one static config for every job.

    The static (M, R) defaults sit mid-range of the paper's [5, 40] sweep —
    a "reasonable operator default", which is precisely what the paper
    argues against.  Head-of-line blocking included, as in a plain FIFO
    submit queue."""

    name = "fifo-static"

    def __init__(self, *, mappers: int = 20, reducers: int = 20,
                 workers: int = 4, backend: str = "jnp"):
        self._plan = Plan(
            backend=backend, mappers=mappers, reducers=reducers,
            workers=workers,
        )

    def prepare(self, cluster, apps):
        if self._plan.workers > cluster.total_workers:
            raise ValueError(
                f"static worker grant {self._plan.workers} exceeds cluster "
                f"size {cluster.total_workers}"
            )

    def select(self, queue, free_workers, now):
        if self._plan.workers > free_workers:
            return None  # head-of-line blocking: FIFO never reorders
        return Dispatch(queue[0], self._plan)


class PredictivePolicy(SchedulingPolicy):
    """Shared machinery for prediction-driven policies.

    Owns the ModelDatabase, the bootstrap profiling pass (via
    ``tune_categorical``), per-job plan selection from the stored models,
    and the online-refinement hookup.  Subclasses only choose *which* job
    goes next.
    """

    def __init__(
        self,
        *,
        db: ModelDatabase | None = None,
        backends: tuple[str, ...] | None = None,
        mapper_grid: tuple[int, ...] = (4, 8, 16, 24, 32),
        reducer_grid: tuple[int, ...] = (4, 8, 16, 24, 32),
        worker_grid: tuple[int, ...] = (2, 4, 8),
        bootstrap_sizes: tuple[int, ...] = (1 << 14, 1 << 16, 1 << 18),
        n_bootstrap: int | None = None,
        bootstrap_repeats: int = 1,
        online: bool = True,
        refit_every: int = 1,
        seed: int = 0,
        fit_kwargs: dict | None = None,
        depth_grid: tuple[int, ...] = (1,),
        combiner_grid: tuple[bool, ...] = (False,),
        ledger=None,
    ):
        self.db = db if db is not None else ModelDatabase()
        self._backends_arg = backends
        self.mapper_grid = tuple(mapper_grid)
        self.reducer_grid = tuple(reducer_grid)
        self.worker_grid = tuple(sorted(worker_grid))
        self.depth_grid = tuple(sorted(set(int(d) for d in depth_grid)))
        if not self.depth_grid or self.depth_grid[0] < 1:
            raise ValueError(f"bad depth_grid {depth_grid!r}")
        #: combiner axis: (False,) = legacy off-only; (False, True) lets
        #: the policy profile and choose map-side combining per job.
        self.combiner_grid = tuple(
            dict.fromkeys(bool(c) for c in combiner_grid)
        )
        if not self.combiner_grid:
            raise ValueError(f"bad combiner_grid {combiner_grid!r}")
        self.bootstrap_sizes = tuple(bootstrap_sizes)
        self.n_bootstrap = n_bootstrap
        self.bootstrap_repeats = bootstrap_repeats
        self.online = online
        self.refit_every = refit_every
        self.seed = seed
        self.fit_kwargs = dict(fit_kwargs or DEFAULT_FIT_KWARGS)
        self.refiner: OnlineRefiner | None = None
        #: optional :class:`repro.obs.drift.PredictionLedger`: every
        #: completion's (predicted, realized) pair is recorded per
        #: category, and a drift alarm triggers a category-targeted
        #: ``refit_category`` instead of trusting the every-completion
        #: refit to dig the model out from under its stale seed anchors.
        self.ledger = ledger
        self.n_drift_alarms = 0
        self._model_version = 0
        self._plan_cache: dict = {}
        # Drift-refit epoch, bumped per alarm-triggered refit.  Jobs in
        # flight when a correction lands still carry pre-correction
        # predictions; the ledger must not see those completions or every
        # one re-alarms and the corrections compound (a 1.5x rescale
        # applied N times).  Each plan stamps the epoch it was made under.
        self._drift_epoch = 0
        self._plan_drift_epoch: dict[int, int] = {}

    # ---- bootstrap profiling (paper Fig. 2a + 2b) -----------------------

    def prepare(self, cluster, apps):
        self.cluster = cluster
        oracle = cluster.oracle
        self.platform = oracle.platform
        self.backends = tuple(self._backends_arg or oracle.backends())
        #: one model category per (backend, overlap_depth, combiner) —
        #: depth and the combiner are categorical axes exactly like the
        #: backend, so the numeric feature rows (M, R, W, size) and the
        #: wire format of every stored model are unchanged.
        self.categories = tuple(
            _cat_key(b, d, c)
            for b, d, c in itertools.product(
                self.backends, self.depth_grid, self.combiner_grid
            )
        )
        self.worker_grid = tuple(
            w for w in self.worker_grid if w <= cluster.total_workers
        ) or (cluster.total_workers,)
        self.refiner = OnlineRefiner(
            self.db, self.platform,
            refit_every=self.refit_every, fit_kwargs=self.fit_kwargs,
        )
        space = np.asarray(
            [
                (m, r, w, s / SIZE_UNIT)
                for m, r, w, s in itertools.product(
                    self.mapper_grid, self.reducer_grid, self.worker_grid,
                    self.bootstrap_sizes,
                )
            ],
            dtype=np.float64,
        )
        profile_seq = itertools.count()  # distinct noise draw per profile run
        for app in apps:
            if all(
                (app, self.platform, c) in self.db for c in self.categories
            ):
                continue  # warm start: models reloaded from disk

            def make_run_fn(app_name, backend_name, depth, combiner):
                # Off-default knobs stay out of the call signature so
                # narrow oracle stubs (and legacy oracles) keep working.
                extra = {} if depth == 1 else {"depth": depth}
                if combiner:
                    extra["combiner"] = True

                def run(row):
                    return oracle.time(
                        app_name, backend_name, int(row[3] * SIZE_UNIT),
                        int(row[0]), int(row[1]), int(row[2]),
                        job_id=PROFILE_JOB_ID + next(profile_seq),
                        **extra,
                    )
                return run

            result = tune_categorical(
                {
                    _cat_key(b, d, c): make_run_fn(app, b, d, c)
                    for b, d, c in itertools.product(
                        self.backends, self.depth_grid, self.combiner_grid
                    )
                },
                space,
                n_samples=self.n_bootstrap,
                repeats=self.bootstrap_repeats,
                seed=self.seed,
                **self.fit_kwargs,
            )
            for cat, tr in result.per_category.items():
                self.db.put(app, self.platform, tr.model, backend=cat)
                self.refiner.seed_profiles(
                    app, cat, tr.sampled_configs, tr.sampled_times
                )

    # ---- per-job planning (paper Fig. 2b: predict before dispatch) ------

    def _w_bucket(self, free_workers: int) -> int | None:
        """Largest grant in the worker grid that fits the free pool."""
        fitting = [w for w in self.worker_grid if w <= free_workers]
        return max(fitting) if fitting else None

    def best_plan(self, job: JobSpec, free_workers: int) -> Plan | None:
        """Joint (backend, M, R, W) argmin of predicted time at this job's
        size, over grants that fit ``free_workers``.  None = nothing fits."""
        bucket = self._w_bucket(free_workers)
        if bucket is None:
            return None
        key = (job.job_id, bucket, self._model_version)
        if key not in self._plan_cache:
            self._plan_cache[key] = self._argmin_plan(
                job, [w for w in self.worker_grid if w <= bucket]
            )
        self._plan_drift_epoch[job.job_id] = self._drift_epoch
        return self._plan_cache[key]

    def _candidate_rows(self, job: JobSpec, w_options) -> np.ndarray:
        return np.asarray(
            [
                (m, r, w, job.size / SIZE_UNIT)
                for m, r, w in itertools.product(
                    self.mapper_grid, self.reducer_grid, w_options
                )
            ],
            dtype=np.float64,
        )

    def _predict_grid(
        self, job: JobSpec, w_options
    ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        rows = self._candidate_rows(job, w_options)
        preds = {}
        for cat in self.categories:
            model = self.db.get(job.app, self.platform, backend=cat)
            # A polynomial happily predicts <= 0 outside its training mass;
            # floor it so rankings and deadline math stay sane.
            preds[cat] = np.maximum(_np_predict(model, rows), 1e-3)
        return rows, preds

    def _argmin_plan(self, job: JobSpec, w_options) -> Plan:
        rows, preds = self._predict_grid(job, w_options)
        best = None
        for cat, pred in preds.items():
            i = int(np.argmin(pred))
            if best is None or pred[i] < best[0]:
                best = (float(pred[i]), cat, rows[i])
        t, cat, row = best
        backend, depth, combiner = _parse_cat(cat)
        return Plan(
            backend=backend, mappers=int(row[0]), reducers=int(row[1]),
            workers=int(row[2]), predicted_time=t, depth=depth,
            combiner=combiner,
        )

    # ---- online refinement ----------------------------------------------

    def observe(self, record):
        if not self.online or record.plan is None:
            return
        plan, spec = record.plan, record.spec
        row = (plan.mappers, plan.reducers, plan.workers,
               spec.size / SIZE_UNIT)
        cat = _cat_key(plan.backend, getattr(plan, "depth", 1),
                       getattr(plan, "combiner", False))
        refitted = self.refiner.observe(
            spec.app, cat, row, record.true_time
        )
        if (
            self.ledger is not None
            and plan.predicted_time is not None
            and self._plan_drift_epoch.get(
                spec.job_id, self._drift_epoch
            ) == self._drift_epoch
        ):
            alarm = self.ledger.record(
                spec.app, cat, plan.predicted_time, record.true_time,
                t=record.finish,
            )
            if alarm is not None:
                self.n_drift_alarms += 1
                self._drift_epoch += 1
                refitted = self.refiner.refit_category(
                    spec.app, cat,
                    keep_last=self.ledger.keep_last,
                    scale_hint=alarm.scale_hint,
                ) or refitted
        if refitted:
            self._model_version += 1
            self._plan_cache.clear()
        # Oracles that return per-phase traces (telemetry layer) feed the
        # decomposed models too; phase models don't drive plan selection,
        # so no cache invalidation is needed.
        if record.trace is not None:
            self.refiner.observe_phases(
                spec.app, cat, row, record.trace.phase_times()
            )


@register_policy
class PredictiveFIFO(PredictivePolicy):
    """FIFO order, but each job runs at its model-chosen configuration.

    Isolates the value of per-job configuration tuning from the value of
    reordering (compare against ``predict-sjf`` on the same trace)."""

    name = "predict-fifo"

    def select(self, queue, free_workers, now):
        plan = self.best_plan(queue[0], free_workers)
        if plan is None:
            return None
        return Dispatch(queue[0], plan)


@register_policy
class PredictedSJF(PredictivePolicy):
    """Shortest-predicted-job-first with backfilling.

    Among queued jobs whose best plan fits the free pool, dispatch the one
    with the smallest predicted completion time — the classic SJF
    wait-time win, made possible *only* by the config→time model (true
    service times are unknown before execution)."""

    name = "predict-sjf"

    def select(self, queue, free_workers, now):
        best = None
        for job in queue:
            plan = self.best_plan(job, free_workers)
            if plan is None:
                continue
            if best is None or plan.predicted_time < best[1].predicted_time:
                best = (job, plan)
        return Dispatch(*best) if best else None


@register_policy
class PipelinedSJF(PredictedSJF):
    """``predict-sjf`` with the overlap-depth axis switched on.

    Profiles every (backend, depth) category during bootstrap (depth
    rides :func:`tune_categorical` exactly like the backend does), so
    per job the joint (backend, M, R, W, depth) argmin decides whether —
    and how deep — the engine's software-pipelined mode pays off.
    Against an oracle whose depth axis is flat this degenerates to
    ``predict-sjf`` with extra profiling; against the pipelined-aware
    oracles the chosen depth is an interior, size-dependent optimum —
    the paper's configuration-dependency thesis on a brand-new axis.

    Requires an oracle whose ``time`` accepts ``depth=`` for every value
    in ``depth_grid`` beyond 1 (AnalyticOracle always does;
    EngineOracle needs ``pipelined=True``)."""

    name = "predict-pipeline"

    def __init__(self, **kwargs):
        kwargs.setdefault("depth_grid", (1, 2, 4))
        super().__init__(**kwargs)


@register_policy
class CombinerSJF(PredictedSJF):
    """``predict-sjf`` with the map-side-combiner axis switched on.

    Profiles every (backend, combiner) category during bootstrap (the
    ``+c`` categories ride :func:`tune_categorical` exactly like the
    backend), so per job the joint (backend, M, R, W, combiner) argmin
    decides whether pre-aggregating map output — paying combine compute
    for contracted shuffle bytes — beats shipping the raw stream.  The
    tradeoff is size- and key-space-dependent (big skewed jobs combine,
    small or high-cardinality ones don't), which is the paper's
    configuration-dependency thesis on the combiner axis.

    Requires an oracle whose ``time`` accepts ``combiner=`` (both
    bundled oracles do)."""

    name = "predict-combine"

    def __init__(self, **kwargs):
        kwargs.setdefault("combiner_grid", (False, True))
        super().__init__(**kwargs)


@register_policy
class DeadlineAware(PredictivePolicy):
    """Earliest-deadline-first + model-based admission control.

    A job whose deadline cannot be met even at the fastest predicted
    configuration (max worker grant, best backend) is rejected up front —
    capacity is never burned on a lost cause.  Admission is *queue-aware*:
    before declaring a deadline infeasible, the estimated queue wait (the
    predicted service times of jobs ahead in dispatch order, scaled by
    their share of the worker pool) is added to the job's own predicted
    service time — a job that is feasible at dispatch but queued behind
    enough work is a lost cause too (ROADMAP "smarter admission").
    Feasible deadline jobs are served EDF with the *cheapest* grant that
    still meets the deadline (predicted), leaving workers for the rest;
    best-effort jobs (no deadline) backfill last at their fastest plan."""

    name = "predict-deadline"

    def __init__(self, *, slo_margin: float = 0.0,
                 queue_aware: bool = True, **kwargs):
        super().__init__(**kwargs)
        self.slo_margin = slo_margin  # fractional safety margin on deadlines
        self.queue_aware = queue_aware

    def _deadline_budget(self, job: JobSpec, now: float) -> float:
        return (job.deadline - now) / (1.0 + self.slo_margin)

    def _queue_share(self, plan: Plan | None) -> float:
        """Estimated pool-time one queued job consumes before those behind
        it can expect workers: predicted service time weighted by its share
        of the pool (W jobs at grant w each overlap ~ total/w ways)."""
        if plan is None or plan.predicted_time is None:
            return 0.0
        return plan.predicted_time * (
            plan.workers / self.cluster.total_workers
        )

    def _cheapest_feasible(
        self, job: JobSpec, free_workers: int, budget: float
    ) -> Plan | None:
        """Min-grant (then min-time) plan predicted to finish in budget."""
        w_options = [w for w in self.worker_grid if w <= free_workers]
        if not w_options:
            return None
        rows, preds = self._predict_grid(job, w_options)
        best = None
        for cat, pred in preds.items():
            ok = np.nonzero(pred <= budget)[0]
            for i in ok:
                cand = (int(rows[i][2]), float(pred[i]), cat, rows[i])
                if best is None or cand[:2] < best[:2]:
                    best = cand
        if best is None:
            return None
        _, t, cat, row = best
        backend, depth, combiner = _parse_cat(cat)
        return Plan(
            backend=backend, mappers=int(row[0]), reducers=int(row[1]),
            workers=int(row[2]), predicted_time=t, depth=depth,
            combiner=combiner,
        )

    def _admission_sweep(self, order, free_workers, now):
        """Queue-aware admission: walk the dispatch order accumulating the
        estimated queue wait; return a Reject for the first deadline job
        whose own fastest service time plus that wait overruns its budget.

        The dispatch loop below alone cannot do this — it returns at the
        first dispatch/hold, so jobs queued behind others would only be
        re-examined (and rejected) after their budget had silently burned
        down.  The sweep rejects them up front instead.

        Parallelism-aware: a virtual free-worker pool (seeded with the
        currently free workers) is drained by the grants of jobs ahead;
        a job that still fits the pool runs *concurrently* with the queue
        ahead and experiences no queue wait.  Only once the pool is
        exhausted do the accumulated pool-shares of the jobs ahead count
        as estimated wait.
        """
        wait_ahead = 0.0    # pool-share of everything ahead (worker-time)
        virtual_free = free_workers
        for job in order:
            fastest = self.best_plan(job, self.cluster.total_workers)
            grant = fastest.workers if fastest is not None else 0
            fits_now = 0 < grant <= virtual_free
            if job.deadline is not None:
                budget = self._deadline_budget(job, now)
                t_fast = (
                    fastest.predicted_time if fastest is not None
                    else float("inf")
                )
                queue_wait = 0.0 if fits_now else wait_ahead
                if t_fast + queue_wait > budget:
                    return Reject(
                        job,
                        f"infeasible: fastest predicted {t_fast:.3f}s"
                        + (
                            f" + est. queue wait {queue_wait:.3f}s"
                            if queue_wait > 0 else ""
                        )
                        + f" > budget {budget:.3f}s",
                    )
            if fits_now:
                virtual_free -= grant
            wait_ahead += self._queue_share(fastest)
        return None

    def select(self, queue, free_workers, now):
        order = sorted(
            queue,
            key=lambda j: (
                j.deadline if j.deadline is not None else float("inf"),
                j.arrival, j.job_id,
            ),
        )
        if self.queue_aware:
            reject = self._admission_sweep(order, free_workers, now)
            if reject is not None:
                return reject
        for job in order:
            if job.deadline is None:
                plan = self.best_plan(job, free_workers)
                if plan is not None:
                    return Dispatch(job, plan)
                continue
            budget = self._deadline_budget(job, now)
            fastest = self.best_plan(job, self.cluster.total_workers)
            if fastest is None or fastest.predicted_time > budget:
                return Reject(
                    job,
                    f"infeasible: fastest predicted "
                    f"{fastest.predicted_time if fastest else float('inf'):.3f}s"
                    f" > budget {budget:.3f}s",
                )
            plan = self._cheapest_feasible(job, free_workers, budget)
            if plan is not None:
                return Dispatch(job, plan)
            # Feasible with a bigger grant than is currently free: hold the
            # workers we have (EDF reservation) rather than backfilling
            # past an urgent job.
            return None
        return None


def _pre_shuffle_wall(times: dict) -> float:
    """Wall seconds before the shuffle opens (trace phase order)."""
    pre = 0.0
    for phase, t in times.items():
        if phase == "shuffle":
            break
        if t > 0:
            pre += t
    return pre


@register_policy
class ResourceAware(PredictedSJF):
    """SJF scheduling against predicted fabric demand (telemetry-driven).

    Beyond the total-time model, this policy fits three fabric models per
    (application, backend) from the oracle's per-phase profiles
    (``phase_profile``, backed by the telemetry layer's decomposed
    counters): shuffle *bytes*, the wall time *before* the shuffle opens,
    and the shuffle *wall* itself.  Together they predict each dispatch's
    fabric transfer as a time window ``[t0, t1) @ bytes/s`` — the same
    shape the contention-aware ground truth (:class:`repro.cluster.oracle.
    SharedFabric`) prices.  A candidate is scored by its predicted time
    plus ``contention_alpha`` x the fair-share stretch its window would
    suffer against the windows of already-running jobs (intervals where
    aggregate demand D exceeds ``net_capacity`` C inflate by D/C),
    steering dispatch away from co-scheduling overlapping shuffle-heavy
    transfers — what a network-provisioning model (arXiv:1206.2016) says
    to avoid.

    ``net_capacity=None`` (default) means an unconstrained fabric: scoring
    reduces exactly to predicted time and the policy is decision-for-
    decision identical to ``predict-sjf`` — the safe default for oracles
    that do not model network contention.  Operators set it to their
    fabric's sustained bytes/s.  The policy is work-conserving either
    way: contention re-orders dispatch, it never idles workers.
    """

    name = "predict-resource"

    def __init__(self, *, net_capacity: float | None = None,
                 contention_alpha: float = 4.0, **kwargs):
        super().__init__(**kwargs)
        self.net_capacity = (
            float("inf") if net_capacity is None else float(net_capacity)
        )
        if self.net_capacity <= 0:
            raise ValueError("net_capacity must be positive")
        self.contention_alpha = float(contention_alpha)
        self._bytes_models: dict[tuple[str, str], RegressionModel] = {}
        self._window_models: dict[tuple[str, str], tuple] = {}
        #: job_id -> (t0, t1, bytes/s): predicted fabric windows of
        #: currently running jobs.
        self._windows: dict[int, tuple[float, float, float]] = {}
        self.n_contention_deferrals = 0

    # ---- bootstrap: fit fabric models from phase profiles ---------------

    def prepare(self, cluster, apps):
        super().prepare(cluster, apps)
        self._windows.clear()
        profile = getattr(cluster.oracle, "phase_profile", None)
        if profile is None:
            return  # no per-phase source: behave as plain predict-sjf
        from repro.telemetry.models import phase_resource_key

        res_keys = {
            "bytes": phase_resource_key("shuffle", "bytes"),
            "pre": phase_resource_key("shuffle", "window_pre_s"),
            "wall": phase_resource_key("shuffle", "window_wall_s"),
        }
        # A compact profiling set suffices: shuffle bytes are ~linear in
        # size and barely config-dependent, but we keep the full feature
        # row so the stored models compose with everything else.
        rows = np.asarray(
            [
                (m, r, self.worker_grid[-1], s / SIZE_UNIT)
                for m, r, s in itertools.product(
                    self.mapper_grid[:: max(1, len(self.mapper_grid) - 1)],
                    self.reducer_grid[:: max(1, len(self.reducer_grid) - 1)],
                    self.bootstrap_sizes,
                )
            ],
            dtype=np.float64,
        )
        for app in apps:
            for backend, comb in itertools.product(
                self.backends, self.combiner_grid
            ):
                # Fabric models are per (app, backend, combiner): the
                # combined stream ships fewer bytes over a different
                # window, and the whole point of the axis is that the
                # scheduler can *predict* that contraction.  Combiner-off
                # keeps the bare-backend key (legacy databases load).
                cat = _cat_key(backend, 1, comb)
                extra = {"combiner": True} if comb else {}
                fitted = {
                    name: self.db.get(app, self.platform, cat,
                                      resource=rk)
                    for name, rk in res_keys.items()
                    if (app, self.platform, cat, rk) in self.db
                }
                if len(fitted) < len(res_keys):
                    profs = [
                        profile(
                            app, backend, int(row[3] * SIZE_UNIT),
                            int(row[0]), int(row[1]), int(row[2]),
                            **extra,
                        )
                        for row in rows
                    ]
                    targets = {
                        "bytes": [p["shuffle_bytes"] for p in profs],
                        "pre": [_pre_shuffle_wall(p["time_s"])
                                for p in profs],
                        "wall": [max(p["time_s"].get("shuffle", 0.0), 0.0)
                                 for p in profs],
                    }
                    for name, rk in res_keys.items():
                        if name in fitted:
                            continue
                        # Degree-1 bases fit the 12-point profile set
                        # without ever going underdetermined: bytes are
                        # ~linear in size, and the window-shape targets
                        # only steer dispatch, they gate nothing.
                        model = regression_fit(
                            rows,
                            np.asarray(targets[name], dtype=np.float64),
                            degree=1, cross_terms=False, scale=True,
                            lam=1e-9,
                        )
                        self.db.put(
                            app, self.platform, model, backend=cat,
                            resource=rk,
                        )
                        fitted[name] = model
                self._bytes_models[(app, backend, comb)] = fitted["bytes"]
                self._window_models[(app, backend, comb)] = (
                    fitted["pre"], fitted["wall"]
                )

    # ---- dispatch scoring ------------------------------------------------

    def _shuffle_window(
        self, job: JobSpec, plan: Plan, now: float
    ) -> tuple[float, float, float] | None:
        """Predicted fabric transfer (t0, t1, bytes/s) for this dispatch."""
        comb = bool(getattr(plan, "combiner", False))
        wmodels = self._window_models.get((job.app, plan.backend, comb))
        bmodel = self._bytes_models.get((job.app, plan.backend, comb))
        if wmodels is None or bmodel is None or plan.predicted_time is None:
            return None
        row = np.asarray(
            (plan.mappers, plan.reducers, plan.workers,
             job.size / SIZE_UNIT),
            dtype=np.float64,
        )
        nbytes = max(float(_np_predict(bmodel, row)[0]), 0.0)
        if nbytes <= 0.0:
            return None
        # Clamp the window inside the predicted runtime: the degree-1
        # window models may overshoot between profile points.
        pre = min(max(float(_np_predict(wmodels[0], row)[0]), 0.0),
                  plan.predicted_time)
        wall = min(max(float(_np_predict(wmodels[1], row)[0]), 1e-9),
                   max(plan.predicted_time - pre, 1e-9))
        return (now + pre, now + pre + wall, nbytes / wall)

    def _predicted_stretch(self, win: tuple[float, float, float]) -> float:
        """Fair-share seconds the fabric would add to this transfer given
        the predicted windows of running jobs: over every sub-interval of
        the window where aggregate demand D > capacity C, wire time
        inflates by D/C (the :class:`SharedFabric` law)."""
        t0, t1, rate = win
        edges = sorted(
            {t0, t1}
            | {p for (w0, w1, _) in self._windows.values()
               for p in (w0, w1) if t0 < p < t1}
        )
        extra = 0.0
        for a, b in zip(edges, edges[1:]):
            demand = rate + sum(
                r for (w0, w1, r) in self._windows.values()
                if w0 < b and w1 > a
            )
            if demand > self.net_capacity:
                extra += (b - a) * (demand / self.net_capacity - 1.0)
        return extra

    def select(self, queue, free_workers, now):
        # Windows whose transfer has closed no longer load the fabric.
        self._windows = {
            j: w for j, w in self._windows.items() if w[1] > now
        }
        best = None
        best_sjf = None  # what plain SJF would pick (deferral accounting)
        for job in queue:
            plan = self.best_plan(job, free_workers)
            if plan is None:
                continue
            win = (
                self._shuffle_window(job, plan, now)
                if math.isfinite(self.net_capacity) else None
            )
            stretch = self._predicted_stretch(win) if win else 0.0
            score = plan.predicted_time + self.contention_alpha * stretch
            if best is None or score < best[0]:
                best = (score, job, plan, win)
            if best_sjf is None or plan.predicted_time < best_sjf:
                best_sjf = plan.predicted_time
        if best is None:
            return None
        _, job, plan, win = best
        if best_sjf is not None and plan.predicted_time > best_sjf:
            self.n_contention_deferrals += 1
        if win is not None:
            self._windows[job.job_id] = win
        return Dispatch(job, plan)

    def observe(self, record):
        self._windows.pop(record.spec.job_id, None)
        super().observe(record)


@register_policy
class ElasticDeadline(DeadlineAware):
    """Deadline EDF + preemptive, regrant-aware elasticity.

    On a plain :class:`~repro.cluster.cluster.Cluster` this is exactly
    ``predict-deadline``.  On an
    :class:`~repro.elastic.sim.ElasticCluster` it adds two moves, each
    gated by the :class:`~repro.elastic.regrant.RegrantCostModel` on the
    same regression basis every other decision uses:

    * **rescue (shrink)** — when a deadline job's cheapest feasible plan
      does not fit the free pool (the base policy would hold it while its
      budget burns), shrink a running *best-effort* job to the smallest
      grant in the worker grid at its next wave boundary, freeing workers
      in wave-time rather than job-time.  The cost model's ``shrink_ok``
      vetoes moves on nearly-finished victims or where the checkpoint
      overhead is large relative to the victim's predicted remaining run.
      While a shrink is in flight, the beneficiary is shielded from
      rejection and best-effort work is barred from backfilling the
      workers being freed.
    * **regrow** — once no queued deadline job needs the pool, previously
      shrunk jobs are grown back toward their original grant when the
      cost model predicts the regrant pays for itself
      (``worth_it``: time saved under W' exceeds the checkpoint cost).

    With no contention neither move triggers and the schedule is
    decision-for-decision identical to ``predict-deadline`` — which is
    the benchmark's no-regression guarantee.
    """

    name = "predict-elastic"

    def __init__(self, *, shrink_floor: int | None = None,
                 min_remaining_steps: int = 2,
                 min_remaining_frac: float = 0.15,
                 max_overhead_frac: float = 0.25,
                 regrow: bool = True, min_grow_gain_s: float = 1e-3,
                 suspend: bool = False,
                 **kwargs):
        super().__init__(**kwargs)
        self._shrink_floor_arg = shrink_floor
        if min_remaining_steps < 2:
            # A regrant can only take effect at a boundary *before* the
            # final wave; the simulator rejects later requests loudly.
            raise ValueError("min_remaining_steps must be >= 2")
        self.min_remaining_steps = int(min_remaining_steps)
        self.min_remaining_frac = float(min_remaining_frac)
        self.max_overhead_frac = float(max_overhead_frac)
        self.regrow = bool(regrow)
        self.min_grow_gain_s = float(min_grow_gain_s)
        #: suspend-to-disk rescue: when no shrinkable victim can free
        #: enough workers for a starved deadline job, park a whole
        #: best-effort job on disk (grant 0) and resume it once the pool
        #: quiets down.  Off by default: a suspended job pays the full
        #: disk-queue wait, so this is the aggressive setting.
        self.suspend = bool(suspend)
        self.n_shrinks = 0
        self.n_grows = 0
        self.n_suspends = 0
        self.n_resumes = 0
        self._awaiting: set[int] = set()

    def prepare(self, cluster, apps):
        super().prepare(cluster, apps)
        from repro.elastic.regrant import RegrantCostModel

        self.elastic = bool(getattr(cluster, "supports_elastic", False))
        self.shrink_floor = (
            self._shrink_floor_arg if self._shrink_floor_arg is not None
            else min(self.worker_grid)
        )
        self.cost_model = RegrantCostModel(
            snapshot_overhead_s=getattr(
                cluster, "snapshot_overhead_s", 0.02
            ),
            restore_overhead_s=getattr(
                cluster, "restore_overhead_s", 0.02
            ),
            min_remaining_frac=self.min_remaining_frac,
            max_overhead_frac=self.max_overhead_frac,
        )
        self._awaiting.clear()

    def observe_overhead(self, save_s: float, restore_s: float) -> None:
        """Measured (snapshot, restore) walls from the cluster — the
        EngineOracle path measures real ``save_snapshot``/``load_snapshot``
        costs; folding them in keeps regrant pricing honest."""
        self.cost_model.record_overhead(save_s, restore_s)

    # ---- prediction on the regression basis -----------------------------

    def _predicted_total(self, spec: JobSpec, plan: Plan,
                         workers: int) -> float:
        """Model-predicted total time of (spec, plan) at grant ``workers``
        — the regression evaluated off the plan's frozen (M, R)."""
        model = self.db.get(
            spec.app, self.platform,
            backend=_cat_key(plan.backend, getattr(plan, "depth", 1),
                             getattr(plan, "combiner", False)),
        )
        row = np.asarray(
            (plan.mappers, plan.reducers, workers, spec.size / SIZE_UNIT),
            dtype=np.float64,
        )
        return float(max(_np_predict(model, row)[0], 1e-3))

    def _evaluate_regrant(self, view, new_workers: int):
        return self.cost_model.evaluate(
            t_total_current=self._predicted_total(
                view.spec, view.plan, view.workers
            ),
            t_total_new=self._predicted_total(
                view.spec, view.plan, new_workers
            ),
            progress=view.progress,
            current_workers=view.workers,
            new_workers=new_workers,
        )

    # ---- elastic decision layer -----------------------------------------

    def select(self, queue, free_workers, now):
        if not self.elastic:
            return super().select(queue, free_workers, now)
        views = self.cluster.running_jobs(now)
        pending_free = sum(
            v.workers - v.pending_workers for v in views
            if v.pending_workers is not None
            and v.pending_workers < v.workers
        )
        if pending_free == 0:
            # Nothing in flight: any previous rescue resolved (or died).
            self._awaiting.clear()
        action = self._maybe_rescue(queue, free_workers, pending_free,
                                    views, now)
        if action is not None:
            return action
        if self._awaiting:
            # Workers are being freed for awaited deadline jobs: other
            # deadline jobs proceed normally, but best-effort work must
            # not backfill the grant in flight, and the awaited jobs are
            # shielded from the base policy's rejection sweep.
            shielded = tuple(
                j for j in queue
                if j.deadline is not None and j.job_id not in self._awaiting
            )
            return super().select(shielded, free_workers, now) \
                if shielded else None
        action = self._maybe_regrow(queue, free_workers, views)
        if action is not None:
            return action
        return super().select(queue, free_workers, now)

    def idle(self, free_workers, now):
        """Elastic moves on an empty (or fully held) queue — the
        simulator calls this after the dispatch loop, which is the only
        chance to regrow right after the last queued job dispatched."""
        if not self.elastic or self._awaiting:
            return None
        return self._maybe_regrow(
            (), free_workers, self.cluster.running_jobs(now)
        )

    def _maybe_rescue(self, queue, free_workers, pending_free, views, now):
        """Shrink a running best-effort job to free workers for the most
        urgent deadline job that is feasible in time but starved of pool."""
        from repro.elastic.sim import Regrant

        deadline_jobs = sorted(
            (j for j in queue if j.deadline is not None),
            key=lambda j: (j.deadline, j.arrival, j.job_id),
        )
        for job in deadline_jobs:
            budget = self._deadline_budget(job, now)
            fastest = self.best_plan(job, self.cluster.total_workers)
            if fastest is None or fastest.predicted_time > budget:
                self._awaiting.discard(job.job_id)
                continue    # hopeless: the base sweep will reject it
            if self._cheapest_feasible(job, free_workers, budget):
                self._awaiting.discard(job.job_id)
                continue    # dispatchable right now: base handles it
            target = self._cheapest_feasible(
                job, self.cluster.total_workers, budget
            )
            if target is None:
                continue
            deficit = target.workers - (free_workers + pending_free)
            if deficit <= 0:
                # Enough is already being freed; hold for the boundary.
                self._awaiting.add(job.job_id)
                continue
            victims = sorted(
                (
                    v for v in views
                    if v.spec.deadline is None
                    and v.pending_workers is None
                    and v.workers > self.shrink_floor
                    and v.steps_remaining >= self.min_remaining_steps
                ),
                key=lambda v: (-v.workers,
                               -v.progress.remaining_fraction(v.workers)),
            )
            for victim in victims:
                new_w = max(self.shrink_floor, victim.workers - deficit)
                decision = self._evaluate_regrant(victim, new_w)
                if not decision.shrink_ok:
                    continue
                self._awaiting.add(job.job_id)
                self.n_shrinks += 1
                return Regrant(
                    victim.job_id, new_w,
                    reason=f"rescue deadline job {job.job_id} "
                           f"(gain gate: {decision.gain_s:+.3f}s)",
                )
            if self.suspend:
                # No shrinkable victim can free enough (typically: the
                # best-effort jobs already sit at the shrink floor).
                # Park one on disk entirely — its whole grant frees at
                # the next wave boundary.
                for victim in sorted(
                    (
                        v for v in views
                        if v.spec.deadline is None
                        and v.pending_workers is None
                        and v.steps_remaining >= self.min_remaining_steps
                    ),
                    key=lambda v: (-v.workers,
                                   -v.progress.remaining_fraction(
                                       v.workers)),
                ):
                    # Gate on the cost model's most aggressive shrink:
                    # suspension is never cheaper than shrinking to 1.
                    if not self._evaluate_regrant(victim, 1).shrink_ok:
                        continue
                    self._awaiting.add(job.job_id)
                    self.n_suspends += 1
                    return Regrant(
                        victim.job_id, 0,
                        reason=f"suspend to disk: rescue deadline job "
                               f"{job.job_id}",
                    )
        return None

    def _maybe_regrow(self, queue, free_workers, views):
        """Grow a shrunk job back toward its original grant when the pool
        is quiet and the cost model predicts the move pays for itself."""
        from repro.elastic.sim import Regrant

        if free_workers <= 0:
            return None
        if any(j.deadline is not None for j in queue):
            return None     # deadline work queued: keep the slack
        # Resume suspended-to-disk jobs first: they hold zero workers and
        # pay full queue wait, so any slack goes to them before regrows.
        # NOT gated on self.regrow — a suspended job must always have a
        # path back, or the simulator (rightly) reports it stranded.
        suspended = getattr(self.cluster, "suspended_jobs", None)
        if suspended is not None:
            for sus in suspended():
                w = min(sus.workers_before, free_workers)
                if w >= 1:
                    self.n_resumes += 1
                    return Regrant(
                        sus.job_id, w,
                        reason="resume from disk (pool quiet)",
                    )
        if not self.regrow:
            return None
        candidates = sorted(
            (
                v for v in views
                if v.shrunk_from is not None
                and v.pending_workers is None
                and v.workers < v.shrunk_from
                and v.steps_remaining >= self.min_remaining_steps
            ),
            key=lambda v: v.started,
        )
        for victim in candidates:
            new_w = min(victim.shrunk_from, victim.workers + free_workers)
            if new_w <= victim.workers:
                continue
            decision = self._evaluate_regrant(victim, new_w)
            if decision.gain_s > self.min_grow_gain_s:
                self.n_grows += 1
                return Regrant(
                    victim.job_id, new_w,
                    reason=f"regrow (predicted gain {decision.gain_s:.3f}s)",
                )
        return None
