"""Open-ended arrival streams: the service-mode side of workload.py.

:func:`~repro.cluster.workload.generate_workload` answers "give me N
jobs"; a live service faces the opposite shape — an unbounded arrival
process whose *rate* varies (daily cycles, bursts, flash crowds) and a
simulator that steps until a horizon rather than draining a fixed trace
(``Cluster.run_service``).  This module supplies the streams:

* arrival **processes** — iterables of strictly increasing arrival times:
  :class:`PoissonProcess` (inhomogeneous, via Lewis–Shedler thinning
  against any ``rate_fn``) and :class:`RenewalProcess` (the open-ended
  extension of ``workload.py``'s poisson/uniform/bursty interarrival
  draws, generated chunk-wise from one rng).  :func:`merge_processes`
  superposes several (e.g. a bursty baseline plus a flash-crowd spike);
* **rate functions** for the Poisson process — :func:`constant_rate`,
  :func:`diurnal_rate` (sinusoidal daily cycle), :func:`flash_crowd_rate`
  (adversarial step overload: rate multiplies by ``factor`` inside each
  crowd window, the provisioning stress case of arXiv:1206.2016);
* :class:`JobStream` — maps a process onto :class:`~repro.cluster.
  workload.JobSpec`\\ s with the same log-uniform sizes / weighted apps /
  optional slack-multiplier deadlines as ``generate_workload``.

Every stream is fully determined by its seed and restartable: iterating
twice (or iterating two identically-configured instances) yields the
identical job sequence — the property that keeps service benchmarks
comparable across policies and PRs, tested in ``tests/test_service.py``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np

from repro.cluster.workload import (
    APPS,
    ARRIVALS,
    JobSpec,
    _interarrival_gaps,
)

__all__ = [
    "JobStream",
    "PoissonProcess",
    "RenewalProcess",
    "constant_rate",
    "diurnal_rate",
    "flash_crowd_rate",
    "merge_processes",
    "take",
]


# ------------------------------------------------------------ rate functions


def constant_rate(rate: float) -> Callable[[float], float]:
    """λ(t) = rate."""
    if rate < 0:
        raise ValueError(f"rate must be >= 0, got {rate}")
    return lambda t: rate


def diurnal_rate(
    base: float,
    *,
    amplitude: float = 0.5,
    period_s: float = 600.0,
    phase: float = 0.0,
) -> Callable[[float], float]:
    """Sinusoidal day cycle: ``base * (1 + amplitude * sin(...))``.

    ``amplitude`` in [0, 1] keeps the rate non-negative; the peak rate
    (what a thinning sampler must envelope) is ``base * (1 + amplitude)``.
    """
    if base < 0 or not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"bad diurnal rate (base={base}, amp={amplitude})")

    def f(t: float) -> float:
        return base * (
            1.0 + amplitude * math.sin(2.0 * math.pi * t / period_s + phase)
        )

    return f


def flash_crowd_rate(
    base: float | Callable[[float], float],
    crowds: Sequence[tuple[float, float, float]],
) -> Callable[[float], float]:
    """Adversarial step overload: inside each ``(t0, t1, factor)`` window
    the base rate multiplies by ``factor`` — no ramp, the flash crowd
    arrives all at once.  Windows may overlap (factors compose)."""
    base_fn = base if callable(base) else constant_rate(float(base))
    windows = [(float(a), float(b), float(f)) for a, b, f in crowds]
    for a, b, f in windows:
        if b <= a or f < 0:
            raise ValueError(f"bad crowd window ({a}, {b}, {f})")

    def f(t: float) -> float:
        r = base_fn(t)
        for a, b, fac in windows:
            if a <= t < b:
                r *= fac
        return r

    return f


# ---------------------------------------------------------------- processes


class PoissonProcess:
    """Inhomogeneous Poisson arrivals by thinning: candidate events at
    ``peak_rate`` are accepted with probability ``rate_fn(t)/peak_rate``.
    ``rate_fn`` must never exceed ``peak_rate`` (checked per candidate).

    Iterating yields an unbounded, strictly increasing time sequence,
    deterministic in ``seed`` and identical on every fresh iteration.
    """

    def __init__(
        self,
        rate_fn: float | Callable[[float], float],
        *,
        peak_rate: float | None = None,
        seed: int = 0,
        t0: float = 0.0,
    ):
        if callable(rate_fn):
            if peak_rate is None:
                raise ValueError(
                    "a callable rate_fn needs an explicit peak_rate "
                    "envelope for thinning"
                )
            self.rate_fn = rate_fn
        else:
            self.rate_fn = constant_rate(float(rate_fn))
            peak_rate = peak_rate if peak_rate is not None else float(rate_fn)
        if peak_rate <= 0:
            raise ValueError(f"peak_rate must be > 0, got {peak_rate}")
        self.peak_rate = float(peak_rate)
        self.seed = int(seed)
        self.t0 = float(t0)

    def __iter__(self) -> Iterator[float]:
        rng = np.random.default_rng(self.seed)
        t = self.t0
        while True:
            t += float(rng.exponential(1.0 / self.peak_rate))
            lam = self.rate_fn(t)
            if lam > self.peak_rate * (1.0 + 1e-9):
                raise ValueError(
                    f"rate_fn({t:.3f}) = {lam:.4f} exceeds the thinning "
                    f"envelope peak_rate = {self.peak_rate:.4f}"
                )
            if rng.random() * self.peak_rate < lam:
                yield t


class RenewalProcess:
    """Open-ended renewal arrivals reusing ``workload.py``'s interarrival
    draws (poisson / uniform / bursty), generated chunk-wise so the
    sequence extends indefinitely from one seeded rng."""

    def __init__(
        self,
        arrival: str = "bursty",
        *,
        mean_interarrival: float,
        seed: int = 0,
        t0: float = 0.0,
        chunk: int = 256,
    ):
        if arrival not in ARRIVALS:
            raise ValueError(
                f"unknown arrival process {arrival!r}; expected {ARRIVALS}"
            )
        if mean_interarrival <= 0 or chunk < 1:
            raise ValueError("mean_interarrival must be > 0, chunk >= 1")
        self.arrival = arrival
        self.mean_interarrival = float(mean_interarrival)
        self.seed = int(seed)
        self.t0 = float(t0)
        self.chunk = int(chunk)

    def __iter__(self) -> Iterator[float]:
        rng = np.random.default_rng(self.seed)
        t = self.t0
        while True:
            gaps = _interarrival_gaps(
                self.chunk, self.arrival, self.mean_interarrival, rng
            )
            for g in gaps:
                t += float(g)
                yield t


def merge_processes(*processes: Iterable[float]) -> Iterator[float]:
    """Superpose arrival processes into one merged time-ordered stream."""
    return heapq.merge(*processes)


# ---------------------------------------------------------------- job stream


@dataclasses.dataclass
class JobStream:
    """Deterministic open-ended stream of :class:`JobSpec`\\ s.

    ``process`` supplies arrival times (any restartable iterable of
    increasing floats); sizes are log-uniform over ``size_range``, apps
    weighted by ``app_weights``, and — when ``deadline_fraction > 0`` and
    a ``service_estimate`` is given — a ``deadline_fraction`` of jobs
    carry ``arrival + slack * estimate`` deadlines, exactly the
    ``generate_workload`` + ``assign_deadlines`` conventions.  Job ids
    count up from ``start_id``.

    The stream itself never terminates; bound it with :func:`take` or let
    ``Cluster.run_service(until_time=…/until_jobs=…)`` cut it off.
    """

    process: Iterable[float]
    seed: int = 0
    apps: Sequence[str] = APPS
    app_weights: Sequence[float] | None = None
    size_range: tuple[int, int] = (1 << 14, 1 << 18)
    deadline_fraction: float = 0.0
    slack_range: tuple[float, float] = (1.5, 4.0)
    service_estimate: Callable[[JobSpec], float] | None = None
    start_id: int = 0

    def __post_init__(self):
        self.apps = tuple(self.apps)
        for a in self.apps:
            if a not in APPS:
                raise ValueError(f"unknown app {a!r}")
        if self.deadline_fraction > 0 and self.service_estimate is None:
            raise ValueError(
                "deadline_fraction > 0 needs a service_estimate"
            )
        if self.app_weights is not None:
            w = np.asarray(self.app_weights, dtype=np.float64)
            if len(w) != len(self.apps) or w.sum() <= 0:
                raise ValueError(f"bad app_weights {self.app_weights!r}")
            self._p = (w / w.sum()).tolist()
        else:
            self._p = None

    def __iter__(self) -> Iterator[JobSpec]:
        rng = np.random.default_rng(self.seed)
        lo, hi = self.size_range
        log_lo, log_hi = math.log(lo), math.log(hi)
        for job_id, t in enumerate(iter(self.process), start=self.start_id):
            # Fixed four draws per job keeps the sequence aligned (and
            # therefore byte-deterministic) whether or not a particular
            # job ends up with a deadline.
            size = int(math.exp(float(rng.uniform(log_lo, log_hi))))
            app = self.apps[int(rng.choice(len(self.apps), p=self._p))]
            dl_coin = float(rng.random())
            slack = float(rng.uniform(*self.slack_range))
            job = JobSpec(
                job_id=job_id, app=app, size=max(1, size), arrival=float(t)
            )
            if dl_coin < self.deadline_fraction:
                job = dataclasses.replace(
                    job,
                    deadline=job.arrival
                    + slack * float(self.service_estimate(job)),
                )
            yield job


def take(stream: Iterable, n: int) -> list:
    """The first ``n`` items of a stream, materialized."""
    return list(itertools.islice(iter(stream), n))
