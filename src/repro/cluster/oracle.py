"""Runtime oracles: the "true" job execution time the scheduler observes.

The paper's loop is profile → model → predict against a *real* cluster.  In
this repo the real thing is the TPU-native MapReduce engine, but an
event-driven scheduling study needs thousands of job executions per trace,
so two interchangeable time sources implement one interface
(``time(app, backend, size, mappers, reducers, workers, job_id)``):

* :class:`AnalyticOracle` — a Hadoop-shaped closed-form cost with wave
  quantization, per-task startup, shuffle imbalance, and backend
  throughput/launch-overhead tradeoffs, plus deterministic-per-job
  multiplicative noise.  Interior optima in both M and R (more tasks
  amortize the spill sort but pay more startup — the paper's observed
  non-monotonicity) make configuration choice genuinely matter.
* :class:`EngineOracle` — wall-clocks :func:`repro.mapreduce.build_job` on
  the live engine (compile-cached, one warmup), for small demo traces where
  the simulated cluster IS the real engine.

Policies never see oracle internals: they only get profiled samples and
completed-job observations, exactly the paper's black-box treatment.
"""

from __future__ import annotations

import math

import numpy as np

#: stable small ints for seeding noise streams (strings don't hash stably).
_APP_IDS = {"wordcount": 1, "eximparse": 2}
_BACKEND_IDS = {"jnp": 1, "pallas": 2, "xla": 3}

#: job_ids at/above this mark bootstrap-profiling runs, not trace jobs —
#: policies allocate ``PROFILE_JOB_ID + seq`` for their profiling calls,
#: and injected platform shifts (``AnalyticOracle(shift_after_job=...)``)
#: never apply to them: profiling always happened *before* the shift.
PROFILE_JOB_ID = 1_000_000

#: map-output pairs emitted per input token (wordcount: one pair per word;
#: eximparse: one pair per 3-token record) — sizes the shuffle traffic.
_PAIRS_PER_TOKEN = {"wordcount": 1.0, "eximparse": 1.0 / 3.0}

#: key-space size per application — must match the corpora the
#: :class:`EngineOracle` builds (wordcount vocab 4096, eximparse 1024
#: transactions), because the analytic combined-bytes term is a
#: distinct-keys expectation over exactly this space.
_KEY_SPACE = {"wordcount": 4096, "eximparse": 1024}


def expected_combined_pairs(app: str, size: int, mappers: int) -> float:
    """Closed-form post-combine shuffle pairs for one job.

    A map task emits ``s = pairs_per_token * size / M`` pairs drawn from a
    key space of ``V`` keys; after map-side combining it ships one pair
    per *distinct* key, whose expectation under uniform draws is the
    coupon-collector occupancy ``V * (1 - (1 - 1/V)^s)``.  Clamped by the
    emitted count (a combiner never expands the stream), summed over the
    M tasks.  Real corpora are Zipf-skewed, not uniform, so this is an
    upper bound on the true combined traffic — the model error the
    heldout bench measures.
    """
    V = float(_KEY_SPACE[app])
    s = _PAIRS_PER_TOKEN[app] * float(size) / max(1, int(mappers))
    distinct = V * (1.0 - (1.0 - 1.0 / V) ** s)
    return int(mappers) * min(s, distinct)


def _analytic_trace(app, backend, size, M, R, W, phase_s, noise_factor,
                    depth: int = 1, overlap_s: float = 0.0,
                    cpu_s: dict | None = None,
                    combined_pairs: float | None = None):
    """Build a JobTrace-shaped record from closed-form phase components.

    The analytic oracle has no real arrays to count, so the counters are
    the closed-form expectations (shuffle bytes = pairs x PAIR_BYTES, no
    overflow); the *shape* matches the engine's traces exactly, which is
    what lets the online per-phase refit path treat both oracles alike.
    ``cpu_s`` carries the closed-form CPU task-seconds per phase (scaled
    by the same noise factor as the walls, with ``cpu_workers = W`` as
    the parallelism ceiling — the simulated cluster grants W workers).

    With ``depth > 1`` the trace gains a fourth ``"pipeline"`` phase
    whose wall is the (negative) overlap saving ``-overlap_s`` — the
    serial phase components stay intact and the four walls still sum
    exactly to the overlapped total, so the timing conservation law
    closes on pipelined analytic traces too.

    ``combined_pairs`` (combiner jobs) inserts a ``combine`` phase between
    map and shuffle and contracts the shuffle/fabric counters to the
    combined stream — the same counter flow the engine's traced modes
    record, so conservation laws close identically on both oracles.
    """
    from repro.telemetry.trace import PAIR_BYTES, JobTrace

    pairs = _PAIRS_PER_TOKEN[app] * float(size)
    shuffle_pairs = pairs if combined_pairs is None else float(combined_pairs)
    nbytes = shuffle_pairs * PAIR_BYTES
    cpu_s = cpu_s or {}

    def cpu(phase):
        if phase not in cpu_s:
            return {}
        return {
            "cpu_s": cpu_s[phase] * noise_factor,
            "cpu_workers": float(W),
        }

    trace = JobTrace(
        app=app,
        config={
            "num_mappers": M, "num_reducers": R, "num_workers": W,
            "reduce_backend": backend, "input_len": int(size),
            "overlap_depth": int(depth),
        },
    )
    trace.record_phase(
        "map", phase_s["map"] * noise_factor,
        tasks=M, waves=math.ceil(M / W), records_in=size,
        pairs_emitted=pairs, **cpu("map"),
    )
    if combined_pairs is not None:
        trace.record_phase(
            "combine", phase_s["combine"] * noise_factor,
            tasks=M, pairs_in=pairs, pairs_out=shuffle_pairs,
            bytes_in=pairs * PAIR_BYTES, bytes_out=nbytes,
            net_bytes=0.0, **cpu("combine"),
        )
    trace.record_phase(
        "shuffle", phase_s["shuffle"] * noise_factor,
        pairs_in=shuffle_pairs, pairs_out=shuffle_pairs, pairs_dropped=0,
        bytes_in=nbytes, bytes_out=nbytes, bytes_dropped=0,
        partitions=R,
        net_bytes=nbytes, net_s=phase_s["shuffle"] * noise_factor,
        **cpu("shuffle"),
    )
    trace.record_phase(
        "reduce", phase_s["reduce"] * noise_factor,
        tasks=R, waves=math.ceil(R / W), **cpu("reduce"),
    )
    if depth > 1:
        trace.record_phase(
            "pipeline", -overlap_s,
            overlap_depth=depth, overlap_s=overlap_s,
            net_bytes=0.0,
        )
    trace.finish(sum(p.wall_s for p in trace.phases))
    return trace


class SharedFabric:
    """Deterministic fair-share model of one shared shuffle fabric.

    Each admission prices one transfer — ``nbytes`` over a nominal
    window ``[start, start + nominal_s)`` at its own uncontended rate
    ``nbytes / nominal_s`` — by integrating it piecewise against the
    transfers already committed: wherever aggregate demand D exceeds
    ``capacity`` C, every byte drains at the fair share ``C / D`` of its
    nominal rate, so the newcomer's window stretches.  Earlier
    admissions are never retro-stretched: pricing is causal in dispatch
    order, single-pass, and deterministic.  Transfers whose uncontended
    windows don't overlap therefore never interact — contention can
    delay a job, but it cannot reorder jobs with disjoint lifetimes.

    Over-capacity admissions are logged as contention *episodes* (job,
    window, peak demand, stretch) for the cluster-wide report.
    """

    def __init__(self, capacity: float):
        cap = float(capacity)
        if not cap > 0:
            raise ValueError(f"net capacity must be > 0, got {capacity!r}")
        self.capacity = cap
        #: committed transfers as (t0, t1, bytes_per_s) — byte-conserving
        #: average rates over each transfer's *actual* window.
        self._transfers: list[tuple[float, float, float]] = []
        self.episodes: list[dict] = []
        self.contention_s_total = 0.0
        self.n_contended = 0

    def demand_at(self, t: float) -> float:
        """Aggregate committed fabric demand (bytes/s) at time ``t``."""
        return sum(r for (t0, t1, r) in self._transfers if t0 <= t < t1)

    def admit(self, job_id: int, start: float, nominal_s: float,
              nbytes: float) -> float:
        """Price one transfer; return its stretch (contention seconds)."""
        if nbytes <= 0 or nominal_s <= 0:
            return 0.0
        rate = float(nbytes) / float(nominal_s)
        # Piecewise-constant integration: within each segment between
        # committed-transfer breakpoints the fair share is constant.
        edges = sorted(
            {p for (t0, t1, _) in self._transfers for p in (t0, t1)
             if p > start}
        )
        remaining = float(nbytes)
        t = float(start)
        peak = rate
        for edge in edges + [math.inf]:
            demand = self.demand_at(t) + rate
            peak = max(peak, demand)
            thru = rate * min(1.0, self.capacity / demand)
            if edge == math.inf or remaining <= thru * (edge - t):
                t += remaining / thru
                break
            remaining -= thru * (edge - t)
            t = edge
        end = t
        stretch = (end - start) - float(nominal_s)
        if stretch < 1e-9:  # integration round-off is not contention
            stretch = 0.0
            end = start + float(nominal_s)
        self._transfers.append(
            (float(start), end, float(nbytes) / (end - start))
        )
        if stretch > 0.0:
            self.n_contended += 1
            self.contention_s_total += stretch
            self.episodes.append({
                "job_id": int(job_id),
                "t0": float(start),
                "t1": float(end),
                "peak_bytes_per_s": float(peak),
                "capacity": self.capacity,
                "contention_s": float(stretch),
            })
        return stretch

    def prune(self, now: float) -> None:
        """Drop transfers that ended at/before ``now`` (they can no
        longer overlap any future admission)."""
        self._transfers = [x for x in self._transfers if x[1] > now]


class AnalyticOracle:
    """Closed-form Hadoop-shaped job time; deterministic per (job, config).

    Terms (seconds; ``n`` = input tokens, ``S = n/M`` split size):

    * map:     ``ceil(M/W) * (setup_b + c_map_app*S + c_sort*S*log2(S))``
    * shuffle: ``c_shuf * n * (1 + 0.5/sqrt(R) + c_part*R)``
    * reduce:  ``ceil(R/W) * (setup_b + c_red * thr_b * n/R)``

    Backend ``b`` trades fixed launch overhead against throughput (pallas:
    high setup, best throughput — wins big jobs; jnp: the reverse), so the
    optimal (backend, M, R) shifts with job size, which is what gives a
    prediction-driven policy something to exploit.
    """

    platform = "sim-analytic-v1"
    #: analytic traces always carry per-phase walls + net counters, so a
    #: cluster with a finite ``net_capacity`` can price shared-fabric
    #: contention against this oracle's jobs.
    prices_contention = True

    #: per-token map cost by application (eximparse parses records: pricier).
    MAP_COST = {"wordcount": 8.0e-6, "eximparse": 1.2e-5}
    #: backend -> (per-wave launch overhead s, reduce throughput multiplier)
    BACKENDS = {"jnp": (0.05, 1.0), "xla": (0.065, 0.72), "pallas": (0.13, 0.5)}
    C_SORT = 4.0e-7     # map-side spill sort, per token per log2(split)
    C_SHUF = 2.0e-6     # shuffle bytes moved, per token
    C_PART = 0.004      # per-reducer partition/merge overhead
    C_RED = 6.0e-6      # reduce aggregation, per token
    C_PIPE = 0.012      # per-extra-depth pipeline fill/drain overhead
    C_COMB = 6.0e-7     # map-side combine, per emitted pair
    COMB_SETUP = 0.01   # combine barrier launch overhead, per job

    def __init__(
        self,
        *,
        noise: float = 0.02,
        seed: int = 0,
        shift_after_job: int | None = None,
        shift_factor: float = 1.0,
    ):
        self.noise = float(noise)
        self.seed = int(seed)
        #: injected mid-trace platform shift: every trace job with
        #: ``shift_after_job <= job_id < PROFILE_JOB_ID`` runs
        #: ``shift_factor`` x slower (same platform string — the point is
        #: that the *models* don't know).  Profiling job_ids are exempt:
        #: the bootstrap ran before the platform drifted.  This is the
        #: drift-alarm bench's ground truth (see ``repro.obs.drift``).
        self.shift_after_job = (
            None if shift_after_job is None else int(shift_after_job)
        )
        self.shift_factor = float(shift_factor)
        if self.shift_factor <= 0:
            raise ValueError("shift_factor must be > 0")
        self._last_call: tuple | None = None

    def _shift(self, job_id: int) -> float:
        if self.shift_after_job is None:
            return 1.0
        jid = int(job_id)
        if jid < self.shift_after_job or jid >= PROFILE_JOB_ID:
            return 1.0
        return self.shift_factor

    def backends(self) -> tuple[str, ...]:
        return tuple(self.BACKENDS)

    def _phase_components(
        self, app: str, backend: str, size: int,
        mappers: int, reducers: int, workers: int,
        combiner: bool = False,
    ) -> dict[str, float]:
        """Noise-free per-phase seconds — the closed-form decomposition.

        With ``combiner=True`` the dict gains a ``combine`` entry (the
        barrier pays ``C_COMB`` per emitted pair plus a fixed launch) and
        the shuffle term contracts by the expected combined-pairs ratio
        (:func:`expected_combined_pairs`) — pre-aggregation buys smaller
        fabric transfers at the price of extra map-side compute, so the
        knob has a genuine interior tradeoff for a policy to learn.
        """
        if app not in _APP_IDS:
            raise ValueError(f"unknown app {app!r}")
        if backend not in self.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        M, R, W = int(mappers), int(reducers), int(workers)
        if M < 1 or R < 1 or W < 1:
            raise ValueError(f"bad config M={M} R={R} W={W}")
        n = float(size)
        setup, thr = self.BACKENDS[backend]
        S = n / M
        map_waves = math.ceil(M / W)
        red_waves = math.ceil(R / W)
        t_map = map_waves * (
            setup
            + self.MAP_COST[app] * S
            + self.C_SORT * S * math.log2(max(S, 2.0))
        )
        t_shuffle = self.C_SHUF * n * (
            1.0 + 0.5 / math.sqrt(R) + self.C_PART * R
        )
        t_reduce = red_waves * (setup + self.C_RED * thr * n / R)
        out = {"map": t_map, "shuffle": t_shuffle, "reduce": t_reduce}
        if combiner:
            pairs = _PAIRS_PER_TOKEN[app] * n
            ratio = expected_combined_pairs(app, size, M) / max(pairs, 1.0)
            out["combine"] = self.COMB_SETUP + self.C_COMB * pairs
            out["shuffle"] = t_shuffle * min(1.0, ratio)
        return out

    def _cpu_components(
        self, phase_s: dict[str, float], size: int,
        mappers: int, reducers: int, workers: int,
    ) -> dict[str, float]:
        """Closed-form CPU task-seconds per phase (noise-free).

        Map and reduce burn one core per task: CPU = wall x tasks/waves
        (the busy-core count of the wave schedule, <= W by construction).
        The shuffle's ``c_shuf * n`` term is pure wire time; the
        imbalance and partition/merge terms are host CPU work, so
        shuffle CPU is the wall minus the wire term (single-threaded
        merge: always <= wall).  The combine barrier (if present) is
        pure local compute — no wire time — so its CPU equals its wall.
        """
        M, R, W = int(mappers), int(reducers), int(workers)
        wire = self.C_SHUF * float(size)
        out = {
            "map": phase_s["map"] * M / math.ceil(M / W),
            "shuffle": max(0.0, phase_s["shuffle"] - wire),
            "reduce": phase_s["reduce"] * R / math.ceil(R / W),
        }
        if "combine" in phase_s:
            out["combine"] = phase_s["combine"]
        return out

    def _overlapped_total(self, phase_s: dict[str, float], depth: int
                          ) -> float:
        """Closed-form total at overlap depth D.

        D=1 is the serial sum.  For D>1 the steady state runs map
        against shuffle+reduce concurrently: the longer side is fully
        exposed, the shorter side's exposure shrinks as 1/D (deeper
        pipelines hide more of it behind the critical path), and each
        extra stage pays a fill/drain cost ``C_PIPE`` — so the optimum
        depth is interior and config-dependent, exactly like M and R.
        """
        total = sum(phase_s.values())
        if depth <= 1:
            return total
        # The combine barrier (if present) rides the compute half of the
        # pipeline: it overlaps with the fabric side like the map does.
        t_map = phase_s["map"] + phase_s.get("combine", 0.0)
        t_sr = phase_s["shuffle"] + phase_s["reduce"]
        return (
            max(t_map, t_sr)
            + min(t_map, t_sr) / depth
            + self.C_PIPE * (depth - 1)
        )

    def _noise_factor(
        self, app, backend, M, R, W, job_id
    ) -> float:
        if self.noise <= 0.0:
            return 1.0
        ss = np.random.SeedSequence(
            [self.seed, int(job_id), int(M), int(R), int(W),
             _APP_IDS[app], _BACKEND_IDS[backend]]
        )
        rng = np.random.default_rng(ss)
        return float(np.exp(rng.normal(0.0, self.noise)))

    def time(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        job_id: int = 0,
        depth: int = 1,
        combiner: bool = False,
        _noiseless: bool = False,
    ) -> float:
        if int(depth) < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        phase_s = self._phase_components(
            app, backend, size, mappers, reducers, workers,
            combiner=bool(combiner),
        )
        t = self._overlapped_total(phase_s, int(depth))
        self._last_call = (
            app, backend, int(size), int(mappers), int(reducers),
            int(workers), int(job_id), int(depth), bool(combiner),
            bool(_noiseless),
        )
        if not _noiseless:
            t *= self._noise_factor(
                app, backend, mappers, reducers, workers, job_id
            )
        return t * self._shift(job_id)

    def take_trace(self):
        """Per-phase trace of the most recent :meth:`time` call (or None).

        Computed lazily from the stored call signature so the hot path
        (thousands of bootstrap-profiling calls per trace) pays one tuple
        assignment, not a trace construction.
        """
        if self._last_call is None:
            return None
        app, backend, size, M, R, W, job_id, depth, combiner, noiseless = \
            self._last_call
        phase_s = self._phase_components(
            app, backend, size, M, R, W, combiner=combiner
        )
        factor = (1.0 if noiseless else self._noise_factor(
            app, backend, M, R, W, job_id
        )) * self._shift(job_id)
        overlap = (
            sum(phase_s.values()) - self._overlapped_total(phase_s, depth)
        ) * factor
        return _analytic_trace(
            app, backend, size, M, R, W, phase_s, factor,
            depth=depth, overlap_s=overlap,
            cpu_s=self._cpu_components(phase_s, size, M, R, W),
            combined_pairs=(
                expected_combined_pairs(app, size, M) if combiner else None
            ),
        )

    # ---- partial execution (elastic layer) ------------------------------

    def remaining_segments(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        *,
        map_tasks_done: int = 0,
        shuffled: bool = False,
        reduce_tasks_done: int = 0,
        job_id: int = 0,
        combiner: bool = False,
        combined: bool = False,
        _noiseless: bool = False,
    ) -> list[tuple[str, float]]:
        """Per-wave-boundary segment costs of the *remaining* work.

        Returns ``[(kind, seconds), ...]`` with kind in
        ``{"map", "combine", "shuffle", "reduce"}`` — one entry per
        remaining map wave, one for the combine barrier (combiner jobs
        that have not passed it), one for the shuffle barrier (if not yet
        passed), one per remaining reduce wave, all under grant
        ``workers``.  The closed
        form is the exact per-wave decomposition of :meth:`time`: each map
        wave costs ``setup + c_map*S + c_sort*S*log2(S)``, the shuffle its
        full closed-form term, each reduce wave ``setup + c_red*thr*n/R``,
        scaled by the same per-(job, config) noise factor — so with zero
        progress the segment walls sum to :meth:`time` (modulo float
        associativity).  This is what prices partial execution for the
        elastic scheduler: regrants requantize the remaining tasks into
        waves of the *new* grant.
        """
        phase_s = self._phase_components(
            app, backend, size, mappers, reducers, workers,
            combiner=bool(combiner),
        )
        M, R, W = int(mappers), int(reducers), int(workers)
        factor = (1.0 if _noiseless else self._noise_factor(
            app, backend, M, R, W, job_id
        )) * self._shift(job_id)
        segs: list[tuple[str, float]] = []
        map_waves_left = math.ceil(max(0, M - int(map_tasks_done)) / W)
        per_map_wave = phase_s["map"] / math.ceil(M / W)
        segs += [("map", per_map_wave * factor)] * map_waves_left
        if combiner and not combined and not shuffled:
            segs.append(("combine", phase_s["combine"] * factor))
        if not shuffled:
            segs.append(("shuffle", phase_s["shuffle"] * factor))
        red_waves_left = math.ceil(max(0, R - int(reduce_tasks_done)) / W)
        per_red_wave = phase_s["reduce"] / math.ceil(R / W)
        segs += [("reduce", per_red_wave * factor)] * red_waves_left
        return segs

    def remaining_time(self, *args, **kwargs) -> float:
        """Total remaining seconds (sum of :meth:`remaining_segments`)."""
        return sum(t for _, t in self.remaining_segments(*args, **kwargs))

    def phase_profile(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        combiner: bool = False,
    ) -> dict:
        """Noise-free per-phase times, CPU seconds, and shuffle/fabric
        bytes for one config — the profiling source for decomposed
        (per-phase, per-resource) models.  With ``combiner=True`` the
        byte counters are the expected *combined* stream."""
        phase_s = self._phase_components(
            app, backend, size, mappers, reducers, workers,
            combiner=bool(combiner),
        )
        from repro.telemetry.trace import PAIR_BYTES

        pairs = _PAIRS_PER_TOKEN[app] * float(size)
        if combiner:
            pairs = min(pairs, expected_combined_pairs(app, size, mappers))
        nbytes = pairs * PAIR_BYTES
        return {
            "time_s": dict(phase_s),
            "shuffle_bytes": nbytes,
            "cpu_s": self._cpu_components(
                phase_s, size, mappers, reducers, workers
            ),
            "net_bytes": nbytes,
        }

    def nominal_time(self, app: str, size: int) -> float:
        """Noise-free time at a nominal mid-range config — the service-time
        estimate :func:`repro.cluster.workload.assign_deadlines` needs."""
        return self.time(app, "jnp", size, 16, 16, 4, _noiseless=True)


class EngineOracle:
    """Wall-clock the real MapReduce engine (compile-cached, one warmup).

    Every distinct (app, size, backend, M, R, W) costs a compile, so this is
    for small demonstration traces (see ``examples/cluster_sim.py --real``),
    not 50-job benchmark sweeps.  Sizes are snapped to multiples of 1024 to
    bound the compile-cache cardinality.

    Every execution path is a mode of one
    :class:`repro.mapreduce.plan.ExecutionPlan` per (app, size, backend,
    M, R): ``time`` wall-clocks the fused (or, with ``sharded=True``, the
    real ``shard_map`` mesh) mode, ``remaining_segments`` wall-clocks the
    resumable mode's wave steppers, and traced runs fence the same
    steppers per phase — so the scheduled path and the priced path can
    never drift.

    ``sharded=True`` (platform ``engine-sharded``) schedules the real
    multi-device mesh path: each grant W runs on a W-device mesh (built
    from the first W of ``jax.devices()``), and with ``traced=True`` the
    phases execute as separate mesh programs, so completed jobs carry
    per-phase *wall times* measured on the sharded engine — previously a
    single-controller-only capability.
    """

    def __init__(
        self, *, warmup: int = 1, size_quantum: int = 1024,
        traced: bool = False, sharded: bool = False,
        pipelined: bool = False, mesh_axis: str = "workers",
    ):
        self.warmup = warmup
        self.size_quantum = size_quantum
        self.sharded = bool(sharded)
        #: with pipelined=True, ``time(..., depth=D)`` with D > 1
        #: wall-clocks the plan's pipelined mode — the knob a depth-aware
        #: predictive policy profiles and chooses per job.  Off by
        #: default so depth requests can't silently hit the fused path.
        self.pipelined = bool(pipelined)
        if self.pipelined and self.sharded:
            raise ValueError(
                "pipelined=True is a single-controller mode; it does not "
                "compose with sharded=True"
            )
        self.mesh_axis = mesh_axis
        self.platform = "engine-sharded" if sharded else "engine-wallclock"
        #: with traced=True, jobs run through the phase-split telemetry
        #: path: every execution appends a JobTrace to ``recorder`` and
        #: ``take_trace`` exposes the latest to the cluster, so completed
        #: jobs carry per-phase observations (the online per-phase refit
        #: loop).  Timing then includes per-phase fencing overhead —
        #: consistent across configs, so models stay comparable.
        self.traced = bool(traced)
        #: contention pricing needs per-phase walls + net counters on
        #: every completed job — only the traced path records them.  An
        #: untraced engine oracle cannot price a shared fabric, and the
        #: cluster refuses ``net_capacity`` against it rather than
        #: silently skipping the charge.
        self.prices_contention = self.traced
        self.recorder = None
        if traced:
            from repro.telemetry import PhaseRecorder

            # Consumers only read recent traces (``take_trace``); bound
            # retention so bootstrap profiling (thousands of runs) doesn't
            # grow the recorder without limit over a long simulation.
            self.recorder = PhaseRecorder(max_traces=64)
        self._corpora: dict = {}
        self._jobs: dict = {}
        self._traced_jobs: dict = {}
        self._meshes: dict = {}
        self._warmed: set = set()   # (resumable id, grant) stepper warmups
        self._overheads: dict = {}  # measured (save_s, restore_s) cache

    def backends(self) -> tuple[str, ...]:
        return ("jnp", "xla")

    def _corpus(self, app: str, size: int):
        key = (app, size)
        if key not in self._corpora:
            from repro.mapreduce import exim_mainlog, eximparse, wordcount, \
                wordcount_corpus

            if app == "wordcount":
                self._corpora[key] = (
                    wordcount(4096), wordcount_corpus(size, vocab_size=4096)
                )
            elif app == "eximparse":
                self._corpora[key] = (
                    eximparse(1024), exim_mainlog(size, n_transactions=1024)
                )
            else:
                raise ValueError(f"unknown app {app!r}")
        return self._corpora[key]

    def _mesh_for(self, workers: int):
        """A ``workers``-device mesh over the first W local devices."""
        import jax
        import numpy as _np

        W = int(workers)
        if W not in self._meshes:
            devices = jax.devices()
            if W > len(devices):
                raise ValueError(
                    f"engine-sharded oracle needs {W} devices but only "
                    f"{len(devices)} are visible (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={W} for a "
                    "CPU emulation run)"
                )
            self._meshes[W] = jax.sharding.Mesh(
                _np.asarray(devices[:W]), (self.mesh_axis,)
            )
        return self._meshes[W]

    def _build_mode(self, app, backend, size, mappers, reducers, workers,
                    recorder, depth: int = 1, combiner: bool = False):
        """One ExecutionPlan, lowered in this oracle's scheduling mode."""
        from repro.mapreduce import ExecutionPlan, JobConfig

        mr_app, corpus = self._corpus(app, size)
        plan = ExecutionPlan(
            mr_app,
            JobConfig(
                num_mappers=int(mappers),
                num_reducers=int(reducers),
                num_workers=int(workers),
                combiner=bool(combiner),
                reduce_backend=backend,
                overlap_depth=int(depth),
            ),
            len(corpus),
        )
        if self.sharded:
            job = plan.sharded(
                self._mesh_for(workers), self.mesh_axis, recorder=recorder
            )
        elif recorder is not None:
            job = plan.traced(recorder)  # depth from the config
        elif int(depth) > 1:
            job = plan.pipelined()
        else:
            job = plan.fused()
        return job, corpus

    def _get_job(self, app, backend, size, mappers, reducers, workers,
                 depth: int = 1, combiner: bool = False):
        import jax

        # The combiner flag is part of the compile-cache identity: a
        # combined and an uncombined job at the same (M, R, W, depth)
        # lower different pipelines and must never share a cached trace.
        key = (app, size, backend, int(mappers), int(reducers),
               int(workers), int(depth), bool(combiner))
        if key not in self._jobs:
            job, corpus = self._build_mode(
                app, backend, size, mappers, reducers, workers,
                self.recorder, depth, combiner=bool(combiner),
            )
            for _ in range(self.warmup):
                jax.block_until_ready(job(corpus))
            self._jobs[key] = (job, corpus)
        return self._jobs[key]

    def time(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        job_id: int = 0,
        depth: int = 1,
        combiner: bool = False,
    ) -> float:
        import time as _time

        import jax

        if int(depth) < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if int(depth) > 1 and not self.pipelined:
            raise ValueError(
                "depth > 1 requires EngineOracle(pipelined=True)"
            )
        size = max(self.size_quantum,
                   (int(size) // self.size_quantum) * self.size_quantum)
        job, corpus = self._get_job(
            app, backend, size, mappers, reducers, workers, int(depth),
            combiner=bool(combiner),
        )
        t0 = _time.perf_counter()
        jax.block_until_ready(job(corpus))
        return _time.perf_counter() - t0

    def take_trace(self):
        """JobTrace of the most recent execution (traced mode), else None."""
        if self.recorder is None or not len(self.recorder):
            return None
        return self.recorder.last

    def phase_profile(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        combiner: bool = False,
    ) -> dict:
        """Measured per-phase times + shuffle bytes for one config.

        Runs the real engine through the telemetry path (one compile per
        distinct config — same cost caveat as :meth:`time`).  Available
        regardless of ``traced``: an untraced oracle keeps a separate
        traced-job cache so :meth:`time` stays on the fused path.
        """
        if self.recorder is not None:
            self.time(app, backend, size, mappers, reducers, workers,
                      combiner=bool(combiner))
            return self._profile_from(self.recorder.last)

        import jax

        from repro.telemetry import PhaseRecorder

        size = max(self.size_quantum,
                   (int(size) // self.size_quantum) * self.size_quantum)
        key = (app, size, backend, int(mappers), int(reducers),
               int(workers), bool(combiner))
        if key not in self._traced_jobs:
            rec = PhaseRecorder(max_traces=4)
            job, corpus = self._build_mode(
                app, backend, size, mappers, reducers, workers, rec,
                combiner=bool(combiner),
            )
            for _ in range(self.warmup):
                jax.block_until_ready(job(corpus))
            self._traced_jobs[key] = (job, corpus, rec)
        job, corpus, rec = self._traced_jobs[key]
        jax.block_until_ready(job(corpus))
        return self._profile_from(rec.last)

    @staticmethod
    def _profile_from(trace) -> dict:
        times = trace.phase_times()
        return {
            "time_s": times,
            "shuffle_bytes": trace.counter("shuffle", "bytes_out"),
            "cpu_s": {
                ph: trace.counter(ph, "cpu_s", 0.0) for ph in times
            },
            "net_bytes": trace.counter(
                "shuffle", "net_bytes",
                trace.counter("shuffle", "bytes_in", 0.0),
            ),
        }

    def nominal_time(self, app: str, size: int) -> float:
        return self.time(app, "jnp", size, 8, 8, 4)

    # ---- partial execution (elastic layer) ------------------------------

    def _get_resumable(self, app, backend, size, mappers, reducers,
                       combiner: bool = False):
        from repro.elastic.resumable import ResumableJob
        from repro.mapreduce import JobConfig

        key = ("resumable", app, size, backend, int(mappers),
               int(reducers), bool(combiner))
        if key not in self._jobs:
            mr_app, corpus = self._corpus(app, size)
            job = ResumableJob(
                mr_app,
                JobConfig(
                    num_mappers=int(mappers),
                    num_reducers=int(reducers),
                    num_workers=1,
                    combiner=bool(combiner),
                    reduce_backend=backend,
                ),
                len(corpus),
            )
            self._jobs[key] = (job, corpus)
        return self._jobs[key]

    def remaining_segments(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        workers: int,
        *,
        map_tasks_done: int = 0,
        shuffled: bool = False,
        reduce_tasks_done: int = 0,
        job_id: int = 0,
        combiner: bool = False,
        combined: bool = False,
    ) -> list[tuple[str, float]]:
        """Wave-step the *real* engine over the remaining work, wall-
        clocking each step — the engine-backed twin of
        :meth:`AnalyticOracle.remaining_segments`.

        A fresh resumable state is advanced (untimed) to the cursor, then
        each remaining wave-boundary step is executed and fenced.  A done
        count that is not a multiple of ``workers`` is snapped *down* to
        the last reachable boundary, so the partially-covered wave is
        priced as a full remaining wave — the same conservative wave
        quantization as :meth:`AnalyticOracle.remaining_segments`, and
        never an under-estimate.  Every distinct (app, size, backend,
        M, R) compiles its steppers once per grant — small demo traces
        and tests only (mark slow).
        """
        import time as _time

        import jax

        size = max(self.size_quantum,
                   (int(size) // self.size_quantum) * self.size_quantum)
        job, corpus = self._get_resumable(
            app, backend, size, mappers, reducers, combiner=bool(combiner)
        )
        # Warm the steppers for this grant once, untimed (compile fence).
        warm_key = (id(job), int(workers))
        if warm_key not in self._warmed:
            job.run(corpus, state=job.regrant(job.initial_state(),
                                              int(workers)))
            self._warmed.add(warm_key)
        state = job.regrant(job.initial_state(), int(workers))
        # Advance untimed to the cursor, never past it: only take a step
        # whose (clamped) endpoint still lies within the done counts.
        W = int(workers)
        M, R = int(mappers), int(reducers)
        target_m = min(int(map_tasks_done), M)
        target_r = min(int(reduce_tasks_done), R)
        while not state.cursor.done:
            c = state.cursor
            if not c.map_done:
                if min(M, c.map_tasks_done + W) > target_m:
                    break
            elif combiner and not c.combined and not c.shuffled:
                if not (combined or shuffled):
                    break
            elif not c.shuffled:
                if not shuffled:
                    break
            elif min(R, c.reduce_tasks_done + W) > target_r:
                break
            state = job.step(state, corpus)
        segs: list[tuple[str, float]] = []
        while not state.cursor.done:
            before = state.cursor
            t0 = _time.perf_counter()
            state = job.step(state, corpus)
            for leaf in state.arrays.values():
                jax.block_until_ready(leaf)
            dt = _time.perf_counter() - t0
            if before.map_tasks_done != state.cursor.map_tasks_done:
                segs.append(("map", dt))
            elif before.combined != state.cursor.combined:
                segs.append(("combine", dt))
            elif before.shuffled != state.cursor.shuffled:
                segs.append(("shuffle", dt))
            else:
                segs.append(("reduce", dt))
        return segs

    def remaining_time(self, *args, **kwargs) -> float:
        """Total remaining seconds (sum of :meth:`remaining_segments`)."""
        return sum(t for _, t in self.remaining_segments(*args, **kwargs))

    def regrant_overhead(
        self,
        app: str,
        backend: str,
        size: int,
        mappers: int,
        reducers: int,
        *,
        map_tasks_done: int = 0,
        shuffled: bool = False,
        reduce_tasks_done: int = 0,
        combiner: bool = False,
    ) -> tuple[float, float]:
        """Measured ``(save_s, restore_s)`` walls of a real wave-boundary
        snapshot round-trip at this cursor — what a preemption *actually*
        costs on this engine, fed to
        :meth:`repro.elastic.regrant.RegrantCostModel.record_overhead`
        (and charged by the elastic simulator) in place of configured
        estimates.

        The snapshot layout changes at the shuffle barrier (map
        accumulators before, partitions after), so measurements are
        cached per (job, phase-of-life) bucket; within a bucket the cost
        is cursor-independent (canonical task-major buffers have static
        shapes).
        """
        import tempfile

        from repro.checkpoint import CheckpointManager
        from repro.elastic.snapshot import load_snapshot, save_snapshot

        size = max(self.size_quantum,
                   (int(size) // self.size_quantum) * self.size_quantum)
        job, corpus = self._get_resumable(
            app, backend, size, mappers, reducers, combiner=bool(combiner)
        )
        # The snapshot layout flips only once the shuffle barrier has
        # *executed* (map accumulators swap for partitions + outputs); a
        # map-complete-but-unshuffled cursor still carries the pre-shuffle
        # buffers, so it prices in the pre-shuffle bucket.
        post_shuffle = bool(shuffled)
        key = (id(job), post_shuffle)
        if key not in self._overheads:
            state = job.initial_state()
            if post_shuffle:
                # Advance through the barrier so the snapshot carries the
                # post-shuffle (partitions + output) layout.
                while not state.cursor.shuffled:
                    state = job.step(state, corpus)
            with tempfile.TemporaryDirectory() as d:
                mgr = CheckpointManager(d, keep=1)
                _, save_s = save_snapshot(mgr, state)
                _, _, restore_s = load_snapshot(mgr)
            self._overheads[key] = (float(save_s), float(restore_s))
        return self._overheads[key]
