"""Workload generation for the cluster scheduling layer.

A workload is a *trace*: a time-ordered stream of heterogeneous MapReduce
jobs (the paper's two applications at varying input sizes), each with an
arrival time drawn from a configurable arrival process and, optionally, a
completion deadline (SLO).  Traces are fully determined by their seed so
every policy in a benchmark sees the identical job stream — the multi-job
analogue of the paper's "same experiment set for every model" discipline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

#: applications the workload generator knows how to emit (the paper's two).
APPS = ("wordcount", "eximparse")

ARRIVALS = ("poisson", "uniform", "bursty")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One job in a trace (immutable; scheduling state lives in JobRecord)."""

    job_id: int
    app: str                 # "wordcount" | "eximparse"
    size: int                # input size in tokens
    arrival: float           # seconds since trace start
    deadline: float | None = None  # absolute completion deadline, or None

    def __post_init__(self):
        if self.app not in APPS:
            raise ValueError(f"unknown app {self.app!r}; expected {APPS}")
        if self.size < 1 or self.arrival < 0:
            raise ValueError(f"bad job spec {self}")


def _interarrival_gaps(
    n: int, arrival: str, mean_gap: float, rng: np.random.Generator
) -> np.ndarray:
    if arrival == "poisson":
        return rng.exponential(mean_gap, size=n)
    if arrival == "uniform":
        return rng.uniform(0.0, 2.0 * mean_gap, size=n)
    if arrival == "bursty":
        # Bursts of back-to-back arrivals separated by long idle gaps:
        # same mean rate as "poisson", much higher variance — the stress
        # case for admission control.
        in_burst = rng.random(n) < 0.75
        long_gap = rng.exponential(4.0 * mean_gap, size=n)
        short_gap = rng.exponential(mean_gap / 12.0, size=n)
        return np.where(in_burst, short_gap, long_gap)
    raise ValueError(f"unknown arrival process {arrival!r}; expected {ARRIVALS}")


def generate_workload(
    n_jobs: int,
    *,
    seed: int = 0,
    arrival: str = "poisson",
    mean_interarrival: float = 0.5,
    apps: Sequence[str] = APPS,
    app_weights: Sequence[float] | None = None,
    size_range: tuple[int, int] = (1 << 14, 1 << 18),
    first_arrival: float = 0.0,
) -> list[JobSpec]:
    """Generate a deterministic heterogeneous trace of ``n_jobs`` jobs.

    Sizes are log-uniform over ``size_range`` (small jobs are common, big
    jobs dominate total work — the canonical heavy-tailed cluster mix);
    applications are drawn with ``app_weights`` (uniform by default).
    Deadlines are assigned separately by :func:`assign_deadlines` because a
    sensible deadline needs a service-time estimate, which is the
    scheduler's (oracle/model's) business, not the trace's.
    """
    if n_jobs < 1:
        raise ValueError("n_jobs must be >= 1")
    for a in apps:
        if a not in APPS:
            raise ValueError(f"unknown app {a!r}")
    rng = np.random.default_rng(seed)
    gaps = _interarrival_gaps(n_jobs, arrival, mean_interarrival, rng)
    gaps[0] = first_arrival
    arrivals = np.cumsum(gaps)
    lo, hi = size_range
    sizes = np.exp(
        rng.uniform(math.log(lo), math.log(hi), size=n_jobs)
    ).astype(np.int64)
    p = None
    if app_weights is not None:
        w = np.asarray(app_weights, dtype=np.float64)
        p = w / w.sum()
    chosen = rng.choice(len(apps), size=n_jobs, p=p)
    return [
        JobSpec(
            job_id=i,
            app=apps[int(chosen[i])],
            size=int(sizes[i]),
            arrival=float(arrivals[i]),
        )
        for i in range(n_jobs)
    ]


def assign_deadlines(
    jobs: Sequence[JobSpec],
    service_estimate: Callable[[JobSpec], float],
    *,
    slack_range: tuple[float, float] = (1.5, 4.0),
    fraction: float = 1.0,
    seed: int = 0,
) -> list[JobSpec]:
    """Attach deadlines: ``arrival + slack * service_estimate(job)``.

    ``service_estimate`` is typically the runtime oracle (or a fitted model)
    evaluated at a nominal configuration; ``slack_range`` draws a per-job
    multiplier, so some jobs are comfortably feasible and some are tight —
    the spread an admission-control policy has to discriminate.  Only a
    ``fraction`` of jobs get deadlines (the rest are best-effort, deadline
    ``None``).
    """
    rng = np.random.default_rng(seed)
    out = []
    for job in jobs:
        if rng.random() <= fraction:
            slack = rng.uniform(*slack_range)
            deadline = job.arrival + slack * float(service_estimate(job))
            out.append(dataclasses.replace(job, deadline=deadline))
        else:
            out.append(job)
    return out
