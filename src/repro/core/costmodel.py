"""Analytic execution-time source: roofline terms from compiled dry-runs.

On a TPU-less container the profiler cannot wall-clock at-scale workloads, so
this module turns a compiled XLA artifact into the three roofline terms the
grading methodology specifies (all per-device, post-SPMD — ``cost_analysis``
reports the per-device program after partitioning):

    compute    = HLO_flops / peak_flops            (s)
    memory     = HLO_bytes / hbm_bandwidth         (s)
    collective = collective_bytes / ici_bandwidth  (s)

``collective_bytes`` is not in cost_analysis; we parse the compiled HLO text
and sum the *output* operand sizes of every collective op (all-gather,
all-reduce, reduce-scatter, all-to-all, collective-permute).  The estimated
step time is max(compute, memory) + collective when overlap is off, and
max(compute, memory, collective) under perfect overlap — both are reported.

This is also the ``AnalyticTimer`` backend for the paper's profiling phase at
scale: time(config) := estimated step time of the config's compiled artifact.
"""

from __future__ import annotations

import dataclasses
import math
import re


# TPU v5e hardware constants (per brief).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (approx, per brief)
HBM_BYTES = 16 * 1024**3       # 16 GiB per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096,1024]{2,1,0}" or "f32[]" — capture dtype + dims.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# Matches `  %x = TYPE all-gather(...)` / `ROOT %y = (..) all-reduce-start(`
_COLLECTIVE_LINE_RE = re.compile(
    r"=\s+(?P<shape>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (tuple shapes -> sum of elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue  # e.g. token[] / opaque
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind byte totals parsed from compiled (post-SPMD) HLO."""

    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-operand bytes of every collective in compiled HLO text.

    ``-start`` variants are counted once (their paired ``-done`` line has no
    own shape production matched by the regex since it's `<kind>-done(` which
    doesn't match our kind group followed by `(` — it does! guard explicitly).
    """
    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: already counted at -start
        m = _COLLECTIVE_LINE_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        nbytes = _shape_bytes(m.group("shape"))
        bytes_by_kind[kind] += nbytes
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind=bytes_by_kind, count_by_kind=count_by_kind)


@dataclasses.dataclass
class RooflineReport:
    """The §Roofline record for one (arch, shape, mesh) cell."""

    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device HLO bytes accessed
    collective_bytes: float       # per-device collective bytes (HLO output sums)
    compute_s: float
    memory_s: float
    collective_s: float
    peak_hbm_bytes: float         # memory_analysis peak (args+temp) per device
    dominant: str
    # Usefulness accounting
    model_flops: float | None = None   # 6*N*D (train) / 2*N*D-style (serve), GLOBAL
    useful_ratio: float | None = None  # model_flops / (flops * n_devices)
    collectives: CollectiveStats | None = None

    @property
    def step_time_no_overlap(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def step_time_overlap(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful model FLOP/s achieved ÷ peak, at the no-overlap step time.

        This is the score-bearing number: it charges every inefficiency
        (redundant compute, memory stalls, exposed collectives) against the
        machine's peak.
        """
        if not self.model_flops:
            return float("nan")
        return self.flops_fraction_of_peak

    @property
    def flops_fraction_of_peak(self) -> float:
        if not self.model_flops or self.n_devices is None:
            return float("nan")
        per_dev_useful = self.model_flops / self.n_devices
        t = self.step_time_no_overlap
        return (per_dev_useful / t) / PEAK_FLOPS_BF16 if t > 0 else float("nan")

    n_devices: int | None = None

    def to_dict(self) -> dict:
        d = {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "peak_hbm_bytes": self.peak_hbm_bytes,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "n_devices": self.n_devices,
            "step_time_no_overlap": self.step_time_no_overlap,
            "step_time_overlap": self.step_time_overlap,
            "roofline_fraction": self.flops_fraction_of_peak,
        }
        if self.collectives is not None:
            d["collective_bytes_by_kind"] = self.collectives.bytes_by_kind
            d["collective_count_by_kind"] = self.collectives.count_by_kind
        return d


def roofline_from_compiled(
    compiled,
    *,
    n_devices: int,
    model_flops: float | None = None,
    hlo_text: str | None = None,
) -> RooflineReport:
    """Derive the three roofline terms from a jax Compiled object."""
    from repro.compat import cost_analysis_dict

    cost = cost_analysis_dict(compiled)
    # cost_analysis is per-device for SPMD-partitioned modules.
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    collective_bytes = float(coll.total_bytes)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    useful = None
    if model_flops is not None and flops > 0:
        useful = model_flops / (flops * n_devices)
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        peak_hbm_bytes=peak,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        collectives=coll,
        n_devices=n_devices,
    )


def format_seconds(s: float) -> str:
    if s == 0 or math.isnan(s):
        return f"{s:.3g}s"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1:
        return f"{s * 1e3:.2f}ms"
    return f"{s:.3f}s"
