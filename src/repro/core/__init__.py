"""Core: the paper's profiling -> modeling -> prediction pipeline.

Paper: "On Modeling Dependency between MapReduce Configuration Parameters and
Total Execution Time" (Rizvandi et al., 2012).
"""

from repro.core.features import (
    FeatureSpec,
    design_matrix,
    fit_feature_spec,
    grid,
)
from repro.core.profiler import (
    ProfileResult,
    profile_categorical,
    profile_experiments,
    timeit,
)
from repro.core.predictor import ModelDatabase
from repro.core.regression import (
    RegressionModel,
    fit,
    prediction_error_stats,
)
from repro.core.costmodel import (
    RooflineReport,
    parse_collectives,
    roofline_from_compiled,
)
from repro.core.tuner import (
    CategoricalTuneResult,
    TuneResult,
    mesh_factorizations,
    tune,
    tune_categorical,
    validate,
)

__all__ = [
    "FeatureSpec",
    "design_matrix",
    "fit_feature_spec",
    "grid",
    "ProfileResult",
    "profile_categorical",
    "profile_experiments",
    "timeit",
    "ModelDatabase",
    "RegressionModel",
    "fit",
    "prediction_error_stats",
    "RooflineReport",
    "parse_collectives",
    "roofline_from_compiled",
    "CategoricalTuneResult",
    "TuneResult",
    "mesh_factorizations",
    "tune",
    "tune_categorical",
    "validate",
]
