"""Multivariate linear regression on polynomial features (paper Eqn. 3-6).

Paper-faithful solver: ordinary least squares via the normal equations,
``A = (P^T P)^{-1} P^T T``.  We solve the system with a Cholesky/LU solve of
``(P^T P + lam*I) A = P^T T`` rather than forming the explicit inverse, which
is algebraically identical at lam=0 but numerically saner; ``lam`` defaults to
0 (paper-faithful) with an opt-in ridge.

Beyond-paper (opt-in, benchmarked separately):
* ridge regularization (``lam > 0``);
* IRLS robust refit (the paper cites Wood et al. [29] for weighting
  high-error points; we implement Huber-weighted iteratively reweighted
  least squares);
* float64 path for ill-conditioned unscaled cubic features.

Everything is pure JAX and jit-friendly; `fit` is also exposed jitted for the
batched case (fitting many application models at once — the "model database"
refresh path).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import FeatureSpec, design_matrix, fit_feature_spec


@dataclasses.dataclass(frozen=True)
class RegressionModel:
    """A fitted config->time model for one (application, platform)."""

    spec: FeatureSpec
    coef: np.ndarray  # (F,) alpha vector, paper ordering
    # Fit diagnostics.
    train_rmse: float
    train_mape: float  # mean |err|/|T| in percent, paper's error metric
    r2: float

    def predict(self, params) -> jnp.ndarray:
        """Paper Eqn. 4-5: evaluate the fitted polynomial."""
        P = design_matrix(self.spec, params)
        return P @ jnp.asarray(self.coef, dtype=P.dtype)

    def to_dict(self) -> dict:
        return {
            "spec": dataclasses.asdict(self.spec),
            "coef": np.asarray(self.coef).tolist(),
            "train_rmse": self.train_rmse,
            "train_mape": self.train_mape,
            "r2": self.r2,
        }

    @staticmethod
    def from_dict(d: dict) -> "RegressionModel":
        spec_d = dict(d["spec"])
        for k in ("lo", "hi"):
            if spec_d.get(k) is not None:
                spec_d[k] = tuple(spec_d[k])
        return RegressionModel(
            spec=FeatureSpec(**spec_d),
            coef=np.asarray(d["coef"], dtype=np.float64),
            train_rmse=float(d["train_rmse"]),
            train_mape=float(d["train_mape"]),
            r2=float(d["r2"]),
        )


@partial(jax.jit, static_argnames=("dtype",))
def _solve_normal_equations(P, T, lam, dtype=jnp.float32):
    """A = (P^T P + lam I)^{-1} P^T T  via a linear solve (paper Eqn. 6)."""
    P = P.astype(dtype)
    T = T.astype(dtype)
    G = P.T @ P  # (F, F) Gram matrix
    F = G.shape[0]
    G = G + lam * jnp.eye(F, dtype=dtype)
    b = P.T @ T
    return jnp.linalg.solve(G, b)


@partial(jax.jit, static_argnames=("dtype", "iters"))
def _irls_huber(P, T, coef0, delta, lam, dtype=jnp.float32, iters=5):
    """Huber-weighted IRLS refinement (beyond-paper robust refit).

    Downweights experiments whose residual exceeds ``delta`` — the same
    intent as the paper's cited Robust Stepwise Regression post-processing.
    """
    P = P.astype(dtype)
    T = T.astype(dtype)
    F = P.shape[1]

    def body(coef, _):
        r = T - P @ coef
        absr = jnp.abs(r) + 1e-12
        w = jnp.minimum(1.0, delta / absr)  # Huber weights
        Pw = P * w[:, None]
        G = Pw.T @ P + lam * jnp.eye(F, dtype=dtype)
        b = Pw.T @ T
        return jnp.linalg.solve(G, b), None

    coef, _ = jax.lax.scan(body, coef0.astype(dtype), None, length=iters)
    return coef


def fit(
    params,
    times,
    *,
    degree: int = 3,
    cross_terms: bool = False,
    scale: bool = False,
    lam: float = 0.0,
    robust: bool = False,
    huber_delta: float | None = None,
    dtype=jnp.float64,
) -> RegressionModel:
    """Fit the paper's model.  Defaults (modulo dtype) are paper-faithful.

    params: (M, N) raw configuration parameter values.
    times:  (M,)  mean total execution time per experiment (profiler output).

    dtype=float64 runs the solve in numpy float64 (JAX x64 is disabled by
    default and flipping it is global); float32 uses the jitted JAX path.
    """
    params = np.asarray(params, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    if params.ndim != 2 or times.ndim != 1 or params.shape[0] != times.shape[0]:
        raise ValueError(
            f"bad shapes params={params.shape} times={times.shape}"
        )
    M, N = params.shape
    spec = fit_feature_spec(
        params, degree=degree, cross_terms=cross_terms, scale=scale
    )
    if M < spec.n_features:
        raise ValueError(
            f"underdetermined fit: M={M} experiments < F={spec.n_features} "
            f"features (paper requires M >> N)"
        )
    P = np.asarray(design_matrix(spec, params), dtype=np.float64)

    if dtype == jnp.float64:
        # Normal-equations solve in numpy float64 (paper Eqn. 6).
        G = P.T @ P + lam * np.eye(P.shape[1])
        coef = np.linalg.solve(G, P.T @ times)
        if robust:
            delta = huber_delta or 1.345 * max(
                1e-12, float(np.std(times - P @ coef))
            )
            for _ in range(5):
                r = times - P @ coef
                w = np.minimum(1.0, delta / (np.abs(r) + 1e-12))
                Pw = P * w[:, None]
                G = Pw.T @ P + lam * np.eye(P.shape[1])
                coef = np.linalg.solve(G, Pw.T @ times)
    else:
        coef = np.asarray(
            _solve_normal_equations(
                jnp.asarray(P), jnp.asarray(times), lam, dtype=dtype
            ),
            dtype=np.float64,
        )
        if robust:
            delta = huber_delta or 1.345 * max(
                1e-12, float(np.std(times - P @ coef))
            )
            coef = np.asarray(
                _irls_huber(
                    jnp.asarray(P), jnp.asarray(times), jnp.asarray(coef),
                    delta, lam, dtype=dtype,
                ),
                dtype=np.float64,
            )

    pred = P @ coef
    resid = times - pred
    rmse = float(np.sqrt(np.mean(resid**2)))
    mape = float(np.mean(np.abs(resid) / np.maximum(np.abs(times), 1e-12))) * 100
    ss_res = float(np.sum(resid**2))
    ss_tot = float(np.sum((times - times.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return RegressionModel(
        spec=spec, coef=coef, train_rmse=rmse, train_mape=mape, r2=r2
    )


def prediction_error_stats(model: RegressionModel, params, times) -> dict:
    """Paper Table 1: mean and variance of |pred - actual| / actual in %."""
    times = np.asarray(times, dtype=np.float64)
    pred = np.asarray(model.predict(params), dtype=np.float64)
    err_pct = np.abs(pred - times) / np.maximum(np.abs(times), 1e-12) * 100
    return {
        "mean_pct": float(np.mean(err_pct)),
        "var_pct": float(np.var(err_pct)),
        "median_pct": float(np.median(err_pct)),
        "max_pct": float(np.max(err_pct)),
        "per_experiment_pct": err_pct.tolist(),
    }
