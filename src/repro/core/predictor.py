"""Prediction phase + model database (paper Fig. 2b).

The paper keeps one fitted model per application in a database, keyed so that
a model is only ever used for the *same application on the same platform*
(its stated validity boundary).  ``ModelDatabase`` enforces that key structure
and persists to JSON so a long-lived scheduler can reload models across
restarts — the paper's motivating use case (smarter job scheduling).

Beyond the paper's two-part key, the database carries two optional key
components:

* ``backend`` — the MapReduce engine's execution backend is a categorical
  knob (see ``core.tuner.tune_categorical``), and the paper's pattern of
  "one model per category" needs one store slot per
  (application, platform, backend);
* ``resource`` — the telemetry layer (``repro.telemetry``) decomposes the
  total time into per-(phase, resource) models ("map:time_s",
  "shuffle:bytes_out", ...); the empty resource ``""`` is the monolithic
  total-time model.

Both default to ``""`` (the paper-faithful two-part key), so existing call
sites are unchanged; JSON files written with 2-part or 3-part keys load
transparently, and databases containing no resource-qualified models write
the same 3-part format PR 2 produced.
"""

from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

from repro.core.regression import RegressionModel

_SEP = "\x00"


class ModelDatabase:
    """Per-(application, platform[, backend[, resource]]) RegressionModels."""

    def __init__(self) -> None:
        self._models: dict[tuple[str, str, str, str], RegressionModel] = {}

    @staticmethod
    def _key(
        application: str,
        platform: str,
        backend: str = "",
        resource: str = "",
    ) -> tuple[str, str, str, str]:
        return (application, platform, backend, resource)

    def put(
        self,
        application: str,
        platform: str,
        model: RegressionModel,
        backend: str = "",
        resource: str = "",
    ) -> None:
        self._models[
            self._key(application, platform, backend, resource)
        ] = model

    def get(
        self,
        application: str,
        platform: str,
        backend: str = "",
        resource: str = "",
    ) -> RegressionModel:
        key = self._key(application, platform, backend, resource)
        if key not in self._models:
            raise KeyError(
                f"no model for application={application!r} on "
                f"platform={platform!r}"
                + (f" backend={backend!r}" if backend else "")
                + (f" resource={resource!r}" if resource else "")
                + "; the paper's models do not transfer "
                "across applications or platforms — profile first."
            )
        return self._models[key]

    def __contains__(self, key: tuple[str, ...]) -> bool:
        return self._key(*key) in self._models

    def __len__(self) -> int:
        return len(self._models)

    def applications(self) -> list[tuple[str, ...]]:
        """Stored keys; the resource component is elided when empty, so
        resource-less databases keep the PR 2 three-part shape."""
        return sorted(
            key if key[3] else key[:3] for key in self._models
        )

    def backends_for(self, application: str, platform: str) -> list[str]:
        """Backend key components stored for one (application, platform),
        over total-time (resource ``""``) models only.

        This is how a scheduler enumerates the categories available for the
        joint (backend, config) argmin — see ``repro.cluster.policies``.
        """
        return sorted(
            b
            for (a, p, b, res) in self._models
            if (a, p, res) == (application, platform, "")
        )

    def resources_for(
        self, application: str, platform: str, backend: str = ""
    ) -> list[str]:
        """Non-empty resource key components stored for one
        (application, platform, backend) — the telemetry layer's decomposed
        per-(phase, resource) models."""
        return sorted(
            res
            for (a, p, b, res) in self._models
            if (a, p, b) == (application, platform, backend) and res
        )

    def predict(
        self,
        application: str,
        platform: str,
        params: Sequence[float],
        backend: str = "",
        resource: str = "",
    ) -> float:
        """Paper Fig. 2b: look up the app's model, evaluate Eqn. 5."""
        model = self.get(application, platform, backend, resource)
        return float(np.asarray(model.predict(np.asarray(params))).ravel()[0])

    # ---- persistence ----------------------------------------------------

    def save(self, path: str) -> None:
        payload = {}
        for key, model in self._models.items():
            app, plat, backend, resource = key
            # Resource-less keys keep the PR 2 3-part wire format so older
            # readers (and existing fixtures) stay compatible.
            parts = [app, plat, backend] + ([resource] if resource else [])
            payload[_SEP.join(parts)] = model.to_dict()
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic publish

    @classmethod
    def load(cls, path: str) -> "ModelDatabase":
        db = cls()
        with open(path) as f:
            payload = json.load(f)
        for key, d in payload.items():
            parts = key.split(_SEP)
            if len(parts) < 2 or len(parts) > 4:
                raise ValueError(f"malformed model key {key!r} in {path}")
            # Legacy files: 2-part (app, platform) and 3-part (+backend).
            parts = parts + [""] * (4 - len(parts))
            app, plat, backend, resource = parts
            db.put(
                app, plat, RegressionModel.from_dict(d),
                backend=backend, resource=resource,
            )
        return db
