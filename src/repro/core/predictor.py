"""Prediction phase + model database (paper Fig. 2b).

The paper keeps one fitted model per application in a database, keyed so that
a model is only ever used for the *same application on the same platform*
(its stated validity boundary).  ``ModelDatabase`` enforces that key structure
and persists to JSON so a long-lived scheduler can reload models across
restarts — the paper's motivating use case (smarter job scheduling).
"""

from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

from repro.core.regression import RegressionModel


class ModelDatabase:
    """Per-(application, platform) store of fitted RegressionModels."""

    def __init__(self) -> None:
        self._models: dict[tuple[str, str], RegressionModel] = {}

    @staticmethod
    def _key(application: str, platform: str) -> tuple[str, str]:
        return (application, platform)

    def put(self, application: str, platform: str, model: RegressionModel):
        self._models[self._key(application, platform)] = model

    def get(self, application: str, platform: str) -> RegressionModel:
        key = self._key(application, platform)
        if key not in self._models:
            raise KeyError(
                f"no model for application={application!r} on "
                f"platform={platform!r}; the paper's models do not transfer "
                f"across applications or platforms — profile first."
            )
        return self._models[key]

    def __contains__(self, key: tuple[str, str]) -> bool:
        return self._key(*key) in self._models

    def __len__(self) -> int:
        return len(self._models)

    def applications(self) -> list[tuple[str, str]]:
        return sorted(self._models)

    def predict(
        self, application: str, platform: str, params: Sequence[float]
    ) -> float:
        """Paper Fig. 2b: look up the app's model, evaluate Eqn. 5."""
        model = self.get(application, platform)
        return float(np.asarray(model.predict(np.asarray(params))).ravel()[0])

    # ---- persistence ----------------------------------------------------

    def save(self, path: str) -> None:
        payload = {
            f"{app}\x00{plat}": model.to_dict()
            for (app, plat), model in self._models.items()
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic publish

    @classmethod
    def load(cls, path: str) -> "ModelDatabase":
        db = cls()
        with open(path) as f:
            payload = json.load(f)
        for key, d in payload.items():
            app, plat = key.split("\x00")
            db.put(app, plat, RegressionModel.from_dict(d))
        return db
