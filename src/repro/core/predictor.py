"""Prediction phase + model database (paper Fig. 2b).

The paper keeps one fitted model per application in a database, keyed so that
a model is only ever used for the *same application on the same platform*
(its stated validity boundary).  ``ModelDatabase`` enforces that key structure
and persists to JSON so a long-lived scheduler can reload models across
restarts — the paper's motivating use case (smarter job scheduling).

Beyond the paper's two-part key, the database also carries an optional
``backend`` component: the MapReduce engine's execution backend is a
categorical knob (see ``core.tuner.tune_categorical``), and the paper's
pattern of "one model per category" needs one store slot per
(application, platform, backend).  ``backend=""`` (the default) is the
paper-faithful two-part key, so existing call sites are unchanged; JSON
files written before this extension load transparently.
"""

from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

from repro.core.regression import RegressionModel

_SEP = "\x00"


class ModelDatabase:
    """Per-(application, platform[, backend]) store of RegressionModels."""

    def __init__(self) -> None:
        self._models: dict[tuple[str, str, str], RegressionModel] = {}

    @staticmethod
    def _key(
        application: str, platform: str, backend: str = ""
    ) -> tuple[str, str, str]:
        return (application, platform, backend)

    def put(
        self,
        application: str,
        platform: str,
        model: RegressionModel,
        backend: str = "",
    ) -> None:
        self._models[self._key(application, platform, backend)] = model

    def get(
        self, application: str, platform: str, backend: str = ""
    ) -> RegressionModel:
        key = self._key(application, platform, backend)
        if key not in self._models:
            raise KeyError(
                f"no model for application={application!r} on "
                f"platform={platform!r}"
                + (f" backend={backend!r}" if backend else "")
                + "; the paper's models do not transfer "
                "across applications or platforms — profile first."
            )
        return self._models[key]

    def __contains__(self, key: tuple[str, ...]) -> bool:
        return self._key(*key) in self._models

    def __len__(self) -> int:
        return len(self._models)

    def applications(self) -> list[tuple[str, str, str]]:
        return sorted(self._models)

    def backends_for(self, application: str, platform: str) -> list[str]:
        """Backend key components stored for one (application, platform).

        This is how a scheduler enumerates the categories available for the
        joint (backend, config) argmin — see ``repro.cluster.policies``.
        """
        return sorted(
            b for (a, p, b) in self._models if (a, p) == (application, platform)
        )

    def predict(
        self,
        application: str,
        platform: str,
        params: Sequence[float],
        backend: str = "",
    ) -> float:
        """Paper Fig. 2b: look up the app's model, evaluate Eqn. 5."""
        model = self.get(application, platform, backend)
        return float(np.asarray(model.predict(np.asarray(params))).ravel()[0])

    # ---- persistence ----------------------------------------------------

    def save(self, path: str) -> None:
        payload = {
            _SEP.join(key): model.to_dict()
            for key, model in self._models.items()
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic publish

    @classmethod
    def load(cls, path: str) -> "ModelDatabase":
        db = cls()
        with open(path) as f:
            payload = json.load(f)
        for key, d in payload.items():
            parts = key.split(_SEP)
            if len(parts) == 2:  # pre-backend files: (app, platform) only
                parts.append("")
            elif len(parts) != 3:
                raise ValueError(f"malformed model key {key!r} in {path}")
            app, plat, backend = parts
            db.put(app, plat, RegressionModel.from_dict(d), backend=backend)
        return db
