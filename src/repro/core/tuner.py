"""Regression-driven configuration autotuner (beyond-paper closure).

The paper stops at prediction and *suggests* using the model to make
schedulers smarter.  This module closes that loop for the framework itself:

1. sample a small subset of the discrete configuration space (e.g. mesh
   factorizations data x model, microbatch counts, remat policies);
2. profile each sample (wall-clock or analytic via ``core.costmodel``);
3. fit the paper's polynomial model on the samples;
4. predict over the *entire* space and return the argmin — at the cost of
   |samples| profiles instead of |space|.

For categorical knobs (e.g. remat policy) we fit one model per category —
the paper's per-application model database pattern, reused per-category.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core import regression
from repro.core.profiler import profile_experiments


@dataclasses.dataclass
class TuneResult:
    best_config: np.ndarray
    predicted_time: float
    model: regression.RegressionModel
    sampled_configs: np.ndarray
    sampled_times: np.ndarray
    # Filled by validate(): true time of the chosen config and of the true
    # optimum, to report regret.
    measured_best_time: float | None = None
    true_optimum_time: float | None = None

    @property
    def regret_pct(self) -> float | None:
        if self.measured_best_time is None or self.true_optimum_time is None:
            return None
        return (
            (self.measured_best_time - self.true_optimum_time)
            / self.true_optimum_time
            * 100.0
        )


def _latin_hypercube_indices(n_space: int, n_samples: int, seed: int) -> np.ndarray:
    """Stratified index sample over a 1-D enumeration of the space."""
    rng = np.random.default_rng(seed)
    edges = np.linspace(0, n_space, n_samples + 1)
    idx = np.array(
        [rng.integers(int(edges[i]), max(int(edges[i + 1]), int(edges[i]) + 1))
         for i in range(n_samples)]
    )
    return np.clip(idx, 0, n_space - 1)


def tune(
    run_fn: Callable[[Sequence[float]], float],
    space: np.ndarray,
    *,
    n_samples: int | None = None,
    repeats: int = 1,
    degree: int = 3,
    scale: bool = True,
    lam: float = 1e-6,
    cross_terms: bool = True,
    seed: int = 0,
    verbose: bool = False,
) -> TuneResult:
    """Profile a sample of ``space`` (K, N), model, and argmin the prediction.

    Defaults use the beyond-paper conditioning fixes (scale + tiny ridge +
    cross terms) because the tuner must be robust unattended; pass
    ``scale=False, lam=0.0, cross_terms=False`` for the paper-faithful basis.
    """
    space = np.asarray(space, dtype=np.float64)
    K, N = space.shape
    n_feat = 1 + N * degree + (N * (N - 1) // 2 if cross_terms else 0)
    if n_samples is None:
        n_samples = min(K, max(2 * n_feat, 8))
    n_samples = min(n_samples, K)
    if n_samples < n_feat:
        raise ValueError(
            f"n_samples={n_samples} < n_features={n_feat}; enlarge the sample"
        )
    idx = _latin_hypercube_indices(K, n_samples, seed)
    samples = space[np.unique(idx)]
    # Top up uniques lost to clipping.
    rng = np.random.default_rng(seed + 1)
    while samples.shape[0] < min(n_samples, K):
        extra = space[rng.integers(0, K)]
        if not (samples == extra).all(axis=1).any():
            samples = np.vstack([samples, extra])
    prof = profile_experiments(
        run_fn, samples, repeats=repeats, verbose=verbose
    )
    model = regression.fit(
        prof.params,
        prof.times,
        degree=degree,
        scale=scale,
        lam=lam,
        cross_terms=cross_terms,
    )
    pred = np.asarray(model.predict(space), dtype=np.float64)
    best = int(np.argmin(pred))
    return TuneResult(
        best_config=space[best],
        predicted_time=float(pred[best]),
        model=model,
        sampled_configs=prof.params,
        sampled_times=prof.times,
    )


@dataclasses.dataclass
class CategoricalTuneResult:
    """Joint optimum over (category, numeric config)."""

    best_category: str
    best_config: np.ndarray
    predicted_time: float
    per_category: dict[str, TuneResult]

    def predicted_times(self) -> dict[str, float]:
        return {c: r.predicted_time for c, r in self.per_category.items()}


def tune_categorical(
    run_fns: Mapping[str, Callable[[Sequence[float]], float]],
    space: np.ndarray,
    **tune_kwargs,
) -> CategoricalTuneResult:
    """Tune a mixed categorical x numeric space: one polynomial model per
    category value, argmin across all of them.

    The paper's model is numeric-only; categorical axes (here: the MapReduce
    engine's shuffle/reduce backend) don't embed in a polynomial basis, so we
    reuse the paper's model-database pattern — one independent model per
    category — and take the joint argmin.  Costs |categories| x |samples|
    profiles instead of |categories| x |space|.
    """
    if not run_fns:
        raise ValueError("run_fns must name at least one category")
    per = {
        cat: tune(fn, space, **tune_kwargs) for cat, fn in run_fns.items()
    }
    best_cat = min(per, key=lambda c: per[c].predicted_time)
    return CategoricalTuneResult(
        best_category=best_cat,
        best_config=per[best_cat].best_config,
        predicted_time=per[best_cat].predicted_time,
        per_category=per,
    )


def validate(
    result: TuneResult,
    run_fn: Callable[[Sequence[float]], float],
    space: np.ndarray,
    *,
    repeats: int = 1,
) -> TuneResult:
    """Measure the chosen config and the exhaustive optimum; fill regret."""
    space = np.asarray(space, dtype=np.float64)
    times = np.array(
        [
            np.mean([run_fn(row) for _ in range(repeats)])
            for row in space
        ]
    )
    chosen = np.where((space == result.best_config).all(axis=1))[0]
    result.measured_best_time = float(times[chosen[0]])
    result.true_optimum_time = float(times.min())
    return result


def mesh_factorizations(n_devices: int, *, min_axis: int = 1) -> np.ndarray:
    """All (data, model) integer factorizations of n_devices — the discrete
    config space whose analogue in the paper is (#mappers, #reducers)."""
    out = []
    for data in range(min_axis, n_devices + 1):
        if n_devices % data == 0:
            model = n_devices // data
            if model >= min_axis:
                out.append((data, model))
    return np.asarray(out, dtype=np.float64)


def log2_space(values: Sequence[int]) -> np.ndarray:
    """Convenience: 1-D config space as a column vector."""
    return np.asarray(values, dtype=np.float64)[:, None]
