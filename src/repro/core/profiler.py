"""Profiling phase (paper Fig. 2a).

``profile_experiments`` runs an application callable under each configuration
in an experiment set, ``repeats`` times each (paper: 5), and keeps the mean
total execution time — exactly the paper's pruning-by-averaging mechanism.

Two time sources are supported, both behind the same interface:

* ``WallClockTimer``   — real wall time with ``block_until_ready`` fencing
  (used for the MapReduce reproduction and small-model runs on host devices);
* ``AnalyticTimer``    — roofline-term time from a compiled dry-run artifact
  (used for at-scale workloads in this TPU-less container; see
  ``core.costmodel``).

The profiler is deliberately ignorant of what the "application" is: it only
sees ``fn(config) -> float seconds``.  That mirrors the paper's black-box
treatment of MapReduce jobs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass
class ProfileResult:
    """Profiling-phase output: the training set for the modeling phase."""

    params: np.ndarray  # (M, N) configuration values
    times: np.ndarray   # (M,)  mean execution time per experiment (seconds)
    raw_times: np.ndarray  # (M, repeats) all repeats, for variance analysis
    param_names: tuple[str, ...]

    @property
    def n_experiments(self) -> int:
        return self.params.shape[0]

    def repeat_cv(self) -> np.ndarray:
        """Coefficient of variation across repeats, per experiment.

        The paper attributes residual prediction error to "temporal changes";
        this quantifies that noise floor.
        """
        mean = self.raw_times.mean(axis=1)
        std = self.raw_times.std(axis=1)
        return std / np.maximum(mean, 1e-12)


def timeit(fn: Callable[[], object]) -> float:
    """Wall-clock one call, fencing async dispatch."""
    import jax

    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def profile_experiments(
    run_fn: Callable[[Sequence[float]], float],
    configs: np.ndarray,
    *,
    repeats: int = 5,
    param_names: Sequence[str] | None = None,
    warmup: int = 0,
    reducer: str = "mean",
    verbose: bool = False,
) -> ProfileResult:
    """Run every config ``repeats`` times; aggregate per paper Fig. 2a.

    run_fn(config_row) must return the total execution time in seconds for
    one run of the application under that configuration.

    ``reducer``: "mean" is paper-faithful; "median"/"min" are beyond-paper
    noise-robust options (documented in EXPERIMENTS.md when used).
    """
    configs = np.asarray(configs, dtype=np.float64)
    if configs.ndim != 2:
        raise ValueError(f"configs must be (M, N), got {configs.shape}")
    M, N = configs.shape
    names = tuple(param_names or (f"p{i}" for i in range(N)))
    raw = np.zeros((M, repeats), dtype=np.float64)
    for i, row in enumerate(configs):
        for _ in range(warmup):
            run_fn(row)
        for r in range(repeats):
            raw[i, r] = float(run_fn(row))
        if verbose:
            print(
                f"[profiler] config {i + 1}/{M} "
                f"{dict(zip(names, row))}: "
                f"mean={raw[i].mean():.4f}s cv={raw[i].std() / max(raw[i].mean(), 1e-12):.3f}"
            )
    if reducer == "mean":
        times = raw.mean(axis=1)
    elif reducer == "median":
        times = np.median(raw, axis=1)
    elif reducer == "min":
        times = raw.min(axis=1)
    else:
        raise ValueError(f"unknown reducer {reducer!r}")
    return ProfileResult(
        params=configs, times=times, raw_times=raw, param_names=names
    )


def profile_categorical(
    run_fns: Mapping[str, Callable[[Sequence[float]], float]],
    configs: np.ndarray,
    *,
    repeats: int = 5,
    param_names: Sequence[str] | None = None,
    warmup: int = 0,
    reducer: str = "mean",
    verbose: bool = False,
) -> dict[str, ProfileResult]:
    """Profile the same configuration set under each categorical variant.

    ``run_fns`` maps a category value (e.g. the MapReduce engine's reduce
    backend: "jnp" / "pallas" / "xla") to its ``run_fn``.  The numeric
    parameters stay shared, so the results are directly comparable and feed
    the per-category models of :func:`repro.core.tuner.tune_categorical` —
    the paper's per-application model-database pattern, reused per-category.
    """
    return {
        cat: profile_experiments(
            fn,
            configs,
            repeats=repeats,
            param_names=param_names,
            warmup=warmup,
            reducer=reducer,
            verbose=verbose,
        )
        for cat, fn in run_fns.items()
    }
