"""Polynomial design matrix for config->time regression (paper Eqn. 1-2).

The paper's feature map expands each of the N configuration parameters into
per-parameter monomials up to a fixed degree (3 in the paper), with NO cross
terms, plus a single intercept column:

    row(p) = [1, p_1, p_1^2, p_1^3, ..., p_N, p_N^2, p_N^3]

``PolynomialFeatures`` reproduces this exactly in paper-faithful mode
(``degree=3, cross_terms=False, scale=False``).  Beyond-paper options:

* ``scale=True``      -- affinely map each raw parameter to [0, 1] before
  expansion (fit-time ranges are stored).  Pure conditioning fix: the model
  class is identical (an affine change of variables of a polynomial basis
  spans the same function space), but the normal equations go from condition
  number ~1e9 (p up to 40, cubed) to ~1e3, which matters in float32.
* ``cross_terms=True`` -- add pairwise products p_i * p_j (i<j), enriching the
  model for interacting knobs (e.g. mappers x reducers contention).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """Immutable description of a fitted feature map."""

    n_params: int
    degree: int = 3
    cross_terms: bool = False
    scale: bool = False
    # Fit-time parameter ranges (used only when scale=True).
    lo: tuple[float, ...] | None = None
    hi: tuple[float, ...] | None = None

    @property
    def n_features(self) -> int:
        n = 1 + self.n_params * self.degree
        if self.cross_terms:
            n += self.n_params * (self.n_params - 1) // 2
        return n

    def column_names(self) -> list[str]:
        names = ["1"]
        for i in range(self.n_params):
            for d in range(1, self.degree + 1):
                names.append(f"p{i}" if d == 1 else f"p{i}^{d}")
        if self.cross_terms:
            for i in range(self.n_params):
                for j in range(i + 1, self.n_params):
                    names.append(f"p{i}*p{j}")
        return names


def fit_feature_spec(
    params: np.ndarray | jnp.ndarray,
    *,
    degree: int = 3,
    cross_terms: bool = False,
    scale: bool = False,
) -> FeatureSpec:
    """Build a FeatureSpec from training parameter rows (M, N)."""
    params = np.asarray(params, dtype=np.float64)
    if params.ndim != 2:
        raise ValueError(f"params must be (M, N), got shape {params.shape}")
    n_params = params.shape[1]
    lo = hi = None
    if scale:
        lo = tuple(float(x) for x in params.min(axis=0))
        hi_raw = params.max(axis=0)
        # Guard degenerate (constant) parameters: width 1 keeps the affine
        # map invertible without changing the constant column it produces.
        hi = tuple(
            float(h if h > l else l + 1.0) for l, h in zip(lo, hi_raw)
        )
    return FeatureSpec(
        n_params=n_params, degree=degree, cross_terms=cross_terms,
        scale=scale, lo=lo, hi=hi,
    )


def design_matrix(spec: FeatureSpec, params) -> jnp.ndarray:
    """Expand raw parameter rows (M, N) into the design matrix P (M, F).

    Differentiable and jit-able; the expansion itself is the (tiny) compute
    kernel of the paper's modeling phase.
    """
    p = jnp.asarray(params, dtype=jnp.float32)
    if p.ndim == 1:
        p = p[None, :]
    if p.shape[-1] != spec.n_params:
        raise ValueError(
            f"expected {spec.n_params} parameters, got {p.shape[-1]}"
        )
    if spec.scale:
        lo = jnp.asarray(spec.lo, dtype=jnp.float32)
        hi = jnp.asarray(spec.hi, dtype=jnp.float32)
        p = (p - lo) / (hi - lo)
    cols = [jnp.ones(p.shape[:-1] + (1,), dtype=p.dtype)]
    for i in range(spec.n_params):
        pi = p[..., i : i + 1]
        acc = pi
        for _ in range(spec.degree):
            cols.append(acc)
            acc = acc * pi
    if spec.cross_terms:
        for i in range(spec.n_params):
            for j in range(i + 1, spec.n_params):
                cols.append(p[..., i : i + 1] * p[..., j : j + 1])
    # Paper ordering: [1, p1, p1^2, p1^3, p2, p2^2, p2^3, ...]
    return jnp.concatenate(cols, axis=-1)


def grid(ranges: Sequence[tuple[int, int, int]]) -> np.ndarray:
    """Cartesian experiment grid: ranges[(lo, hi, step)] per parameter."""
    axes = [np.arange(lo, hi + 1, step) for lo, hi, step in ranges]
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.stack([m.ravel() for m in mesh], axis=-1).astype(np.float64)
