"""Fault-tolerant checkpointing: sharded save/restore, async, elastic.

Layout (one directory per step):

    ckpt_dir/
      step_000123.tmp/...      # staged writes
      step_000123/             # atomic rename == commit
        MANIFEST.json          # pytree structure, shapes, dtypes, step
        arr_000000.npy ...     # one file per leaf (host-local full value)
      LATEST                   # text file with the newest committed step

Guarantees:
* **atomicity** — a checkpoint is visible only after the directory rename;
  a crash mid-save leaves a .tmp dir that restore ignores and save GC's;
* **async** — ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and writes files on a background thread, so the
  train loop loses only the device->host copy time;
* **elasticity** — arrays are stored unsharded (gathered); ``restore``
  re-shards onto whatever mesh/sharding the *restoring* job provides, so a
  job restarted on a different device count resumes seamlessly.  At real
  multi-host scale the same layout holds per-host array shards; the
  manifest format carries shapes/dtypes so cross-topology stitching is a
  pure-host transformation.
* **retention** — ``keep`` newest checkpoints survive garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _dict_key_paths(tree) -> list[list[str]] | None:
    """Leaf key-paths for pure nested-dict trees (leaf order = flatten
    order), or None when the tree mixes in other containers.

    Stored in the manifest so :meth:`CheckpointManager.restore` can
    rebuild the tree without a ``like`` template — which is what the
    elastic snapshot layer needs: a resuming job learns its buffer shapes
    *from* the checkpoint (they depend on the grant the job held when it
    was preempted), so it cannot supply them up front.
    """
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths: list[list[str]] = []
    for path, _leaf in paths_leaves:
        if not path:
            return None   # bare leaf at the root: no dict to rebuild
        keys = []
        for entry in path:
            if not isinstance(entry, jax.tree_util.DictKey) or not isinstance(
                entry.key, str
            ):
                return None
            keys.append(entry.key)
        paths.append(keys)
    return paths


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._gc_stale_tmp()

    # ------------------------------------------------------------------ io

    def _gc_stale_tmp(self):
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(
                    os.path.join(self.directory, name), ignore_errors=True
                )

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def latest_step(self) -> int | None:
        latest = os.path.join(self.directory, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            s = f.read().strip()
        return int(s) if s else None

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    # ---------------------------------------------------------------- save

    def save(self, step: int, tree) -> None:
        """Synchronous save: snapshot + write + commit."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        leaves, treedef = _flatten_with_paths(host_tree)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "n_leaves": len(leaves),
            "leaves": [
                {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
                for l in leaves
            ],
            # Key-paths for nested-dict trees (None otherwise): enables
            # template-free restore (restore(step, like=None)).
            "paths": _dict_key_paths(host_tree),
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i:06d}.npy"),
                    np.asarray(leaf), allow_pickle=False)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)  # atomic commit
        latest_tmp = os.path.join(self.directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc(step)

    def _gc(self, newest_step: int) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s != newest_step:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore

    def restore(self, step: int | None, like=None, shardings=None):
        """Load a checkpoint into the structure of ``like``.

        ``like=None``: rebuild the tree from the manifest's stored
        key-paths instead (nested-dict checkpoints only) — the caller
        learns shapes/dtypes from the checkpoint rather than supplying
        them, which is how elastic job snapshots are reloaded (a resuming
        job's buffer shapes depend on the grant it was preempted under).

        ``shardings``: optional pytree of jax.sharding.Sharding — arrays are
        device_put with these (elastic re-shard onto the current mesh).

        Dtypes are part of the contract: with a ``like`` template every
        loaded leaf must match its template leaf's dtype exactly (the
        MapReduce snapshot pytrees mix int32/bool/unicode leaves, and a
        silent int32<->float32 or bool<->int8 coercion would corrupt
        bit-exact resume guarantees).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        if like is None:
            tree = self._restore_from_paths(d, manifest)
        else:
            like_leaves, treedef = jax.tree_util.tree_flatten(like)
            if manifest["n_leaves"] != len(like_leaves):
                raise ValueError(
                    f"checkpoint has {manifest['n_leaves']} leaves, target "
                    f"structure has {len(like_leaves)} — structure mismatch"
                )
            arrays = []
            for i, ref in enumerate(like_leaves):
                arr = np.load(os.path.join(d, f"arr_{i:06d}.npy"))
                want_shape = tuple(np.shape(ref))
                if tuple(arr.shape) != want_shape:
                    raise ValueError(
                        f"leaf {i}: checkpoint shape {arr.shape} != expected "
                        f"{want_shape}"
                    )
                want_dtype = np.asarray(ref).dtype
                if arr.dtype != want_dtype:
                    raise ValueError(
                        f"leaf {i}: checkpoint dtype {arr.dtype} != expected "
                        f"{want_dtype} — refusing a silent cast"
                    )
                arrays.append(arr)
            tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree, step

    def _restore_from_paths(self, d: str, manifest: dict):
        """Template-free restore: rebuild a nested-dict tree from the
        manifest's key-paths (written by this manager for dict trees)."""
        paths = manifest.get("paths")
        if paths is None:
            raise ValueError(
                "checkpoint was not saved as a nested-dict tree (or "
                "predates path manifests); pass like= to restore it"
            )
        if len(paths) != manifest["n_leaves"]:
            raise ValueError("manifest paths/leaves count mismatch")
        tree: dict = {}
        for i, keys in enumerate(paths):
            arr = np.load(os.path.join(d, f"arr_{i:06d}.npy"))
            node = tree
            for k in keys[:-1]:
                node = node.setdefault(k, {})
            node[keys[-1]] = arr
        return tree
