"""Fault-tolerant checkpointing: sharded save/restore, async, elastic.

Layout (one directory per step):

    ckpt_dir/
      step_000123.tmp/...      # staged writes
      step_000123/             # atomic rename == commit
        MANIFEST.json          # pytree structure, shapes, dtypes, step
        arr_000000.npy ...     # one file per leaf (host-local full value)
      LATEST                   # text file with the newest committed step

Guarantees:
* **atomicity** — a checkpoint is visible only after the directory rename;
  a crash mid-save leaves a .tmp dir that restore ignores and save GC's;
* **async** — ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and writes files on a background thread, so the
  train loop loses only the device->host copy time;
* **elasticity** — arrays are stored unsharded (gathered); ``restore``
  re-shards onto whatever mesh/sharding the *restoring* job provides, so a
  job restarted on a different device count resumes seamlessly.  At real
  multi-host scale the same layout holds per-host array shards; the
  manifest format carries shapes/dtypes so cross-topology stitching is a
  pure-host transformation.
* **retention** — ``keep`` newest checkpoints survive garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._gc_stale_tmp()

    # ------------------------------------------------------------------ io

    def _gc_stale_tmp(self):
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(
                    os.path.join(self.directory, name), ignore_errors=True
                )

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def latest_step(self) -> int | None:
        latest = os.path.join(self.directory, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            s = f.read().strip()
        return int(s) if s else None

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    # ---------------------------------------------------------------- save

    def save(self, step: int, tree) -> None:
        """Synchronous save: snapshot + write + commit."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        leaves, treedef = _flatten_with_paths(host_tree)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "n_leaves": len(leaves),
            "leaves": [
                {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
                for l in leaves
            ],
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i:06d}.npy"),
                    np.asarray(leaf), allow_pickle=False)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)  # atomic commit
        latest_tmp = os.path.join(self.directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc(step)

    def _gc(self, newest_step: int) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s != newest_step:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore

    def restore(self, step: int | None, like, shardings=None):
        """Load a checkpoint into the structure of ``like``.

        ``shardings``: optional pytree of jax.sharding.Sharding — arrays are
        device_put with these (elastic re-shard onto the current mesh).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        if manifest["n_leaves"] != len(like_leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, target "
                f"structure has {len(like_leaves)} — structure mismatch"
            )
        arrays = []
        for i, ref in enumerate(like_leaves):
            arr = np.load(os.path.join(d, f"arr_{i:06d}.npy"))
            want_shape = tuple(np.shape(ref))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != expected "
                    f"{want_shape}"
                )
            arrays.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree, step
