"""State-space / linear-attention blocks: RWKV6 (Finch) and Mamba.

Both blocks support:
* full-sequence training/prefill via **chunked scans** — the sequential
  recurrence is carried between chunks while work inside a chunk is
  parallel.  This bounds the O(T) backward-residual memory of a naive
  per-step `lax.scan` (the same trick the Pallas rwkv6 kernel uses on-chip);
* single-step decode against an explicit state pytree (the SSM analogue of a
  KV cache — O(1) in context length, which is why these archs own the
  ``long_500k`` cell).

RWKV6 recurrence (per head; S in R^{dk x dv}):
    out_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with **data-dependent decay** w_t = exp(-exp(w0 + tanh(x_t A) B)) — the
Finch contribution.

Mamba (selective SSM, per channel c):
    h_t[c] = exp(A[c] * dt_t[c]) * h_{t-1}[c] + dt_t[c] * B_t * x_t[c]
    y_t[c] = C_t . h_t[c] + D[c] * x_t[c]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rmsnorm

# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


def init_rwkv(key, cfg, dtype):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    ks = jax.random.split(key, 10)
    lora = 32
    return {
        "mix": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "wr": init_dense(ks[1], d, d, dtype),
        "wk": init_dense(ks[2], d, d, dtype),
        "wv": init_dense(ks[3], d, d, dtype),
        "wg": init_dense(ks[4], d, d, dtype),
        "wo": init_dense(ks[5], d, d, dtype),
        # data-dependent decay lora: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": (jnp.zeros((d,)) - 0.6).astype(dtype),
        "wA": init_dense(ks[6], d, lora, dtype),
        "wB": (jax.random.normal(ks[7], (lora, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[8], (H, hs)) * 0.1).astype(dtype),
        "ln_w": jnp.ones((H, hs), dtype=dtype),
        # channel-mix
        "cm_mix": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25).astype(dtype),
        "cm_k": init_dense(ks[0], d, cfg.d_ff, dtype),
        "cm_v": init_dense(ks[1], cfg.d_ff, d, dtype),
        "cm_r": init_dense(ks[2], d, d, dtype),
    }


def rwkv_state_init(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    return {
        "S": jnp.zeros((batch, H, hs, hs), dtype=jnp.float32),
        "x_prev_tm": jnp.zeros((batch, d), dtype),
        "x_prev_cm": jnp.zeros((batch, d), dtype),
    }


def _rwkv_chunk(S0, r, k, v, w, u):
    """One chunk of the WKV6 recurrence, parallel within the chunk.

    S0: (B, H, hs, hs); r,k,v,w: (B, C, H, hs); u: (H, hs).
    Returns (out (B,C,H,hs), S_C).

    Numerics: all decay factors are expressed as exp of *non-positive*
    cumulative-log differences (never ratios of cumulative products), so the
    chunk is overflow-safe for arbitrarily strong data-dependent decay.
    """
    C = r.shape[1]
    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0))
    logD = jnp.cumsum(logw, axis=1)                  # (B, C, H, hs), <= 0
    logDm1 = logD - logw                             # log D_{j-1}, D_0 = 1
    r32 = r.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    # inter-chunk: out_q += (r_q * D_{q-1}) @ S0          (exp(logDm1) <= 1)
    out = jnp.einsum("bchk,bhkv->bchv", r32 * jnp.exp(logDm1), S0)
    # intra-chunk: att[q,d] = sum_c r[q,c] k[d,c] exp(logDm1[q,c]-logD[d,c])
    # (exponent <= 0 for d < q); pairwise decay materialized per chunk.
    pair = jnp.exp(
        jnp.minimum(
            logDm1[:, :, None] - logD[:, None, :], 0.0
        )
    )  # (B, Cq, Cd, H, hs)
    att = jnp.einsum("bqhc,bdhc,bqdhc->bhqd", r32, k32, pair)
    tri = jnp.tril(jnp.ones((C, C), dtype=bool), k=-1)
    att = jnp.where(tri[None, None], att, 0.0)
    out = out + jnp.einsum("bhqd,bdhv->bqhv", att, v32)
    # bonus diagonal: out_q += (r_q . (u * k_q)) v_q
    bonus = jnp.sum(r32 * (u[None, None] * k32), axis=-1)   # (B,C,H)
    out = out + bonus[..., None] * v32
    # state: S_C = diag(D_C) S0 + sum_i diag(exp(logD_C - logD_i)) k_i v_i^T
    logD_C = logD[:, -1]                             # (B,H,hs)
    decay_i = jnp.exp(logD_C[:, None] - logD)        # (B,C,H,hs), <= 1
    S = S0 * jnp.exp(logD_C)[..., None] + jnp.einsum(
        "bchk,bchv->bhkv", k32 * decay_i, v32
    )
    return out, S


def rwkv_time_mix(x, params, cfg, state, chunk: int = 64):
    """x: (B, S, D) full-sequence (chunked) or (B, 1, D) decode."""
    B, S, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    # token shift: x_{t-1} (state carries the last token of the prev call)
    prev = jnp.concatenate(
        [state["x_prev_tm"].astype(cdt)[:, None], xc[:, :-1]], axis=1
    )
    mix = params["mix"].astype(cdt)
    xr = xc + (prev - xc) * mix[0]
    xk = xc + (prev - xc) * mix[1]
    xv = xc + (prev - xc) * mix[2]
    xg = xc + (prev - xc) * mix[3]
    xw = xc + (prev - xc) * mix[4]
    r = (xr @ params["wr"].astype(cdt)).reshape(B, S, H, hs)
    k = (xk @ params["wk"].astype(cdt)).reshape(B, S, H, hs)
    v = (xv @ params["wv"].astype(cdt)).reshape(B, S, H, hs)
    g = xg @ params["wg"].astype(cdt)
    # data-dependent decay (fp32)
    dd = jnp.tanh(xw.astype(jnp.float32) @ params["wA"].astype(jnp.float32))
    dd = dd @ params["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + dd))
    w = w.reshape(B, S, H, hs)
    u = params["u"].astype(jnp.float32)

    if S == 1:
        # decode fast path: one recurrence step
        S0 = state["S"]
        r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        out = jnp.einsum(
            "bhk,bhkv->bhv", r1, S0 + u[None, :, :, None] * kv
        )
        S_new = S0 * w1[..., None] + kv
        out = out[:, None]
    else:
        pad = (-S) % chunk
        if pad:
            padw = lambda t, fill: jnp.pad(
                t, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=fill
            )
            r, k, v = padw(r, 0), padw(k, 0), padw(v, 0)
            w = padw(w, 1.0)
        n_chunks = (S + pad) // chunk
        rc = r.reshape(B, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
        kc = k.reshape(B, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
        wc = w.reshape(B, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
        # Pin batch sharding through the chunking transpose (see the
        # matching note in _selective_scan_chunked).
        from repro.sharding.context import constraint

        pin = lambda t: constraint(t, None, ("pod", "data"), None, None, None)
        rc, kc, vc, wc = pin(rc), pin(kc), pin(vc), pin(wc)

        def step(Sc, inp):
            ri, ki, vi, wi = inp
            out, Sn = _rwkv_chunk(Sc, ri, ki, vi, wi, u)
            return Sn, out

        S_new, outs = jax.lax.scan(step, state["S"], (rc, kc, vc, wc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, hs)[:, :S]

    # per-head groupnorm + gate
    out = rmsnorm(out, params["ln_w"], cfg.norm_eps)
    out = out.reshape(B, S, D) * jax.nn.silu(g)
    out = out.astype(cdt) @ params["wo"].astype(cdt)
    new_state = dict(state)
    new_state["S"] = S_new
    new_state["x_prev_tm"] = x[:, -1].astype(state["x_prev_tm"].dtype)
    return out, new_state


def rwkv_channel_mix(x, params, cfg, state):
    B, S, D = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    prev = jnp.concatenate(
        [state["x_prev_cm"].astype(cdt)[:, None], xc[:, :-1]], axis=1
    )
    mix = params["cm_mix"].astype(cdt)
    xk = xc + (prev - xc) * mix[0]
    xr = xc + (prev - xc) * mix[1]
    k = jax.nn.relu(xk @ params["cm_k"].astype(cdt)) ** 2
    v = k @ params["cm_v"].astype(cdt)
    r = jax.nn.sigmoid(xr @ params["cm_r"].astype(cdt))
    new_state = dict(state)
    new_state["x_prev_cm"] = x[:, -1].astype(state["x_prev_cm"].dtype)
    return r * v, new_state


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, d_in)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": init_dense(ks[2], d_in, 2 * ds + 1, dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (d_in, ds))
        ).astype(jnp.float32),
        "D": jnp.ones((d_in,), dtype),
        "out_proj": init_dense(ks[3], d_in, d, dtype),
    }


def mamba_state_init(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.mamba_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype),
    }


def _selective_scan_chunked(h0, dt, dtx, A, B_seq, C_seq, chunk: int):
    """y_t = C_t . h_t with h_t = exp(dt_t*A) * h_{t-1} + (dt_t*x_t) B_t.

    dt, dtx: (B, S, d_in); A: (d_in, ds); B_seq, C_seq: (B, S, ds).

    Memory discipline (the property real Mamba kernels rely on): the
    (B, S, d_in, ds) decay/input/hidden tensors are built and consumed one
    chunk at a time INSIDE the scan — materializing any of them for the
    full sequence measured 2.9 TiB/device for jamba train_4k.

    Returns (y (B, S, d_in), h_S (B, d_in, ds)).
    """
    B, S, d_in = dt.shape
    pad = (-S) % chunk
    if pad:
        w2 = ((0, 0), (0, pad), (0, 0))
        dt = jnp.pad(dt, w2)        # dt=0 -> a=1, b=0: identity steps
        dtx = jnp.pad(dtx, w2)
        B_seq = jnp.pad(B_seq, w2)
        C_seq = jnp.pad(C_seq, w2)
    n = (S + pad) // chunk
    chunked = lambda t: t.reshape(B, n, chunk, t.shape[-1]).transpose(
        1, 0, 2, 3)
    dtc, dtxc, bcs, ccs = map(chunked, (dt, dtx, B_seq, C_seq))
    # Pin shardings through the reshape/transpose: without these the SPMD
    # partitioner replicates the scan inputs (measured 2.5 TiB/device peak
    # on jamba train_4k, §Perf-jamba): batch stays on dp, channels on TP.
    from repro.sharding.context import constraint

    dp = ("pod", "data")
    dtc = constraint(dtc, None, dp, None, "model")
    dtxc = constraint(dtxc, None, dp, None, "model")
    bcs = constraint(bcs, None, dp, None, None)
    ccs = constraint(ccs, None, dp, None, None)

    def op(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    def step(h, inp):
        dti, dtxi, bi, ci = inp  # (B, chunk, ...)
        a_i = jnp.exp(dti[..., None] * A[None, None])     # (B,c,d_in,ds)
        b_i = dtxi[..., None] * bi[:, :, None, :]
        aa, bb = jax.lax.associative_scan(op, (a_i, b_i), axis=1)
        h_all = aa * h[:, None] + bb
        y = jnp.einsum("bcdn,bcn->bcd", h_all, ci)
        return h_all[:, -1], y

    hS, y_chunks = jax.lax.scan(step, h0, (dtc, dtxc, bcs, ccs))
    y = y_chunks.transpose(1, 0, 2, 3).reshape(B, S + pad, d_in)
    return y[:, :S], hS


def mamba_block(x, params, cfg, state, chunk: int = 256):
    """x: (B, S, D); state: {"h", "conv"}. Returns (out, new_state)."""
    B, S, D = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    d_in = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    xc = x.astype(cdt)
    xz = xc @ params["in_proj"].astype(cdt)
    xs, z = jnp.split(xz, 2, axis=-1)                 # (B, S, d_in)
    # causal depthwise conv over time, seeded by the carried tail
    tail = state["conv"].astype(cdt)                  # (B, d_conv-1, d_in)
    xpad = jnp.concatenate([tail, xs], axis=1)
    kw = params["conv_w"].astype(cdt)                 # (d_conv, d_in)
    dconv = kw.shape[0]
    xconv = sum(
        xpad[:, i : i + S] * kw[i][None, None] for i in range(dconv)
    ) + params["conv_b"].astype(cdt)
    xconv = jax.nn.silu(xconv)
    # data-dependent SSM params (fp32 for the recurrence)
    proj = (xconv @ params["x_proj"].astype(cdt)).astype(jnp.float32)
    B_ssm, C_ssm, dt_raw = (
        proj[..., :ds],
        proj[..., ds : 2 * ds],
        proj[..., 2 * ds :],
    )
    dt = jax.nn.softplus(
        dt_raw + params["dt_bias"].astype(jnp.float32)[None, None]
    )  # (B,S,d_in)? dt_raw is (B,S,1) shared -> broadcast per channel
    A = -jnp.exp(params["A_log"])                     # (d_in, ds)
    xf = xconv.astype(jnp.float32)
    y, h_S = _selective_scan_chunked(
        state["h"], dt, dt * xf, A, B_ssm, C_ssm, chunk
    )
    y = y + params["D"].astype(jnp.float32)[None, None] * xf
    y = y.astype(cdt) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(cdt)
    new_state = {
        "h": h_S,
        "conv": xpad[:, -(dconv - 1):].astype(state["conv"].dtype)
        if dconv > 1
        else state["conv"],
    }
    return out, new_state
