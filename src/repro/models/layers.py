"""Shared neural net layers (pure functional JAX: explicit params pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, weight, eps: float = 1e-6):
    """RMSNorm in fp32, cast back to input dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"w": jnp.ones((d,), dtype=dtype)}


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rope_angles(positions, head_dim: int, theta: float = 10000.0):
    """positions (...,) -> cos/sin (..., head_dim/2)."""
    freqs = theta ** (
        -jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, n_heads, head_dim); cos/sin (..., S, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, w_gate, w_up, w_down, compute_dtype=jnp.bfloat16):
    x = x.astype(compute_dtype)
    g = jax.nn.silu(x @ w_gate.astype(compute_dtype))
    u = x @ w_up.astype(compute_dtype)
    return (g * u) @ w_down.astype(compute_dtype)


def geglu(x, w_gate, w_up, w_down, compute_dtype=jnp.bfloat16):
    x = x.astype(compute_dtype)
    g = gelu(x @ w_gate.astype(compute_dtype))
    u = x @ w_up.astype(compute_dtype)
    return (g * u) @ w_down.astype(compute_dtype)


def ffn_apply(x, params, ffn_type: str, compute_dtype=jnp.bfloat16):
    if ffn_type == "swiglu":
        return swiglu(
            x, params["w_gate"], params["w_up"], params["w_down"], compute_dtype
        )
    if ffn_type == "geglu":
        return geglu(
            x, params["w_gate"], params["w_up"], params["w_down"], compute_dtype
        )
    if ffn_type in ("relu", "gelu"):
        x = x.astype(compute_dtype)
        act = jax.nn.relu if ffn_type == "relu" else gelu
        h = act(x @ params["w_up"].astype(compute_dtype))
        return h @ params["w_down"].astype(compute_dtype)
    raise ValueError(ffn_type)


def init_ffn(key, d_model: int, d_ff: int, ffn_type: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if ffn_type in ("swiglu", "geglu"):
        return {
            "w_gate": init_dense(k1, d_model, d_ff, dtype),
            "w_up": init_dense(k2, d_model, d_ff, dtype),
            "w_down": init_dense(k3, d_ff, d_model, dtype),
        }
    if ffn_type in ("relu", "gelu"):
        return {
            "w_up": init_dense(k1, d_model, d_ff, dtype),
            "w_down": init_dense(k2, d_ff, d_model, dtype),
        }
    raise ValueError(ffn_type)


def cross_entropy_loss(
    logits, labels, *, ignore_index: int = -100, z_loss: float = 0.0
):
    """Token-mean softmax cross-entropy in fp32; labels==ignore_index masked."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * logz**2
    nll = jnp.where(mask, nll, 0.0)
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom
