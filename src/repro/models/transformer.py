"""Composable model assembly for all assigned architectures.

A model is a sequence of *blocks* tiled from ``cfg.block_pattern`` (period P,
repeated n_layers/P times).  Per-position parameters are stacked over repeats
and the stack is consumed by ``lax.scan`` — one trace per period regardless
of depth (compile-time critical for 512-device dry-runs of 64-layer models).

Block kinds:
  attn  : norm -> GQA attention -> residual, then FFN/MoE sub-block
  mamba : norm -> selective SSM -> residual, then FFN/MoE sub-block (jamba)
  rwkv  : norm -> WKV6 time-mix -> residual, norm -> channel-mix -> residual

MoE placement follows ``cfg.moe.every_n_layers/offset`` on absolute layer
index; arctic's dense-residual FFN runs in parallel with its MoE.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cross_entropy_loss,
    ffn_apply,
    init_dense,
    init_ffn,
    init_rmsnorm,
    rmsnorm,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block_position(key, cfg: ModelConfig, pos: int, dtype):
    """Params for one in-period position (shared structure across repeats)."""
    kind = cfg.block_pattern[pos % cfg.pattern_period]
    keys = jax.random.split(key, 8)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(keys[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(keys[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = ssm_mod.init_rwkv(keys[0], cfg, dtype)
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        return p  # rwkv: channel-mix is inside the rwkv params
    else:
        raise ValueError(kind)
    p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
    if cfg.is_moe_layer(pos):
        p["moe"] = moe_mod.init_moe(keys[1], cfg, dtype)
        if cfg.moe.dense_residual and cfg.moe.d_ff_dense:
            dense_cfg_ff = cfg.moe.d_ff_dense
            p["ffn"] = init_ffn(keys[2], cfg.d_model, dense_cfg_ff,
                                cfg.ffn_type, dtype)
    else:
        p["ffn"] = init_ffn(keys[2], cfg.d_model, cfg.d_ff, cfg.ffn_type, dtype)
    return p


def _moe_positions_valid(cfg: ModelConfig):
    if cfg.moe is None:
        return
    if cfg.moe.every_n_layers > 1 and cfg.pattern_period % cfg.moe.every_n_layers:
        raise ValueError(
            f"{cfg.name}: pattern period {cfg.pattern_period} must be a "
            f"multiple of moe.every_n_layers={cfg.moe.every_n_layers} so "
            f"MoE placement is repeat-invariant (scan requirement)"
        )


def init_params(cfg: ModelConfig, key):
    """Full parameter pytree.  Blocks stacked over repeats per position."""
    _moe_positions_valid(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    n_rep = cfg.n_groups_of_layers
    P = cfg.pattern_period
    keys = jax.random.split(key, P + 4)
    blocks = {}
    for pos in range(P):
        rep_keys = jax.random.split(keys[pos], n_rep)
        blocks[f"pos{pos}"] = jax.vmap(
            lambda k: _init_block_position(k, cfg, pos, dtype)
        )(rep_keys)
    params = {
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.input_kind == "tokens" or cfg.family == "vlm":
        params["embed"] = (
            jax.random.normal(keys[P], (cfg.vocab_padded, cfg.d_model))
            * cfg.d_model**-0.5
        ).astype(dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = init_dense(
                keys[P + 1], cfg.d_model, cfg.vocab_padded, dtype
            )
    if cfg.input_kind == "embeddings" or cfg.family == "vlm":
        params["in_proj"] = init_dense(
            keys[P + 2], cfg.embed_in_dim, cfg.d_model, dtype
        )
        if cfg.family == "audio":
            params["lm_head"] = init_dense(
                keys[P + 1], cfg.d_model, cfg.vocab_padded, dtype
            )
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_ffn_or_moe(x, p, cfg: ModelConfig, pos: int):
    """The FFN sub-block (dense, MoE, or arctic's parallel dense+MoE)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rmsnorm(x, p["norm2"]["w"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.is_moe_layer(pos):
        y, aux = moe_mod.moe_ffn_grouped(h, p["moe"], cfg, cdt)
        if cfg.moe.dense_residual and "ffn" in p:
            y = y + ffn_apply(h, p["ffn"], cfg.ffn_type, cdt)
    else:
        y = ffn_apply(h, p["ffn"], cfg.ffn_type, cdt)
    return x + y.astype(x.dtype), aux


def _apply_block(x, p, cfg: ModelConfig, pos: int, state, use_flash: bool):
    """One block.  ``state`` is None (train) or this layer's cache/state.

    Returns (x, new_state, aux_loss).
    """
    kind = cfg.block_pattern[pos % cfg.pattern_period]
    h = rmsnorm(x, p["norm1"]["w"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if kind == "attn":
        cache = state.get("kv") if state else None
        cache_index = state.get("pos") if state else None
        y, new_cache = attn_mod.attention(
            h, p["attn"], cfg,
            cache=cache, cache_index=cache_index, use_flash=use_flash,
        )
        x = x + y.astype(x.dtype)
        x, aux = _apply_ffn_or_moe(x, p, cfg, pos)
        new_state = dict(state, kv=new_cache) if state else None
    elif kind == "mamba":
        mstate = state["mamba"] if state else ssm_mod.mamba_state_init(
            cfg, x.shape[0]
        )
        y, mnew = ssm_mod.mamba_block(h, p["mamba"], cfg, mstate)
        x = x + y.astype(x.dtype)
        x, aux = _apply_ffn_or_moe(x, p, cfg, pos)
        new_state = dict(state, mamba=mnew) if state else None
    elif kind == "rwkv":
        rstate = state["rwkv"] if state else ssm_mod.rwkv_state_init(
            cfg, x.shape[0]
        )
        y, rnew = ssm_mod.rwkv_time_mix(h, p["rwkv"], cfg, rstate)
        x = x + y.astype(x.dtype)
        h2 = rmsnorm(x, p["norm2"]["w"], cfg.norm_eps)
        y2, rnew = ssm_mod.rwkv_channel_mix(h2, p["rwkv"], cfg, rnew)
        x = x + y2.astype(x.dtype)
        new_state = dict(state, rwkv=rnew) if state else None
    else:
        raise ValueError(kind)
    return x, new_state, aux


def _scan_blocks(x, params, cfg: ModelConfig, states, use_flash: bool,
                 remat: str = "none", unroll_layers: bool = False):
    """Scan the period-group over repeats.  states: None or dict pos->stacked.

    ``unroll_layers=True`` python-loops over repeats instead of lax.scan —
    identical semantics, but XLA cost_analysis then counts every repeat
    (scan bodies are costed ONCE regardless of trip count), so the dry-run
    uses it for honest roofline terms.  Production keeps the scan (compile
    time).

    Returns (x, new_states, total_aux).
    """
    P = cfg.pattern_period
    n_rep = cfg.n_groups_of_layers

    def group(x, group_params, group_states):
        aux_total = jnp.float32(0.0)
        new_states = {}
        for pos in range(P):
            st = group_states.get(f"pos{pos}") if group_states else None
            x, nst, aux = _apply_block(
                x, group_params[f"pos{pos}"], cfg, pos, st, use_flash
            )
            aux_total = aux_total + aux
            if nst is not None:
                new_states[f"pos{pos}"] = nst
        return x, new_states, aux_total

    if remat == "full":
        group = jax.checkpoint(group, prevent_cse=False)
    elif remat == "dots":
        group = jax.checkpoint(
            group,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    if unroll_layers:
        take = lambda tree, i: jax.tree.map(
            lambda l: jax.lax.index_in_dim(l, i, 0, keepdims=False), tree
        )
        aux = jnp.float32(0.0)
        new_states_list = []
        for i in range(n_rep):
            gs = take(states, i) if states is not None else None
            x, nst, a = group(x, take(params["blocks"], i), gs)
            aux = aux + a
            if states is not None:
                new_states_list.append(nst)
        if states is None:
            return x, None, aux
        new_states = jax.tree.map(
            lambda *ls: jnp.stack(ls, axis=0), *new_states_list
        )
        return x, new_states, aux

    if states is None:

        def body_nostate(carry, gp):
            x, aux_acc = carry
            x, _, aux = group(x, gp, None)
            return (x, aux_acc + aux), None

        (x, aux), _ = jax.lax.scan(
            body_nostate, (x, jnp.float32(0.0)), params["blocks"]
        )
        return x, None, aux

    def body(carry, xs):
        x, aux_acc = carry
        gp, gs = xs
        x, nst, aux = group(x, gp, gs)
        return (x, aux_acc + aux), nst

    (x, aux), new_states = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["blocks"], states)
    )
    return x, new_states, aux


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, batch):
    """Token/stub-frontend embedding -> (B, S, D) hidden states."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        tok = params["embed"][batch["tokens"]]  # (B, S_text, D)
        patches = (
            batch["patches"].astype(cdt) @ params["in_proj"].astype(cdt)
        )
        return jnp.concatenate([patches.astype(tok.dtype), tok], axis=1)
    if cfg.input_kind == "embeddings":
        return batch["embeds"].astype(cdt) @ params["in_proj"].astype(cdt)
    return params["embed"][batch["tokens"]]


def unembed(params, cfg: ModelConfig, h):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = h.astype(cdt)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(cdt).T
    else:
        logits = h @ params["lm_head"].astype(cdt)
    if cfg.vocab_padded != cfg.vocab_size:
        # mask pad-vocab columns so softmax/xent ignore them exactly
        pad_mask = jnp.where(
            jnp.arange(cfg.vocab_padded) < cfg.vocab_size, 0.0, -1e30
        ).astype(logits.dtype)
        logits = logits + pad_mask
    return logits


def forward(params, cfg: ModelConfig, batch, *, use_flash=False,
            remat="none", return_hidden=False, unroll_layers=False):
    """Full forward -> logits (B, S, V) (or hidden states)."""
    x = embed_inputs(params, cfg, batch)
    x, _, aux = _scan_blocks(
        x, params, cfg, None, use_flash, remat, unroll_layers
    )
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    if return_hidden:
        return x, aux
    return unembed(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, *, use_flash=False,
            remat="none", logits_chunk: int = 0, unroll_layers=False):
    """Next-token LM loss (causal), or per-frame classification (encoder).

    ``logits_chunk > 0`` computes logits+xent in sequence chunks of that size
    (never materializing the full (B,S,V) logits) — the memory lever for
    256k-vocab archs.
    """
    h, aux = forward(
        params, cfg, batch, use_flash=use_flash, remat=remat,
        return_hidden=True, unroll_layers=unroll_layers,
    )
    if cfg.causal:
        if cfg.family == "vlm":
            # loss over text positions only (patches are prefix context)
            npat = batch["patches"].shape[1]
            h_txt = h[:, npat:]
            labels = batch["tokens"][:, 1:]
            h_for_loss = h_txt[:, :-1]
        else:
            labels = batch["tokens"][:, 1:]
            h_for_loss = h[:, :-1]
    else:
        labels = batch["labels"]
        h_for_loss = h
    if logits_chunk and h_for_loss.shape[1] > logits_chunk:
        S = h_for_loss.shape[1]
        pad = (-S) % logits_chunk
        hp = jnp.pad(h_for_loss, ((0, 0), (0, pad), (0, 0)))
        lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        n = (S + pad) // logits_chunk
        hp = hp.reshape(h_for_loss.shape[0], n, logits_chunk, -1)
        lp = lp.reshape(labels.shape[0], n, logits_chunk)

        def chunk_loss(carry, inp):
            hc, lc = inp
            logits = unembed(params, cfg, hc)
            mask = (lc != -100)
            lsum = cross_entropy_loss(logits, lc) * jnp.maximum(
                mask.sum(), 1
            )
            return (carry[0] + lsum, carry[1] + mask.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            chunk_loss,
            (jnp.float32(0.0), jnp.int32(0)),
            (hp.transpose(1, 0, 2, 3), lp.transpose(1, 0, 2)),
        )
        loss = tot / jnp.maximum(cnt, 1)
    else:
        logits = unembed(params, cfg, h_for_loss)
        loss = cross_entropy_loss(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss


# ---------------------------------------------------------------------------
# serving: prefill + decode with explicit state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16):
    """Stacked per-position states for the scan. ``pos`` is the write index."""
    P = cfg.pattern_period
    n_rep = cfg.n_groups_of_layers

    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), tree
        )

    states = {}
    for pos in range(P):
        kind = cfg.block_pattern[pos]
        st: dict = {"pos": jnp.int32(0)}
        if kind == "attn":
            st["kv"] = attn_mod.init_cache(cfg, batch, max_len, cache_dtype)
        elif kind == "mamba":
            st["mamba"] = ssm_mod.mamba_state_init(cfg, batch, cache_dtype)
        elif kind == "rwkv":
            st["rwkv"] = ssm_mod.rwkv_state_init(cfg, batch, cache_dtype)
        states[f"pos{pos}"] = stack(st)
    return {"layers": states, "pos": jnp.int32(0)}


def decode_step(params, cfg: ModelConfig, state, batch, *, use_flash=False,
                unroll_layers=False):
    """Append S new tokens (S=1 for decode) -> (logits (B,S,V), new state)."""
    x = embed_inputs(params, cfg, batch)
    # inject the global position into each layer state copy
    layers = jax.tree.map(lambda v: v, state["layers"])
    for pos_key in layers:
        layers[pos_key]["pos"] = jnp.broadcast_to(
            state["pos"], layers[pos_key]["pos"].shape
        )
    x, new_layers, _ = _scan_blocks(
        x, params, cfg, layers, use_flash, unroll_layers=unroll_layers
    )
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    new_state = {
        "layers": new_layers,
        "pos": state["pos"] + x.shape[1],
    }
    return logits, new_state


def prefill(params, cfg: ModelConfig, batch, max_len: int, *,
            use_flash=False, cache_dtype=jnp.bfloat16, unroll_layers=False):
    """Process the full prompt, returning last-token logits + filled state."""
    if cfg.family == "vlm":
        B = batch["tokens"].shape[0]
        S = batch["tokens"].shape[1] + batch["patches"].shape[1]
    elif cfg.input_kind == "embeddings":
        B, S = batch["embeds"].shape[:2]
    else:
        B, S = batch["tokens"].shape
    state = init_decode_state(cfg, B, max_len, cache_dtype)
    logits, state = decode_step(
        params, cfg, state, batch, use_flash=use_flash,
        unroll_layers=unroll_layers,
    )
    return logits[:, -1:], state
