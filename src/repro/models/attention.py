"""Grouped-query attention with RoPE, qk-norm, KV cache, and flash option.

The XLA einsum path is the dry-run/roofline path (cost_analysis sees real
FLOPs); the Pallas kernels in ``repro.kernels`` are the TPU deployment path,
selected via ``use_flash`` and validated against this reference in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    apply_rope,
    init_dense,
    init_rmsnorm,
    rmsnorm,
    rope_angles,
)


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, d, cfg.n_heads * hd, dtype),
        "wk": init_dense(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": init_dense(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": init_dense(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_valid_len=None):
    """Reference GQA scaled-dot-product attention (no repeated-KV
    materialization — grouped einsum keeps KV bytes at n_kv heads).

    q: (B, Sq, Hq, hd); k, v: (B, Sk, n_kv, hd).  fp32 softmax.
    ``kv_valid_len``: mask out cache slots >= this length (decode mode).
    """
    B, Sq, Hq, hd = q.shape
    n_kv = k.shape[2]
    G = Hq // n_kv
    qg = q.reshape(B, Sq, n_kv, G, hd)
    scale = hd**-0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits * scale
    Sk = k.shape[1]
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = None
    if causal:
        mask = qpos[:, None] >= kpos[None, :]
    if kv_valid_len is not None:
        vmask = kpos[None, :] < kv_valid_len
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, Hq, hd)


def attention(
    x,
    params,
    cfg,
    *,
    positions=None,
    cache=None,
    cache_index=None,
    use_flash: bool = False,
):
    """Forward attention.

    x: (B, S, D).  Without a cache: full self-attention (causal per cfg).
    With ``cache = {"k": (B, S_max, n_kv, hd), "v": ...}`` and scalar
    ``cache_index``: decode/append mode — writes S new entries at
    cache_index and attends over the first cache_index + S entries.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    q = (xc @ params["wq"].astype(cdt)).reshape(B, S, cfg.n_heads, hd)
    k = (xc @ params["wk"].astype(cdt)).reshape(B, S, cfg.n_kv_heads, hd)
    v = (xc @ params["wv"].astype(cdt)).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"]["w"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"]["w"], cfg.norm_eps)
    if positions is None:
        offset = 0 if cache_index is None else cache_index
        positions = jnp.arange(S) + offset
        positions = jnp.broadcast_to(positions, (B, S))
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # Sharding pins for full-sequence self-attention.  When n_kv divides the
    # TP axis, heads shard cleanly; when it does NOT (arctic 8 kv vs 16-way
    # TP), the partitioner replicates batch and ALL-REDUCES the fp32 S^2
    # logits (measured 112 GiB per layer, §Perf-arctic it.3) — instead pin
    # K/V on the sequence axis: softmax over a seq-sharded axis lowers to
    # partial max/sum + tiny stat all-reduces (flash-decode style).
    if cache is None:
        from repro.sharding.context import current_mesh, constraint

        mesh = current_mesh()
        if mesh is not None:
            msize = dict(
                zip(mesh.axis_names, mesh.devices.shape)
            ).get("model", 1)
            dp = ("pod", "data")
            if cfg.n_kv_heads % msize == 0:
                q = constraint(q, dp, None, "model", None)
                k = constraint(k, dp, None, "model", None)
                v = constraint(v, dp, None, "model", None)
            else:
                q = constraint(q, dp, None, None, None)
                k = constraint(k, dp, "model", None, None)
                v = constraint(v, dp, "model", None, None)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0)
        )
        new_cache = {"k": ck, "v": cv}
        if use_flash:
            from repro.kernels.decode_attention import ops as dec_ops

            kv_len = cache_index + S
            out = dec_ops.decode_attention(
                q, ck.astype(cdt), cv.astype(cdt), kv_len
            )
        else:
            # causal within the appended block + mask unwritten cache slots
            out = _sdpa(
                q, ck.astype(cdt), cv.astype(cdt),
                causal=True, q_offset=cache_index,
                kv_valid_len=cache_index + S,
            )
    else:
        if use_flash:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(q, k, v, causal=cfg.causal)
        else:
            out = _sdpa(q, k, v, causal=cfg.causal)
    out = out.reshape(B, S, cfg.n_heads * hd)
    return out @ params["wo"].astype(cdt), new_cache


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }
