"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch, EP.

Design (GShard/MaxText-style "grouped dropped" MoE, TPU-native):

* tokens are processed in ``n_groups`` dispatch groups (a group == one data
  shard at scale, so dispatch stays shard-local and the only cross-device
  movement is the expert all_to_all the SPMD partitioner derives from the
  group->expert resharding);
* within a group, token->expert assignment is sorted (argsort) and each
  expert takes up to ``capacity`` tokens, the rest fall through on the
  residual path (standard dropped-token semantics);
* expert compute is a batched einsum over the expert dimension -> FLOPs are
  tokens * top_k * expert_ffn, NOT n_experts * (the one-hot-dispatch blowup);
* experts are sharded over the "model" mesh axis (EP) via sharding rules in
  ``repro.sharding.rules``; arctic's dense-residual branch runs in parallel.

Router aux loss is the Switch load-balance loss, returned alongside.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    kr, kg, ku, kd = jax.random.split(key, 4)
    shape3 = lambda k, a, b: (
        jax.random.normal(k, (m.n_experts, a, b)) * (a**-0.5)
    ).astype(dtype)
    p = {
        "router": init_dense(kr, d, m.n_experts, jnp.float32),
        "w_gate": shape3(kg, d, f),
        "w_up": shape3(ku, d, f),
        "w_down": shape3(kd, f, d),
    }
    return p


def moe_ffn(x, params, cfg, compute_dtype=jnp.bfloat16):
    """x: (T, D) token block (one dispatch group). Returns (y, aux_loss)."""
    m = cfg.moe
    T, D = x.shape
    E, K = m.n_experts, m.top_k
    cap = max(1, math.ceil(T * K / E * m.capacity_factor))
    cap = min(cap, T)

    # --- router (fp32) ----------------------------------------------------
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)    # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # Switch aux loss: E * sum_e fraction_tokens_e * mean_prob_e.
    assign_onehot = jax.nn.one_hot(expert_idx[:, 0], E)  # top-1 fractions
    aux = E * jnp.mean(assign_onehot.mean(0) * probs.mean(0))

    # --- sort-based dispatch ----------------------------------------------
    flat_expert = expert_idx.reshape(-1)               # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # Position of each routed slot within its expert.
    start = jnp.searchsorted(se, jnp.arange(E + 1), side="left")
    pos = jnp.arange(T * K) - start[se]
    keep = pos < cap
    # Gather kept tokens into (E, cap, D); dropped slots point at row 0 with
    # zero gate so they contribute nothing.
    slot_token = jnp.where(keep, st, 0)
    buf_tok = jnp.zeros((E, cap), dtype=jnp.int32)
    buf_gate = jnp.zeros((E, cap), dtype=jnp.float32)
    buf_valid = jnp.zeros((E, cap), dtype=bool)
    erow = jnp.where(keep, se, E)
    ecol = jnp.where(keep, pos, 0)
    buf_tok = buf_tok.at[erow, ecol].set(slot_token, mode="drop")
    buf_gate = buf_gate.at[erow, ecol].set(
        jnp.where(keep, sg, 0.0), mode="drop"
    )
    buf_valid = buf_valid.at[erow, ecol].set(keep, mode="drop")

    xin = x.astype(compute_dtype)[buf_tok]             # (E, cap, D)
    xin = xin * buf_valid[..., None].astype(compute_dtype)

    # --- expert compute (batched over E; EP shards this axis) -------------
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    if cfg.ffn_type == "geglu":
        act = lambda z: jax.nn.gelu(z, approximate=True)
    else:
        act = jax.nn.silu
    h = act(jnp.einsum("ecd,edf->ecf", xin, wg)) * jnp.einsum(
        "ecd,edf->ecf", xin, wu
    )
    yexp = jnp.einsum("ecf,efd->ecd", h, wd)           # (E, cap, D)

    # --- combine: scatter-add back to tokens, weighted by gates -----------
    yexp = yexp * buf_gate[..., None].astype(compute_dtype)
    y = jnp.zeros((T, D), dtype=compute_dtype)
    y = y.at[buf_tok.reshape(-1)].add(
        yexp.reshape(E * cap, D),
        mode="drop",
    )
    return y, aux.astype(jnp.float32)


def moe_ffn_grouped(x, params, cfg, compute_dtype=jnp.bfloat16):
    """x: (B, S, D) -> grouped GShard-style one-hot-einsum MoE.

    Groups slice the flattened token axis so each group is one data shard's
    tokens at the production sharding.  Dispatch and combine are pure
    EINSUMS against a (G, S, E, C) assignment tensor — no sort / gather /
    scatter, which GSPMD cannot partition on the expert axis (measured:
    sort+scatter dispatch replicated expert grads, 82% of arctic-480b train
    collective bytes as 6.4 TB/device of all-reduce; a gather-based combine
    replicated the (G, T*K, D) intermediate instead — §Perf-arctic it.1-4).
    The einsum dispatch costs ~2*T*S_g*k*cf*D extra flops (~17% of arctic's
    expert compute at S_g=4096) and partitions perfectly: G on dp, E on ep.

    Position-in-expert is the GShard cumsum construction, k-major priority
    (all first choices claim capacity before any second choice).
    """
    from repro.sharding.context import constraint

    m = cfg.moe
    B, S_, D = x.shape
    G = m.n_groups
    T_all = B * S_
    if T_all % G:
        G = 1
    T = T_all // G
    E, K = m.n_experts, m.top_k
    cap = max(1, math.ceil(T * K / E * m.capacity_factor))
    cap = min(cap, T)
    dp, ep = ("pod", "data"), "model"

    xg = constraint(x.reshape(G, T, D), dp, None, None)

    # --- router (fp32) ----------------------------------------------------
    logits = xg.astype(jnp.float32) @ params["router"]      # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (G, T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )
    aux = E * jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E).mean(1) * probs.mean(1)
    )

    # --- GShard cumsum position assignment (k-major priority) -------------
    # mask_k: (G, T, E) one-hot of the k-th choice
    masks = [jax.nn.one_hot(expert_idx[..., k], E, dtype=jnp.int32)
             for k in range(K)]
    counts_before = jnp.zeros((G, 1, E), jnp.int32)
    dispatch = None
    combine = None
    for k in range(K):
        mk = masks[k]
        pos_k = jnp.cumsum(mk, axis=1) - mk + counts_before  # (G, T, E)
        keep_k = (pos_k < cap) & (mk > 0)
        # (G, T, E, C) one-hot of the claimed capacity slot
        slot = jax.nn.one_hot(
            jnp.where(keep_k, pos_k, cap), cap, dtype=compute_dtype
        ) * keep_k[..., None].astype(compute_dtype)
        dispatch = slot if dispatch is None else dispatch + slot
        combine_k = slot * gate_vals[..., k][..., None, None].astype(
            compute_dtype
        )
        combine = combine_k if combine is None else combine + combine_k
        counts_before = counts_before + mk.sum(axis=1, keepdims=True)
    dispatch = constraint(dispatch, dp, None, ep, None)
    combine = constraint(combine, dp, None, ep, None)

    # --- dispatch / expert compute / combine (all einsum) -----------------
    xin = jnp.einsum(
        "gtec,gtd->gecd", dispatch, xg.astype(compute_dtype)
    )
    xin = constraint(xin, dp, ep, None, None)
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    act = (
        (lambda z: jax.nn.gelu(z, approximate=True))
        if cfg.ffn_type == "geglu" else jax.nn.silu
    )
    h = act(jnp.einsum("gecd,edf->gecf", xin, wg)) * jnp.einsum(
        "gecd,edf->gecf", xin, wu
    )
    h = constraint(h, dp, ep, None, None)
    yexp = jnp.einsum("gecf,efd->gecd", h, wd)
    yexp = constraint(yexp, dp, ep, None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, yexp)
    y = constraint(y, dp, None, None)
    return y.reshape(B, S_, D), aux.astype(jnp.float32)
