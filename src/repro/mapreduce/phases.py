"""Shared MapReduce phase primitives (the single source of truth).

The engine used to carry two near-identical copies of the map-task,
combiner, partition, and reduce logic — one in ``build_job`` and one in
``build_job_sharded``.  This module is the one implementation both paths
(and any future backend) compose:

* :func:`task_setup`        — fixed per-task startup compute (JVM analogue);
* :func:`hash_to_reducer`   — Knuth multiplicative key hashing;
* :func:`segment_sum_sorted`— sorted equal-key aggregation (sum / max / first);
* :func:`run_map_task`      — setup + ``map_fn`` + local spill sort;
* :func:`map_phase`         — wave-scheduled map over (waves, W) task grid;
* :func:`combine_rows`      — map-side combine: per-task aggregation +
  compaction of the spill-sorted rows, shrinking everything downstream
  (:func:`combine_capacity` is the static distinct-key bound);
* :func:`bucket_scatter`    — capacity-bounded partition scatter, with
  overflow *accounting* (the ``dropped`` count) instead of silent loss;
* :func:`reduce_phase` / :func:`reduce_local` — wave-scheduled reduce
  through a pluggable :class:`repro.mapreduce.backends.ReduceBackend`.

Everything is pure ``jnp`` with static shapes, so every phase composes
under ``jit``, ``vmap``, ``scan``, and ``shard_map``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

PAD_KEY = jnp.iinfo(jnp.int32).max  # sorts to the end

#: bytes per (key, value) pair moving between phases: two int32s.  The
#: telemetry layer's byte counters (shuffle bytes_in/out/dropped) are pair
#: counts scaled by this, so conservation in pairs and bytes coincide.
PAIR_BYTES = 8

#: reduce ops safe to pre-aggregate map-side: a combiner applies the op
#: twice (per task, then per reducer), which is only semantics-preserving
#: for commutative + associative ops.  ``first`` keeps the earliest value
#: per key in shuffle-delivery order, so combining it would change which
#: value survives — the plan rejects combiner configs for it.
COMBINABLE_OPS = ("sum", "max")


def count_live(keys) -> jnp.ndarray:
    """Number of live (non-PAD) slots in a key array — the counter primitive
    shared by the telemetry layer and the conservation tests."""
    return (jnp.asarray(keys) != PAD_KEY).sum()


def task_setup(dim: int, rounds: int, seed_val):
    """Fixed per-task startup compute — the JVM-start analogue.

    A short chain of (dim x dim) matmuls seeded by the task's data so XLA
    cannot fold it away.  Cost is independent of split size: pure overhead.
    """
    x = (
        jnp.full((dim, dim), 1e-3, dtype=jnp.float32)
        + seed_val.astype(jnp.float32) * 1e-9
    )
    w = jnp.eye(dim, dtype=jnp.float32) * 0.999

    def body(x, _):
        return jnp.tanh(x @ w), None

    x, _ = jax.lax.scan(body, x, None, length=rounds)
    return x.sum() * 1e-20  # ~0 but data-dependent; folded into values


def hash_to_reducer(keys, num_reducers: int):
    """Knuth multiplicative hash in uint32, then mod R."""
    h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(num_reducers)).astype(jnp.int32)


def segment_sum_sorted(keys, values, valid, reduce_op: str = "sum"):
    """Aggregate values of equal adjacent keys (input sorted by key).

    Returns (unique_keys, aggregated, out_valid): one slot per first
    occurrence, PAD elsewhere.  Pure jnp; the Pallas `segment_reduce` kernel
    implements the same contract for the TPU deployment path.
    """
    n = keys.shape[0]
    first = jnp.concatenate(
        [jnp.array([True]), keys[1:] != keys[:-1]]
    ) & valid
    seg_id = jnp.cumsum(first.astype(jnp.int32)) - 1  # -1 before first valid
    seg_id = jnp.where(valid, seg_id, n - 1)  # dump invalid into last slot
    if reduce_op == "sum":
        agg = jnp.zeros((n,), dtype=values.dtype).at[seg_id].add(
            jnp.where(valid, values, 0)
        )
    elif reduce_op == "max":
        agg = jnp.full((n,), jnp.iinfo(jnp.int32).min, dtype=values.dtype)
        agg = agg.at[seg_id].max(
            jnp.where(valid, values, jnp.iinfo(jnp.int32).min)
        )
    elif reduce_op == "first":
        # The earliest value of each run in delivery order: the stable
        # sorts upstream put it at the first-occurrence slot, so the
        # aggregate IS the value already sitting there.  Order-dependent
        # by definition — hence not in COMBINABLE_OPS.
        agg = jnp.zeros((n,), dtype=values.dtype).at[seg_id].add(
            jnp.where(first, values, 0)
        )
    else:
        raise ValueError(reduce_op)
    # The aggregate for the segment starting at a first-occurrence position i
    # is agg[seg_id[i]]; non-first slots are PAD.
    out_keys = jnp.where(first, keys, PAD_KEY)
    out_vals = jnp.where(first, agg[seg_id], 0)
    return out_keys, out_vals, first


def run_map_task(app, cfg, tokens, valid):
    """One map task: startup + map_fn + local spill sort.

    tokens/valid: (S,).  Returns keys/values/pvalid of shape (P,).  The
    map-side combiner is *not* applied here — it is its own fenced stage
    (:func:`combine_rows`, run by the plan between map and shuffle) so it
    can be wall-clocked, counted, and checkpointed at a wave boundary.
    """
    setup = task_setup(cfg.setup_dim, cfg.setup_rounds, tokens.sum())
    keys, values, pvalid = app.map_fn(tokens, valid)
    # Local spill sort (Hadoop sorts map output before the shuffle).
    order = jnp.argsort(jnp.where(pvalid, keys, PAD_KEY))
    keys, values, pvalid = keys[order], values[order], pvalid[order]
    values = values + setup.astype(values.dtype)  # keep setup live
    return keys, values, pvalid


def map_phase(app, cfg, splits, split_valid):
    """Run map tasks in waves of W workers.

    splits: (waves, W, S) int32; split_valid: (waves, W, S) bool.
    Returns keys/values/valid of shape (waves, W, P).
    """

    def wave(carry, inp):
        tok, val = inp
        k, v, pv = jax.vmap(lambda t, m: run_map_task(app, cfg, t, m))(
            tok, val
        )
        return carry, (k, v, pv)

    _, (keys, values, pvalid) = jax.lax.scan(
        wave, jnp.int32(0), (splits, split_valid)
    )
    return keys, values, pvalid


def partition_capacity(n_pairs: int, n_buckets: int, factor: float) -> int:
    """Capacity per partition: uniform share x safety factor, clamped."""
    cap = max(1, int(math.ceil(n_pairs / max(n_buckets, 1) * factor)))
    return min(cap, n_pairs)


def combine_capacity(n_pairs: int, key_space: int) -> int:
    """Static per-task combined-row width: a task emitting ``n_pairs``
    pairs over ``key_space`` possible keys produces at most
    ``min(n_pairs, key_space)`` distinct keys, so truncating the combined
    row there is lossless — and it is this *static* shrink that pulls
    every downstream capacity (:func:`partition_capacity` feeds on the
    stream width) down with it."""
    return max(1, min(int(n_pairs), int(key_space)))


def combine_rows(backend, keys, values, pvalid, reduce_op: str, cap: int):
    """Map-side combine over task-major rows: aggregate each task's
    equal-key runs and compact the row to ``cap`` columns.

    keys/values/pvalid: (N, P) spill-sorted task rows.  Dead slots may
    hold garbage keys (the spill sort only orders by the masked view), so
    they are first masked to PAD_KEY — the validity contract of
    :class:`repro.mapreduce.backends.ReduceBackend`.  The backend's
    ``combine`` front-packs each row's aggregates in ascending key order;
    the static ``[:cap]`` truncation (``cap`` from
    :func:`combine_capacity`) then drops only dead tail slots.

    Returns (ck, cv, cvalid) of shape (N, cap).
    """
    km = jnp.where(pvalid, keys, PAD_KEY)
    vm = jnp.where(pvalid, values, 0)
    ck, cv = backend.combine(km, vm, reduce_op)
    ck, cv = ck[:, :cap], cv[:, :cap]
    return ck, cv, ck != PAD_KEY


def bucket_scatter(ids, n_buckets, n_rows, cap, arrays, fills):
    """Capacity-bounded scatter into fixed (n_rows, cap) partitions.

    ids: (n,) int32, **sorted ascending**; values >= n_buckets mark invalid
    entries (they land nowhere).  ``arrays`` are parallel (n,) arrays; each
    is scattered to ``out[id, position-within-bucket]``, initialised to its
    ``fills`` entry.  Rows n_buckets..n_rows stay at fill (wave padding).

    Returns (list of (n_rows, cap) arrays, dropped) where ``dropped`` counts
    valid entries lost to capacity overflow — Hadoop's fixed spill/partition
    buffers, but with the loss *accounted* so tests can assert conservation.
    """
    n = ids.shape[0]
    start = jnp.searchsorted(ids, jnp.arange(n_buckets + 1), side="left")
    pos = jnp.arange(n) - start[jnp.clip(ids, 0, n_buckets)]
    valid = ids < n_buckets
    dropped = jnp.sum((pos >= cap) & valid)
    row = jnp.where(valid & (pos < cap), ids, n_rows)
    col = jnp.clip(pos, 0, cap - 1)
    outs = []
    for arr, fill in zip(arrays, fills):
        buf = jnp.full((n_rows, cap), fill, dtype=arr.dtype)
        outs.append(buf.at[row, col].set(arr, mode="drop"))
    return outs, dropped


def _masked_setup(cfg, keys_block, out_keys, out_vals):
    """Per-task startup for a reduce block, added only to live output slots.

    keys_block: (N, cap); out_keys/out_vals: backend output (N, cap).
    """
    setup = jax.vmap(
        lambda k: task_setup(cfg.setup_dim, cfg.setup_rounds, k.sum())
    )(keys_block)
    live = out_keys != PAD_KEY
    return out_vals + jnp.where(live, setup[:, None], 0.0).astype(
        out_vals.dtype
    )


def reduce_phase(app, cfg, part_keys, part_vals, backend):
    """Wave-scheduled reduce: R tasks in ``reduce_waves`` waves of W workers.

    part_keys/part_vals: (R_pad, cap) with R_pad = reduce_waves * W, each row
    sorted by key with PAD_KEY padding.  The per-partition aggregation is
    delegated to ``backend`` (a :class:`~repro.mapreduce.backends.ReduceBackend`).
    Returns out_keys/out_vals of shape (R_pad, cap).
    """
    waves_r, W = cfg.reduce_waves, cfg.num_workers
    cap = part_keys.shape[1]
    pk = part_keys.reshape(waves_r, W, cap)
    pv = part_vals.reshape(waves_r, W, cap)

    def wave(carry, inp):
        k, v = inp  # (W, cap): one wave of W reduce tasks
        ok, ov = backend.reduce(k, v, app.reduce_op)
        ov = _masked_setup(cfg, k, ok, ov)
        return carry, (ok, ov)

    _, (ok, ov) = jax.lax.scan(wave, jnp.int32(0), (pk, pv))
    return ok.reshape(waves_r * W, cap), ov.reshape(waves_r * W, cap)


def reduce_local(app, cfg, part_keys, part_vals, backend):
    """Per-worker serial reduce over this worker's owned reduce slots.

    part_keys/part_vals: (slots, cap).  Each slot is one reduce task; they
    run serially (a worker processes its waves one at a time), matching the
    wave-scheduling semantics of the sharded path.
    """

    def one(carry, inp):
        k, v = inp  # (cap,)
        ok, ov = backend.reduce(k[None], v[None], app.reduce_op)
        ov = _masked_setup(cfg, k[None], ok, ov)
        return carry, (ok[0], ov[0])

    _, (ok, ov) = jax.lax.scan(one, jnp.int32(0), (part_keys, part_vals))
    return ok, ov
