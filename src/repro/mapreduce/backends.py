"""Pluggable shuffle/reduce backends for the MapReduce phase pipeline.

The paper models total execution time as a function of configuration
parameters (M, R, ...).  This module turns the *execution strategy* itself
into one more configuration axis: a ``JobConfig`` names a reduce backend and
a shuffle backend by string, the engine resolves them here, and the tuner
can treat the backend as a categorical knob (one model per category — the
paper's per-application model-database pattern, reused per-backend).

Reduce backends (per-partition sorted segment aggregation, all implementing
the same contract as :func:`repro.mapreduce.phases.segment_sum_sorted`):

* ``jnp``    — scatter-add segment sum (the portable reference);
* ``pallas`` — the Pallas TPU ``segment_reduce`` kernel (MXU one-hot
  matmul formulation; interpret mode off-TPU), ``sum`` only;
* ``xla``    — ``jax.ops.segment_sum`` / ``segment_max`` primitives.

Shuffle backends:

* ``lexsort``    — single-controller global sort by (reducer, key) +
  capacity-bounded scatter;
* ``all_to_all`` — per-worker partition + a literal mesh ``all_to_all``
  (the multi-chip deployment path; used inside ``shard_map``).

Registering a new backend is one call::

    register_reduce_backend(MyBackend())
    JobConfig(..., reduce_backend="mine")   # now valid
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mapreduce import phases
from repro.mapreduce.phases import PAD_KEY, bucket_scatter, hash_to_reducer


# ---------------------------------------------------------------------------
# Reduce backends
# ---------------------------------------------------------------------------


class ReduceBackend:
    """Per-partition sorted segment aggregation.

    ``reduce(keys, values, reduce_op)`` takes (N, C) blocks — N partitions,
    each row sorted by key with PAD_KEY padding — and returns (out_keys,
    out_vals) of the same shape: the aggregate of each equal-key run at its
    first occurrence, (PAD_KEY, 0) elsewhere.

    ``combine(keys, values, reduce_op)`` is the map-side variant of the
    same aggregation: identical validity contract, but each row's
    aggregates come back *front-packed* in ascending key order with a
    (PAD_KEY, 0) tail — so the caller can truncate the row to its
    distinct-key bound and shrink the shuffle stream.  The default
    derivation sorts the sparse ``reduce`` output (first occurrences of a
    sorted row are ascending and distinct, so an ascending key sort IS the
    compaction); backends with a native compacting kernel override it.
    """

    name: str = "abstract"
    supported_ops: tuple[str, ...] = ()

    def reduce(self, keys, values, reduce_op: str):
        raise NotImplementedError

    def combine(self, keys, values, reduce_op: str):
        ok, ov = self.reduce(keys, values, reduce_op)
        order = jnp.argsort(ok, axis=1)  # PAD_KEY sorts last
        return (
            jnp.take_along_axis(ok, order, axis=1),
            jnp.take_along_axis(ov, order, axis=1),
        )


class JnpReduceBackend(ReduceBackend):
    """Portable reference: scatter-add/max segment reduce (pure jnp)."""

    name = "jnp"
    supported_ops = ("sum", "max", "first")

    def reduce(self, keys, values, reduce_op: str):
        ok, ov, _ = jax.vmap(
            lambda k, v: phases.segment_sum_sorted(
                k, v, k != PAD_KEY, reduce_op
            )
        )(keys, values)
        return ok, ov


class PallasReduceBackend(ReduceBackend):
    """The Pallas TPU segment-reduce kernel (one grid step per partition).

    Accumulates on the MXU in float32, so integer aggregates are exact only
    while every partial sum stays below ``EXACT_INT_BOUND`` (2**24); beyond
    that the result silently loses low bits relative to the jnp/xla
    backends.  Workloads with per-key totals near that bound should use a
    different backend (tests/test_backends.py pins this boundary).

    ``interpret=None`` (default) auto-selects: the compiled kernel on TPU,
    interpret mode everywhere else.
    """

    name = "pallas"
    supported_ops = ("sum",)
    EXACT_INT_BOUND = 2 ** 24  # float32 integer-exactness limit

    def __init__(self, interpret: bool | None = None):
        self.interpret = interpret

    def reduce(self, keys, values, reduce_op: str):
        if reduce_op not in self.supported_ops:
            raise ValueError(
                f"pallas reduce backend supports {self.supported_ops}, "
                f"got {reduce_op!r}"
            )
        from repro.kernels.segment_reduce import segment_reduce

        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return segment_reduce(keys, values, interpret=interpret)

    def combine(self, keys, values, reduce_op: str):
        # Native compacting kernel: the one-hot segment matmul indexed by
        # segment id front-packs in one pass — no host-visible sort.
        if reduce_op not in self.supported_ops:
            raise ValueError(
                f"pallas reduce backend supports {self.supported_ops}, "
                f"got {reduce_op!r}"
            )
        from repro.kernels.local_reduce import local_reduce

        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return local_reduce(keys, values, interpret=interpret)


class XlaReduceBackend(ReduceBackend):
    """XLA segment primitives (``jax.ops.segment_sum`` / ``segment_max``)."""

    name = "xla"
    supported_ops = ("sum", "max", "first")

    def reduce(self, keys, values, reduce_op: str):
        def one_row(k, v):
            n = k.shape[0]
            valid = k != PAD_KEY
            first = jnp.concatenate(
                [jnp.array([True]), k[1:] != k[:-1]]
            ) & valid
            seg = jnp.cumsum(first.astype(jnp.int32)) - 1
            seg = jnp.where(valid, seg, n - 1)
            if reduce_op == "sum":
                agg = jax.ops.segment_sum(
                    jnp.where(valid, v, 0), seg, num_segments=n
                )
            elif reduce_op == "max":
                agg = jax.ops.segment_max(
                    jnp.where(valid, v, jnp.iinfo(jnp.int32).min),
                    seg,
                    num_segments=n,
                )
            elif reduce_op == "first":
                # Delivery order is the stable sort order, so the first
                # value of each run already sits at the first-occurrence
                # slot (order-dependent: deliberately not combinable).
                agg = jax.ops.segment_sum(
                    jnp.where(first, v, 0), seg, num_segments=n
                )
            else:
                raise ValueError(reduce_op)
            out_k = jnp.where(first, k, PAD_KEY)
            out_v = jnp.where(first, agg[seg], 0).astype(v.dtype)
            return out_k, out_v

        return jax.vmap(one_row)(keys, values)


# ---------------------------------------------------------------------------
# Shuffle backends
# ---------------------------------------------------------------------------


class ShuffleBackend:
    """Routes map-output pairs into per-reduce-task partitions.

    Two structural families share this interface:

    * non-collective (``collective = False``): :meth:`partition` sees the
      job's full flat pair stream and returns global (R_pad, cap)
      partitions — used by the single-controller path;
    * collective (``collective = True``): :meth:`exchange` runs *inside* a
      ``shard_map`` worker body on that worker's local pairs and returns the
      (slots, cap) reduce buckets the worker owns after the exchange.

    Both return a ``dropped`` count for capacity-overflow accounting.
    """

    name: str = "abstract"
    collective: bool = False

    def partition(self, cfg, keys, values, pvalid):
        raise NotImplementedError(f"{self.name} is not a global shuffle")

    def exchange(self, cfg, axis, keys, values, pvalid):
        raise NotImplementedError(f"{self.name} is not a collective shuffle")

    def capacity_for(self, cfg, n_pairs: int) -> int:
        """Per-partition slot capacity this backend will allocate for a job
        with ``n_pairs`` total map-output pairs.  The telemetry layer reads
        this to size its counters; must match what :meth:`partition` /
        :meth:`exchange` actually use."""
        return phases.partition_capacity(
            n_pairs, cfg.num_reducers, cfg.capacity_factor
        )


class LexsortShuffle(ShuffleBackend):
    """Single-controller shuffle: global sort by (reducer, key) + scatter."""

    name = "lexsort"
    collective = False

    def partition(self, cfg, keys, values, pvalid):
        """keys/values/pvalid: flat (n,).  Returns (part_keys, part_vals,
        dropped) with partitions of shape (reduce_waves * W, cap)."""
        R, W = cfg.num_reducers, cfg.num_workers
        n = keys.shape[0]
        rid = hash_to_reducer(keys, R)
        rid = jnp.where(pvalid, rid, R)  # invalid pairs -> OOB dump row
        # Global shuffle sort: primary reducer id, secondary key.
        order = jnp.lexsort((keys, rid))
        skeys, svals, srid = keys[order], values[order], rid[order]
        cap = phases.partition_capacity(n, R, cfg.capacity_factor)
        R_pad = cfg.reduce_waves * W
        (part_keys, part_vals), dropped = bucket_scatter(
            srid, R, R_pad, cap, (skeys, svals), (PAD_KEY, 0)
        )
        return part_keys, part_vals, dropped


class AllToAllShuffle(ShuffleBackend):
    """Mesh shuffle: per-worker partition by destination + ``all_to_all``.

    Runs inside a ``shard_map`` worker body.  Reducer r lives on worker
    r % W; after the exchange each worker buckets its received pairs into
    the ``reduce_waves`` local reduce slots it owns (local slot = r // W).

    The worker-local halves are exposed as :meth:`pack` (before the
    collective) and :meth:`unpack` (after it) so non-mesh callers can
    compose them around an equivalent data movement: the elastic
    resumable path (``repro.elastic.resumable``) vmaps both halves over a
    worker axis and replaces the literal ``all_to_all`` with the block
    transpose it implements — one implementation, two execution modes.
    """

    name = "all_to_all"
    collective = True

    def pack(self, cfg, keys, values, pvalid):
        """Worker-local pre-exchange half: partition this worker's flat
        (n_local,) pairs by destination worker.  Returns ((send_k, send_v,
        send_r), dropped) with (W, shuf_cap) send buffers — row i goes to
        worker i — and the count lost to send-buffer overflow."""
        R, W = cfg.num_reducers, cfg.num_workers
        n_local = keys.shape[0]
        # Per (src, dst) shuffle capacity: uniform share x safety factor.
        shuf_cap = phases.partition_capacity(n_local, W, cfg.capacity_factor)
        # Partition local pairs by destination worker = rid % W.
        rid = jnp.where(pvalid, hash_to_reducer(keys, R), R)
        dst = jnp.where(pvalid, rid % W, W)
        order = jnp.lexsort((keys, rid, dst))
        k, v, rid, dst = (
            keys[order], values[order], rid[order], dst[order]
        )
        (send_k, send_v, send_r), send_dropped = bucket_scatter(
            dst, W, W, shuf_cap, (k, v, rid), (PAD_KEY, 0, R)
        )
        return (send_k, send_v, send_r), send_dropped

    def unpack(self, cfg, n_local, rk, rv, rr):
        """Worker-local post-exchange half: bucket the received flat pairs
        into this worker's reduce tasks (local slot = rid // W, since
        reducer r lives on worker r % W).  ``n_local`` is the per-worker
        map-output pair count, which sizes the reduce-bucket capacity the
        same way on every worker.  Returns ((bk, bv), dropped) with
        buckets of shape (reduce_waves, red_cap)."""
        R, W, waves_r = cfg.num_reducers, cfg.num_workers, cfg.reduce_waves
        red_cap = phases.partition_capacity(
            W * n_local, R, cfg.capacity_factor
        )
        lslot = jnp.where(rr < R, rr // W, waves_r)
        order = jnp.lexsort((rk, lslot))
        rk, rv, lslot = rk[order], rv[order], lslot[order]
        (bk, bv), recv_dropped = bucket_scatter(
            lslot, waves_r, waves_r, red_cap, (rk, rv), (PAD_KEY, 0)
        )
        return (bk, bv), recv_dropped

    def exchange(self, cfg, axis, keys, values, pvalid):
        """keys/values/pvalid: this worker's flat (n_local,) pairs.
        Returns (bucket_keys, bucket_vals, dropped) with buckets of shape
        (reduce_waves, red_cap) and ``dropped`` a per-phase (2,) vector
        ``[send_dropped, recv_dropped]`` — send-buffer overflow vs
        reduce-bucket overflow, kept separate so the sharded path can
        report true per-phase counters, not just the aggregate."""
        n_local = keys.shape[0]
        (send_k, send_v, send_r), send_dropped = self.pack(
            cfg, keys, values, pvalid
        )
        # The shuffle: exchange partition i with worker i (tiled all_to_all:
        # row i of the (W, cap) send buffer goes to worker i, received rows
        # re-stack along the same axis).
        recv_k = jax.lax.all_to_all(send_k, axis, 0, 0, tiled=True)
        recv_v = jax.lax.all_to_all(send_v, axis, 0, 0, tiled=True)
        recv_r = jax.lax.all_to_all(send_r, axis, 0, 0, tiled=True)
        (bk, bv), recv_dropped = self.unpack(
            cfg, n_local,
            recv_k.reshape(-1), recv_v.reshape(-1), recv_r.reshape(-1),
        )
        return bk, bv, jnp.stack([send_dropped, recv_dropped])


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

REDUCE_BACKENDS: dict[str, ReduceBackend] = {}
SHUFFLE_BACKENDS: dict[str, ShuffleBackend] = {}


def register_reduce_backend(backend: ReduceBackend) -> ReduceBackend:
    if not backend.supported_ops:
        raise ValueError(f"backend {backend.name!r} supports no reduce ops")
    REDUCE_BACKENDS[backend.name] = backend
    return backend


def register_shuffle_backend(backend: ShuffleBackend) -> ShuffleBackend:
    SHUFFLE_BACKENDS[backend.name] = backend
    return backend


register_reduce_backend(JnpReduceBackend())
register_reduce_backend(PallasReduceBackend())
register_reduce_backend(XlaReduceBackend())
register_shuffle_backend(LexsortShuffle())
register_shuffle_backend(AllToAllShuffle())


def get_reduce_backend(name: str) -> ReduceBackend:
    try:
        return REDUCE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown reduce backend {name!r}; "
            f"registered: {sorted(REDUCE_BACKENDS)}"
        ) from None


def get_shuffle_backend(name: str) -> ShuffleBackend:
    try:
        return SHUFFLE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown shuffle backend {name!r}; "
            f"registered: {sorted(SHUFFLE_BACKENDS)}"
        ) from None
