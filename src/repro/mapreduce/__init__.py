"""TPU-native MapReduce substrate: engine + the paper's two applications."""

from repro.mapreduce.engine import (
    JobConfig,
    MapReduceApp,
    PAD_KEY,
    build_job,
    build_job_sharded,
    collect_results,
)
from repro.mapreduce.apps import eximparse, wordcount, RECORD_WIDTH
from repro.mapreduce.datagen import exim_mainlog, wordcount_corpus

__all__ = [
    "JobConfig",
    "MapReduceApp",
    "PAD_KEY",
    "build_job",
    "build_job_sharded",
    "collect_results",
    "eximparse",
    "wordcount",
    "RECORD_WIDTH",
    "exim_mainlog",
    "wordcount_corpus",
]
