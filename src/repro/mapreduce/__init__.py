"""TPU-native MapReduce substrate: phase pipeline + pluggable backends +
the paper's two applications.

Layering (see ARCHITECTURE.md):

    phases.py   — the single shared implementation of each phase
    backends.py — swappable shuffle/reduce strategies + registries
    plan.py     — ExecutionPlan: the ONE lowering into canonical wave
                  steppers; fused/traced/sharded/resumable are modes
    engine.py   — JobConfig/MapReduceApp + thin build_job mode selectors
    apps.py     — WordCount and Exim mainlog parsing
    datagen.py  — synthetic corpora
"""

from repro.mapreduce.engine import (
    JobConfig,
    MapReduceApp,
    PAD_KEY,
    build_job,
    build_job_sharded,
    collect_results,
)
from repro.mapreduce.plan import ExecutionPlan
from repro.mapreduce.backends import (
    REDUCE_BACKENDS,
    SHUFFLE_BACKENDS,
    ReduceBackend,
    ShuffleBackend,
    get_reduce_backend,
    get_shuffle_backend,
    register_reduce_backend,
    register_shuffle_backend,
)
from repro.mapreduce.apps import eximparse, wordcount, RECORD_WIDTH
from repro.mapreduce.datagen import exim_mainlog, wordcount_corpus

__all__ = [
    "ExecutionPlan",
    "JobConfig",
    "MapReduceApp",
    "PAD_KEY",
    "build_job",
    "build_job_sharded",
    "collect_results",
    "REDUCE_BACKENDS",
    "SHUFFLE_BACKENDS",
    "ReduceBackend",
    "ShuffleBackend",
    "get_reduce_backend",
    "get_shuffle_backend",
    "register_reduce_backend",
    "register_shuffle_backend",
    "eximparse",
    "wordcount",
    "RECORD_WIDTH",
    "exim_mainlog",
    "wordcount_corpus",
]
