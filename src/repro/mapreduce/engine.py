"""TPU-native MapReduce engine (the paper's workload substrate).

Hadoop concepts are mapped onto JAX/XLA idioms rather than emulated:

* **map/reduce TASKS vs worker SLOTS** — M map tasks and R reduce tasks are
  scheduled over W parallel workers in ``ceil(M/W)`` / ``ceil(R/W)`` *waves*
  (`lax.scan` over waves, `vmap`/`shard_map` over workers).  This is Hadoop's
  slot scheduling, and it is exactly why total execution time depends
  non-trivially and non-monotonically on (M, R) — the dependency the paper
  models.
* **per-task startup overhead** — Hadoop pays JVM/task-setup seconds per
  task; our analogue is a fixed per-task setup compute (``setup_rounds`` of a
  small matmul chain) inside each wave, plus each map task's local spill sort.
* **shuffle** — key-hash partitioning to reducers.  In the single-controller
  path it is a global sort by (reducer, key) + capacity-bounded scatter into
  per-reducer partitions (Hadoop's fixed spill/partition buffers).  In the
  sharded path (``run_job_sharded``) it is a literal `all_to_all` over the
  worker mesh axis.
* **reduce** — per-reducer sorted segment aggregation (sum or app-defined),
  wave-scheduled like the map phase.

Shapes are static per (M, R, W, L) configuration — one compile per config,
wall-clocked post-warmup, which mirrors "job execution time" in the paper
(their clusters also pay a fixed job-setup cost they do not model).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

PAD_KEY = jnp.iinfo(jnp.int32).max  # sorts to the end


@dataclasses.dataclass(frozen=True)
class JobConfig:
    """One MapReduce experiment configuration (the paper's parameter set)."""

    num_mappers: int            # M: map tasks       (paper parameter 1)
    num_reducers: int           # R: reduce tasks    (paper parameter 2)
    num_workers: int = 1        # W: parallel worker slots (cluster size)
    combiner: bool = False      # map-side combine (extra modeled knob)
    capacity_factor: float = 4.0  # reducer partition capacity multiplier
    setup_rounds: int = 4       # per-task startup overhead (matmul rounds)
    setup_dim: int = 32         # startup compute size

    def __post_init__(self):
        if self.num_mappers < 1 or self.num_reducers < 1 or self.num_workers < 1:
            raise ValueError(f"bad config {self}")

    @property
    def map_waves(self) -> int:
        return math.ceil(self.num_mappers / self.num_workers)

    @property
    def reduce_waves(self) -> int:
        return math.ceil(self.num_reducers / self.num_workers)


@dataclasses.dataclass(frozen=True)
class MapReduceApp:
    """A MapReduce application: map emits (key, value) pairs; reduce
    aggregates values per key with ``reduce_op`` (associative, commutative).
    """

    name: str
    key_space: int
    # map_fn(tokens (S,), valid (S,)) -> keys (P,), values (P,), valid (P,)
    map_fn: Callable
    pairs_per_token: int = 1
    reduce_op: str = "sum"  # "sum" | "max"


def _task_setup(dim: int, rounds: int, seed_val):
    """Fixed per-task startup compute — the JVM-start analogue.

    A short chain of (dim x dim) matmuls seeded by the task's data so XLA
    cannot fold it away.  Cost is independent of split size: pure overhead.
    """
    x = (
        jnp.full((dim, dim), 1e-3, dtype=jnp.float32)
        + seed_val.astype(jnp.float32) * 1e-9
    )
    w = jnp.eye(dim, dtype=jnp.float32) * 0.999

    def body(x, _):
        return jnp.tanh(x @ w), None

    x, _ = jax.lax.scan(body, x, None, length=rounds)
    return x.sum() * 1e-20  # ~0 but data-dependent; folded into values


def _hash_to_reducer(keys, num_reducers: int):
    """Knuth multiplicative hash in uint32, then mod R."""
    h = keys.astype(jnp.uint32) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(num_reducers)).astype(jnp.int32)


def _segment_sum_sorted(keys, values, valid, reduce_op: str = "sum"):
    """Aggregate values of equal adjacent keys (input sorted by key).

    Returns (unique_keys, aggregated, out_valid): one slot per first
    occurrence, PAD elsewhere.  Pure jnp; the Pallas `segment_reduce` kernel
    implements the same contract for the TPU deployment path.
    """
    n = keys.shape[0]
    first = jnp.concatenate(
        [jnp.array([True]), keys[1:] != keys[:-1]]
    ) & valid
    seg_id = jnp.cumsum(first.astype(jnp.int32)) - 1  # -1 before first valid
    seg_id = jnp.where(valid, seg_id, n - 1)  # dump invalid into last slot
    if reduce_op == "sum":
        agg = jnp.zeros((n,), dtype=values.dtype).at[seg_id].add(
            jnp.where(valid, values, 0)
        )
    elif reduce_op == "max":
        agg = jnp.full((n,), jnp.iinfo(jnp.int32).min, dtype=values.dtype)
        agg = agg.at[seg_id].max(
            jnp.where(valid, values, jnp.iinfo(jnp.int32).min)
        )
    else:
        raise ValueError(reduce_op)
    # The aggregate for the segment starting at a first-occurrence position i
    # is agg[seg_id[i]]; non-first slots are PAD.
    out_keys = jnp.where(first, keys, PAD_KEY)
    out_vals = jnp.where(first, agg[seg_id], 0)
    return out_keys, out_vals, first


def _map_phase(app: MapReduceApp, cfg: JobConfig, splits, split_valid):
    """Run M map tasks in ``map_waves`` waves of W workers.

    splits: (waves, W, S) int32; split_valid: (waves, W, S) bool.
    Returns keys/values/valid of shape (waves, W, P).
    """

    def one_task(tokens, valid):
        setup = _task_setup(cfg.setup_dim, cfg.setup_rounds, tokens.sum())
        keys, values, pvalid = app.map_fn(tokens, valid)
        # Local spill sort (Hadoop sorts map output before the shuffle).
        order = jnp.argsort(jnp.where(pvalid, keys, PAD_KEY))
        keys, values, pvalid = keys[order], values[order], pvalid[order]
        if cfg.combiner:
            keys, values, first = _segment_sum_sorted(
                keys, values, pvalid, app.reduce_op
            )
            pvalid = first
        values = values + setup.astype(values.dtype)  # keep setup live
        return keys, values, pvalid

    def wave(carry, inp):
        tok, val = inp
        k, v, pv = jax.vmap(one_task)(tok, val)
        return carry, (k, v, pv)

    _, (keys, values, pvalid) = jax.lax.scan(
        wave, jnp.int32(0), (splits, split_valid)
    )
    return keys, values, pvalid


def _partition_and_reduce(app: MapReduceApp, cfg: JobConfig, keys, values, pvalid):
    """Shuffle (sort by (reducer, key) + capacity scatter) and wave-reduce.

    keys/values/pvalid: flat (n_pairs,) arrays.
    Returns out_keys/out_vals (R, C) with PAD_KEY marking empty slots, plus
    the number of pairs dropped by partition-capacity overflow.
    """
    R, W = cfg.num_reducers, cfg.num_workers
    n = keys.shape[0]
    rid = _hash_to_reducer(keys, R)
    rid = jnp.where(pvalid, rid, R)  # invalid pairs -> OOB dump row
    # Global shuffle sort: primary reducer id, secondary key.
    order = jnp.lexsort((keys, rid))
    skeys, svals, srid = keys[order], values[order], rid[order]
    svalid = srid < R
    # Position of each pair within its reducer partition.
    bucket_start = jnp.searchsorted(srid, jnp.arange(R + 1), side="left")
    pos = jnp.arange(n) - bucket_start[jnp.clip(srid, 0, R)]
    cap = max(
        1,
        int(math.ceil(n / R * cfg.capacity_factor)),
    )
    cap = min(cap, n)
    dropped = jnp.sum((pos >= cap) & svalid)
    # Scatter into fixed partitions (R_padded, cap); OOB rows/cols dropped.
    waves_r, Wp = cfg.reduce_waves, W
    R_pad = waves_r * Wp
    part_keys = jnp.full((R_pad, cap), PAD_KEY, dtype=skeys.dtype)
    part_vals = jnp.zeros((R_pad, cap), dtype=svals.dtype)
    row = jnp.where(svalid & (pos < cap), srid, R_pad)
    col = jnp.clip(pos, 0, cap - 1)
    part_keys = part_keys.at[row, col].set(skeys, mode="drop")
    part_vals = part_vals.at[row, col].set(svals, mode="drop")

    # Reduce phase: R tasks in waves of W workers.
    def one_reduce(pkeys, pvals):
        setup = _task_setup(cfg.setup_dim, cfg.setup_rounds, pkeys.sum())
        valid = pkeys != PAD_KEY
        # Partition arrives sorted by key (global sort was (rid, key)).
        out_k, out_v, first = _segment_sum_sorted(
            pkeys, pvals, valid, app.reduce_op
        )
        out_v = out_v + setup.astype(out_v.dtype)
        return jnp.where(first, out_k, PAD_KEY), jnp.where(first, out_v, 0)

    pk = part_keys.reshape(waves_r, Wp, cap)
    pv = part_vals.reshape(waves_r, Wp, cap)

    def wave(carry, inp):
        k, v = jax.vmap(one_reduce)(*inp)
        return carry, (k, v)

    _, (ok, ov) = jax.lax.scan(wave, jnp.int32(0), (pk, pv))
    out_keys = ok.reshape(R_pad, cap)[:R]
    out_vals = ov.reshape(R_pad, cap)[:R]
    return out_keys, out_vals, dropped


def build_job(app: MapReduceApp, cfg: JobConfig, input_len: int):
    """Compile a full MapReduce job for one (app, config, input size).

    Returns jitted ``job(tokens (input_len,) int32) ->
    (out_keys (R, C), out_vals (R, C), dropped ())``.
    """
    M, W = cfg.num_mappers, cfg.num_workers
    S = math.ceil(input_len / M)
    waves_m = cfg.map_waves
    M_pad = waves_m * W
    P = S * app.pairs_per_token

    def job(tokens):
        if tokens.shape != (input_len,):
            raise ValueError(
                f"expected ({input_len},), got {tokens.shape}"
            )
        pad_to = M_pad * S
        padded = jnp.full((pad_to,), 0, dtype=jnp.int32)
        padded = padded.at[:input_len].set(tokens)
        valid = (jnp.arange(pad_to) < input_len).reshape(waves_m, W, S)
        splits = padded.reshape(waves_m, W, S)
        keys, values, pvalid = _map_phase(app, cfg, splits, valid)
        n_pairs = waves_m * W * P
        return _partition_and_reduce(
            app,
            cfg,
            keys.reshape(n_pairs),
            values.reshape(n_pairs),
            pvalid.reshape(n_pairs),
        )

    return jax.jit(job)


# ---------------------------------------------------------------------------
# Sharded path: workers are devices on a mesh axis; shuffle is all_to_all.
# ---------------------------------------------------------------------------


def build_job_sharded(
    app: MapReduceApp, cfg: JobConfig, input_len: int, mesh: jax.sharding.Mesh,
    axis: str = "workers",
):
    """shard_map MapReduce: W = mesh axis size; shuffle = all_to_all.

    Each worker runs its map waves locally, locally combines+partitions by
    destination worker (reducer % W), exchanges partitions with a literal
    ``all_to_all``, then reduces the reducer tasks it owns.  This is the
    deployment path for real multi-chip meshes; semantics match `build_job`.
    """
    W = mesh.shape[axis]
    if cfg.num_workers != W:
        raise ValueError(f"cfg.num_workers={cfg.num_workers} != mesh {W}")
    M, R = cfg.num_mappers, cfg.num_reducers
    S = math.ceil(input_len / M)
    waves_m, waves_r = cfg.map_waves, cfg.reduce_waves
    M_pad = waves_m * W
    P = S * app.pairs_per_token
    n_local_pairs = waves_m * P
    # Per (src, dst) shuffle capacity: uniform share x safety factor.
    shuf_cap = max(1, int(math.ceil(n_local_pairs / W * cfg.capacity_factor)))
    shuf_cap = min(shuf_cap, n_local_pairs)
    red_cap = max(
        1, int(math.ceil(M_pad * P / max(R, 1) * cfg.capacity_factor))
    )

    def worker(splits, valid):  # (1(worker), waves, S) local shards
        splits = splits[0]
        valid = valid[0]

        def one_task(tokens, v):
            setup = _task_setup(cfg.setup_dim, cfg.setup_rounds, tokens.sum())
            keys, values, pvalid = app.map_fn(tokens, v)
            order = jnp.argsort(jnp.where(pvalid, keys, PAD_KEY))
            keys, values, pvalid = keys[order], values[order], pvalid[order]
            if cfg.combiner:
                keys, values, first = _segment_sum_sorted(
                    keys, values, pvalid, app.reduce_op
                )
                pvalid = first
            return keys, values + setup.astype(values.dtype), pvalid

        def wave(c, inp):
            k, v, pv = one_task(*inp)
            return c, (k, v, pv)

        _, (k, v, pv) = jax.lax.scan(wave, 0, (splits, valid))
        k, v, pv = k.reshape(-1), v.reshape(-1), pv.reshape(-1)
        # Partition local pairs by destination worker = rid % W.
        rid = jnp.where(pv, _hash_to_reducer(k, R), R)
        dst = jnp.where(pv, rid % W, W)
        order = jnp.lexsort((k, rid, dst))
        k, v, rid, dst = k[order], v[order], rid[order], dst[order]
        start = jnp.searchsorted(dst, jnp.arange(W + 1), side="left")
        pos = jnp.arange(k.shape[0]) - start[jnp.clip(dst, 0, W)]
        row = jnp.where((dst < W) & (pos < shuf_cap), dst, W)
        col = jnp.clip(pos, 0, shuf_cap - 1)
        send_k = jnp.full((W, shuf_cap), PAD_KEY, jnp.int32)
        send_v = jnp.zeros((W, shuf_cap), v.dtype)
        send_r = jnp.full((W, shuf_cap), R, jnp.int32)
        send_k = send_k.at[row, col].set(k, mode="drop")
        send_v = send_v.at[row, col].set(v, mode="drop")
        send_r = send_r.at[row, col].set(rid, mode="drop")
        # The shuffle: exchange partition i with worker i (tiled all_to_all:
        # row i of the (W, cap) send buffer goes to worker i, received rows
        # re-stack along the same axis).
        recv_k = jax.lax.all_to_all(send_k, axis, 0, 0, tiled=True)
        recv_v = jax.lax.all_to_all(send_v, axis, 0, 0, tiled=True)
        recv_r = jax.lax.all_to_all(send_r, axis, 0, 0, tiled=True)
        rk, rv, rr = (
            recv_k.reshape(-1), recv_v.reshape(-1), recv_r.reshape(-1)
        )
        # Bucket received pairs into this worker's reduce tasks
        # (local slot = rid // W, since reducer r lives on worker r % W).
        lslot = jnp.where(rr < R, rr // W, waves_r)
        order = jnp.lexsort((rk, lslot))
        rk, rv, lslot = rk[order], rv[order], lslot[order]
        start = jnp.searchsorted(lslot, jnp.arange(waves_r + 1), side="left")
        pos = jnp.arange(rk.shape[0]) - start[jnp.clip(lslot, 0, waves_r)]
        rrow = jnp.where((lslot < waves_r) & (pos < red_cap), lslot, waves_r)
        rcol = jnp.clip(pos, 0, red_cap - 1)
        bk = jnp.full((waves_r, red_cap), PAD_KEY, jnp.int32)
        bv = jnp.zeros((waves_r, red_cap), rv.dtype)
        bk = bk.at[rrow, rcol].set(rk, mode="drop")
        bv = bv.at[rrow, rcol].set(rv, mode="drop")
        dropped = jnp.sum((pos >= red_cap) & (lslot < waves_r))

        def one_reduce(c, inp):
            pkeys, pvals = inp
            setup = _task_setup(cfg.setup_dim, cfg.setup_rounds, pkeys.sum())
            vmask = pkeys != PAD_KEY
            ok, ov, first = _segment_sum_sorted(
                pkeys, pvals, vmask, app.reduce_op
            )
            ov = ov + setup.astype(ov.dtype)
            return c, (jnp.where(first, ok, PAD_KEY), jnp.where(first, ov, 0))

        _, (ok, ov) = jax.lax.scan(one_reduce, 0, (bk, bv))
        return ok[None], ov[None], dropped[None]

    from jax.sharding import PartitionSpec as P_

    spec_in = P_(axis, None, None)
    shard_fn = jax.shard_map(
        worker,
        mesh=mesh,
        in_specs=(spec_in, spec_in),
        out_specs=(P_(axis, None, None), P_(axis, None, None), P_(axis)),
    )

    def job(tokens):
        pad_to = M_pad * S
        padded = jnp.zeros((pad_to,), jnp.int32).at[:input_len].set(tokens)
        valid = (jnp.arange(pad_to) < input_len)
        # Worker-major task layout: worker w owns tasks w, w+W, ...
        splits = padded.reshape(waves_m, W, S).transpose(1, 0, 2)
        vsplit = valid.reshape(waves_m, W, S).transpose(1, 0, 2)
        ok, ov, dropped = shard_fn(splits, vsplit)
        return ok, ov, dropped.sum()

    return jax.jit(job)


def collect_results(out_keys, out_vals) -> dict[int, int]:
    """Gather (key -> aggregated value) from job output, host-side."""
    out_keys = np.asarray(out_keys).ravel()
    out_vals = np.asarray(out_vals).ravel()
    mask = out_keys != int(PAD_KEY)
    result: dict[int, int] = {}
    for k, v in zip(out_keys[mask], out_vals[mask]):
        result[int(k)] = result.get(int(k), 0) + int(v)
    return result
