"""TPU-native MapReduce engine (the paper's workload substrate).

Hadoop concepts are mapped onto JAX/XLA idioms rather than emulated:

* **map/reduce TASKS vs worker SLOTS** — M map tasks and R reduce tasks are
  scheduled over W parallel workers in ``ceil(M/W)`` / ``ceil(R/W)`` *waves*
  (wave steppers under ``fori_loop``/``jit``, ``vmap``/``shard_map`` over
  workers).  This is Hadoop's slot scheduling, and it is exactly why total
  execution time depends non-trivially and non-monotonically on (M, R) —
  the dependency the paper models.
* **per-task startup overhead** — Hadoop pays JVM/task-setup seconds per
  task; our analogue is a fixed per-task setup compute (``setup_rounds`` of a
  small matmul chain) inside each wave, plus each map task's local spill sort.
* **shuffle** — key-hash partitioning to reducers, via a pluggable
  :class:`~repro.mapreduce.backends.ShuffleBackend`: ``"lexsort"`` is a
  global sort by (reducer, key) + capacity-bounded scatter (Hadoop's fixed
  spill/partition buffers); ``"all_to_all"`` is a literal mesh collective
  used by the sharded path.
* **reduce** — per-reducer sorted segment aggregation, wave-scheduled like
  the map phase, through a pluggable
  :class:`~repro.mapreduce.backends.ReduceBackend` (``"jnp"``, ``"pallas"``,
  or ``"xla"``).

This module is deliberately thin: the shared phase primitives live in
:mod:`repro.mapreduce.phases`, the swappable strategies in
:mod:`repro.mapreduce.backends`, and the **single lowering** of the
pipeline in :mod:`repro.mapreduce.plan` — ``build_job`` /
``build_job_sharded`` only select a mode of one
:class:`~repro.mapreduce.plan.ExecutionPlan` (fused / traced / sharded;
the elastic layer's resumable mode derives from the same plan), so every
profiled path executes the same canonical wave steppers by construction.

Shapes are static per (M, R, W, L) configuration — one compile per config,
wall-clocked post-warmup, which mirrors "job execution time" in the paper
(their clusters also pay a fixed job-setup cost they do not model).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import numpy as np

from repro.mapreduce import backends as _backends
from repro.mapreduce.phases import PAD_KEY
from repro.mapreduce.plan import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class JobConfig:
    """One MapReduce experiment configuration (the paper's parameter set)."""

    num_mappers: int            # M: map tasks       (paper parameter 1)
    num_reducers: int           # R: reduce tasks    (paper parameter 2)
    num_workers: int = 1        # W: parallel worker slots (cluster size)
    combiner: bool = False      # map-side combine stage between map and
    #                             shuffle (extra modeled knob): pre-aggregate
    #                             each task's pairs, contracting shuffle
    #                             bytes; requires a commutative+associative
    #                             reduce_op (COMBINABLE_OPS — the plan
    #                             rejects e.g. "first")
    capacity_factor: float = 4.0  # reducer partition capacity multiplier
    setup_rounds: int = 4       # per-task startup overhead (matmul rounds)
    setup_dim: int = 32         # startup compute size
    reduce_backend: str = "jnp"     # categorical knob: "jnp"|"pallas"|"xla"
    shuffle_backend: str = "lexsort"  # "lexsort"|"all_to_all"
    overlap_depth: int = 1          # software-pipeline depth (1 = serial)

    def __post_init__(self):
        if self.num_mappers < 1 or self.num_reducers < 1 or self.num_workers < 1:
            raise ValueError(f"bad config {self}")
        if self.overlap_depth < 1:
            raise ValueError(
                f"overlap_depth must be >= 1, got {self.overlap_depth}"
            )
        _backends.get_reduce_backend(self.reduce_backend)
        _backends.get_shuffle_backend(self.shuffle_backend)

    @property
    def map_waves(self) -> int:
        return math.ceil(self.num_mappers / self.num_workers)

    @property
    def reduce_waves(self) -> int:
        return math.ceil(self.num_reducers / self.num_workers)


@dataclasses.dataclass(frozen=True)
class MapReduceApp:
    """A MapReduce application: map emits (key, value) pairs; reduce
    aggregates values per key with ``reduce_op``.  ``sum`` and ``max`` are
    commutative+associative and therefore combiner-eligible; ``first``
    (keep the earliest value per key in delivery order) is order-dependent
    and only legal with the combiner off.
    """

    name: str
    key_space: int
    # map_fn(tokens (S,), valid (S,)) -> keys (P,), values (P,), valid (P,)
    map_fn: Callable
    pairs_per_token: int = 1
    reduce_op: str = "sum"  # "sum" | "max" | "first"


def build_job(app: MapReduceApp, cfg: JobConfig, input_len: int,
              mesh: jax.sharding.Mesh | None = None, axis: str = "workers",
              recorder=None):
    """Compile a full MapReduce job for one (app, config, input size).

    Returns jitted ``job(tokens (input_len,) int32) ->
    (out_keys (R, C), out_vals (R, C), dropped ())``.

    ``cfg.shuffle_backend`` selects the execution strategy: a collective
    backend ("all_to_all") requires ``mesh`` and routes through
    :func:`build_job_sharded`; the default "lexsort" backend compiles the
    single-controller pipeline.

    ``recorder`` (optional) enables per-phase telemetry: any object with
    the :class:`repro.telemetry.PhaseRecorder` protocol
    (``start_job(app_name, cfg, input_len) -> trace`` where the trace has
    ``record_phase(name, wall_s, **counters)`` / ``finish(total_s)``).
    With a recorder the phases compile separately (fenced and
    wall-clocked — on the sharded path too, as separate mesh programs)
    and each call of the returned job appends one trace; with
    ``recorder=None`` (default) the fused single-program mode compiles —
    telemetry off costs nothing.
    """
    shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
    if shuffle.collective:
        if mesh is None:
            raise ValueError(
                f"shuffle backend {shuffle.name!r} is a mesh collective; "
                "pass mesh= (or call build_job_sharded)"
            )
        return build_job_sharded(
            app, cfg, input_len, mesh, axis, recorder=recorder
        )
    if mesh is not None:
        raise ValueError(
            f"mesh given but shuffle backend {shuffle.name!r} is "
            "single-controller; use shuffle_backend=\"all_to_all\" for a "
            "distributed job"
        )
    plan = ExecutionPlan(app, cfg, input_len)
    if recorder is not None:
        return plan.traced(recorder)
    if cfg.overlap_depth > 1:
        return plan.pipelined()
    return plan.fused()


def build_job_sharded(
    app: MapReduceApp, cfg: JobConfig, input_len: int, mesh: jax.sharding.Mesh,
    axis: str = "workers", counters: bool = False, recorder=None,
):
    """shard_map MapReduce: W = mesh axis size; shuffle = all_to_all.

    A thin wrapper over :meth:`ExecutionPlan.sharded` — the same wave
    steppers as every other mode, wrapped in ``shard_map``.  This is the
    deployment path for real multi-chip meshes; semantics match
    :func:`build_job`.

    With ``counters=True`` the returned job yields ``(out_keys, out_vals,
    dropped, stats)`` where ``stats`` reduces the per-worker overflow
    counters across shards into true per-phase totals::

        stats = {
            "dropped_send": int,   # shuffle send-buffer overflow, all workers
            "dropped_recv": int,   # reduce-bucket overflow, all workers
            "dropped_per_worker": (W, 2) ndarray,  # [send, recv] per worker
        }

    With ``recorder=`` the phases compile as separate mesh programs and
    every call appends a per-phase :class:`~repro.telemetry.JobTrace` —
    per-phase *wall times* on the sharded path.
    """
    plan = ExecutionPlan(app, cfg, input_len)
    return plan.sharded(mesh, axis, counters=counters, recorder=recorder)


def collect_results(out_keys, out_vals) -> dict[int, int]:
    """Gather (key -> aggregated value) from job output, host-side."""
    out_keys = np.asarray(out_keys).ravel()
    out_vals = np.asarray(out_vals).ravel()
    mask = out_keys != int(PAD_KEY)
    result: dict[int, int] = {}
    for k, v in zip(out_keys[mask], out_vals[mask]):
        result[int(k)] = result.get(int(k), 0) + int(v)
    return result
