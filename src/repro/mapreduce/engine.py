"""TPU-native MapReduce engine (the paper's workload substrate).

Hadoop concepts are mapped onto JAX/XLA idioms rather than emulated:

* **map/reduce TASKS vs worker SLOTS** — M map tasks and R reduce tasks are
  scheduled over W parallel workers in ``ceil(M/W)`` / ``ceil(R/W)`` *waves*
  (`lax.scan` over waves, `vmap`/`shard_map` over workers).  This is Hadoop's
  slot scheduling, and it is exactly why total execution time depends
  non-trivially and non-monotonically on (M, R) — the dependency the paper
  models.
* **per-task startup overhead** — Hadoop pays JVM/task-setup seconds per
  task; our analogue is a fixed per-task setup compute (``setup_rounds`` of a
  small matmul chain) inside each wave, plus each map task's local spill sort.
* **shuffle** — key-hash partitioning to reducers, via a pluggable
  :class:`~repro.mapreduce.backends.ShuffleBackend`: ``"lexsort"`` is a
  global sort by (reducer, key) + capacity-bounded scatter (Hadoop's fixed
  spill/partition buffers); ``"all_to_all"`` is a literal mesh collective
  used by the sharded path.
* **reduce** — per-reducer sorted segment aggregation, wave-scheduled like
  the map phase, through a pluggable
  :class:`~repro.mapreduce.backends.ReduceBackend` (``"jnp"``, ``"pallas"``,
  or ``"xla"``).

This module is deliberately thin: the single shared implementation of each
phase lives in :mod:`repro.mapreduce.phases`, the swappable strategies in
:mod:`repro.mapreduce.backends`; ``build_job`` / ``build_job_sharded`` only
compose them.  The backend choice is thereby one more modelable
configuration axis, alongside (M, R, W).

Shapes are static per (M, R, W, L) configuration — one compile per config,
wall-clocked post-warmup, which mirrors "job execution time" in the paper
(their clusters also pay a fixed job-setup cost they do not model).
"""

from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import backends as _backends
from repro.mapreduce import phases
from repro.mapreduce.phases import PAD_KEY, map_phase, reduce_local, reduce_phase

from repro.compat import shard_map as _shard_map

@dataclasses.dataclass(frozen=True)
class JobConfig:
    """One MapReduce experiment configuration (the paper's parameter set)."""

    num_mappers: int            # M: map tasks       (paper parameter 1)
    num_reducers: int           # R: reduce tasks    (paper parameter 2)
    num_workers: int = 1        # W: parallel worker slots (cluster size)
    combiner: bool = False      # map-side combine (extra modeled knob)
    capacity_factor: float = 4.0  # reducer partition capacity multiplier
    setup_rounds: int = 4       # per-task startup overhead (matmul rounds)
    setup_dim: int = 32         # startup compute size
    reduce_backend: str = "jnp"     # categorical knob: "jnp"|"pallas"|"xla"
    shuffle_backend: str = "lexsort"  # "lexsort"|"all_to_all"

    def __post_init__(self):
        if self.num_mappers < 1 or self.num_reducers < 1 or self.num_workers < 1:
            raise ValueError(f"bad config {self}")
        _backends.get_reduce_backend(self.reduce_backend)
        _backends.get_shuffle_backend(self.shuffle_backend)

    @property
    def map_waves(self) -> int:
        return math.ceil(self.num_mappers / self.num_workers)

    @property
    def reduce_waves(self) -> int:
        return math.ceil(self.num_reducers / self.num_workers)


@dataclasses.dataclass(frozen=True)
class MapReduceApp:
    """A MapReduce application: map emits (key, value) pairs; reduce
    aggregates values per key with ``reduce_op`` (associative, commutative).
    """

    name: str
    key_space: int
    # map_fn(tokens (S,), valid (S,)) -> keys (P,), values (P,), valid (P,)
    map_fn: Callable
    pairs_per_token: int = 1
    reduce_op: str = "sum"  # "sum" | "max"


def _resolve_reduce_backend(app: MapReduceApp, cfg: JobConfig):
    backend = _backends.get_reduce_backend(cfg.reduce_backend)
    if app.reduce_op not in backend.supported_ops:
        raise ValueError(
            f"reduce backend {backend.name!r} supports "
            f"{backend.supported_ops}, but app {app.name!r} needs "
            f"{app.reduce_op!r}"
        )
    return backend


def build_stage_fns(app: MapReduceApp, cfg: JobConfig, input_len: int):
    """The single-controller pipeline as separately-composable stage fns.

    Returns ``(stages, meta)`` where ``stages`` maps phase name -> pure
    function (``map``: tokens -> flat (keys, values, pvalid); ``shuffle``:
    those -> (part_keys, part_vals, dropped); ``reduce``: partitions ->
    (out_keys (R, C), out_vals (R, C))) and ``meta`` carries the static
    shape facts telemetry and the cost estimator need (task/wave counts,
    pair counts, partition capacity).

    ``build_job`` composes the stages under one ``jit`` (the fused hot
    path); the traced path jits each stage separately so phases can be
    fenced and wall-clocked; ``telemetry.estimator`` lowers each stage to
    read XLA's flops/bytes cost analysis per phase.
    """
    shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
    if shuffle.collective:
        raise ValueError(
            f"stage decomposition needs a single-controller shuffle; "
            f"{shuffle.name!r} is a mesh collective"
        )
    reduce_backend = _resolve_reduce_backend(app, cfg)

    M, R, W = cfg.num_mappers, cfg.num_reducers, cfg.num_workers
    S = math.ceil(input_len / M)
    waves_m = cfg.map_waves
    M_pad = waves_m * W
    P = S * app.pairs_per_token
    n_pairs = M_pad * P

    def stage_map(tokens):
        if tokens.shape != (input_len,):
            raise ValueError(
                f"expected ({input_len},), got {tokens.shape}"
            )
        pad_to = M_pad * S
        padded = jnp.full((pad_to,), 0, dtype=jnp.int32)
        padded = padded.at[:input_len].set(tokens)
        valid = (jnp.arange(pad_to) < input_len).reshape(waves_m, W, S)
        splits = padded.reshape(waves_m, W, S)
        keys, values, pvalid = map_phase(app, cfg, splits, valid)
        return (
            keys.reshape(n_pairs),
            values.reshape(n_pairs),
            pvalid.reshape(n_pairs),
        )

    def stage_shuffle(keys, values, pvalid):
        return shuffle.partition(cfg, keys, values, pvalid)

    def stage_reduce(part_keys, part_vals):
        out_keys, out_vals = reduce_phase(
            app, cfg, part_keys, part_vals, reduce_backend
        )
        return out_keys[:R], out_vals[:R]

    meta = {
        "input_len": input_len,
        "mappers": M,
        "reducers": R,
        "workers": W,
        "split_size": S,
        "map_waves": waves_m,
        "reduce_waves": cfg.reduce_waves,
        "n_pairs": n_pairs,
        "partition_capacity": shuffle.capacity_for(cfg, n_pairs),
        "r_pad": cfg.reduce_waves * W,
    }
    stages = {
        "map": stage_map,
        "shuffle": stage_shuffle,
        "reduce": stage_reduce,
    }
    return stages, meta


def build_job(app: MapReduceApp, cfg: JobConfig, input_len: int,
              mesh: jax.sharding.Mesh | None = None, axis: str = "workers",
              recorder=None):
    """Compile a full MapReduce job for one (app, config, input size).

    Returns jitted ``job(tokens (input_len,) int32) ->
    (out_keys (R, C), out_vals (R, C), dropped ())``.

    ``cfg.shuffle_backend`` selects the execution strategy: a collective
    backend ("all_to_all") requires ``mesh`` and routes through
    :func:`build_job_sharded`; the default "lexsort" backend compiles the
    single-controller pipeline below.

    ``recorder`` (optional) enables per-phase telemetry: any object with
    the :class:`repro.telemetry.PhaseRecorder` protocol
    (``start_job(app_name, cfg, input_len) -> trace`` where the trace has
    ``record_phase(name, wall_s, **counters)`` / ``finish(total_s)``).
    With a recorder the phases are jitted separately and each call of the
    returned job appends one trace; with ``recorder=None`` (default) the
    fused single-``jit`` path compiles — telemetry off costs nothing.
    """
    shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
    if shuffle.collective:
        if recorder is not None:
            raise ValueError(
                "per-phase wall-clock telemetry is single-controller only; "
                "for the sharded path use build_job_sharded(counters=True) "
                "to get cross-shard-reduced per-phase dropped counters"
            )
        if mesh is None:
            raise ValueError(
                f"shuffle backend {shuffle.name!r} is a mesh collective; "
                "pass mesh= (or call build_job_sharded)"
            )
        return build_job_sharded(app, cfg, input_len, mesh, axis)
    if mesh is not None:
        raise ValueError(
            f"mesh given but shuffle backend {shuffle.name!r} is "
            "single-controller; use shuffle_backend=\"all_to_all\" for a "
            "distributed job"
        )
    stages, meta = build_stage_fns(app, cfg, input_len)
    if recorder is not None:
        return _build_job_traced(app, cfg, stages, meta, recorder)

    def job(tokens):
        keys, values, pvalid = stages["map"](tokens)
        part_keys, part_vals, dropped = stages["shuffle"](
            keys, values, pvalid
        )
        out_keys, out_vals = stages["reduce"](part_keys, part_vals)
        return out_keys, out_vals, dropped

    return jax.jit(job)


def _build_job_traced(app, cfg, stages, meta, recorder):
    """Phase-fenced execution: jit each stage, wall-clock + count each phase.

    Counters are measured from the actual stage outputs (host-side numpy
    reductions), so conservation laws are checkable invariants rather than
    config-derived tautologies.  See ``repro.telemetry.trace``.
    """
    jit_map = jax.jit(stages["map"])
    jit_shuffle = jax.jit(stages["shuffle"])
    jit_reduce = jax.jit(stages["reduce"])
    pair_bytes = phases.PAIR_BYTES

    def job(tokens):
        trace = recorder.start_job(app.name, cfg, meta["input_len"])
        try:
            return _run(tokens, trace)
        except Exception:
            # A failed run must not leave a phantom/partial trace for
            # recorder.last / take_trace consumers to misread as complete.
            if trace in recorder.traces:
                recorder.traces.remove(trace)
            raise

    def _run(tokens, trace):
        t_job = _time.perf_counter()

        t0 = _time.perf_counter()
        keys, values, pvalid = jax.block_until_ready(jit_map(tokens))
        dt = _time.perf_counter() - t0
        pairs_emitted = int(np.asarray(pvalid).sum())
        trace.record_phase(
            "map", dt,
            tasks=meta["mappers"], waves=meta["map_waves"],
            records_in=meta["input_len"],
            pairs_emitted=pairs_emitted, pairs_capacity=meta["n_pairs"],
        )

        t0 = _time.perf_counter()
        part_keys, part_vals, dropped = jax.block_until_ready(
            jit_shuffle(keys, values, pvalid)
        )
        dt = _time.perf_counter() - t0
        n_dropped = int(dropped)
        pairs_out = int((np.asarray(part_keys) != int(PAD_KEY)).sum())
        trace.record_phase(
            "shuffle", dt,
            pairs_in=pairs_emitted, pairs_out=pairs_out,
            pairs_dropped=n_dropped,
            bytes_in=pairs_emitted * pair_bytes,
            bytes_out=pairs_out * pair_bytes,
            bytes_dropped=n_dropped * pair_bytes,
            partitions=meta["reducers"],
            partition_capacity=meta["partition_capacity"],
        )

        t0 = _time.perf_counter()
        out_keys, out_vals = jax.block_until_ready(
            jit_reduce(part_keys, part_vals)
        )
        dt = _time.perf_counter() - t0
        segments = int((np.asarray(out_keys) != int(PAD_KEY)).sum())
        trace.record_phase(
            "reduce", dt,
            tasks=meta["reducers"], waves=meta["reduce_waves"],
            segments_out=segments,
            segment_slots=meta["r_pad"] * meta["partition_capacity"],
        )

        trace.finish(_time.perf_counter() - t_job)
        return out_keys, out_vals, dropped

    return job


# ---------------------------------------------------------------------------
# Sharded path: workers are devices on a mesh axis; shuffle is all_to_all.
# ---------------------------------------------------------------------------


def build_job_sharded(
    app: MapReduceApp, cfg: JobConfig, input_len: int, mesh: jax.sharding.Mesh,
    axis: str = "workers", counters: bool = False,
):
    """shard_map MapReduce: W = mesh axis size; shuffle = all_to_all.

    Each worker runs its map waves locally (the same
    :func:`~repro.mapreduce.phases.map_phase` as the single-controller
    path, with a local worker axis of 1), exchanges partitions through the
    ``all_to_all`` shuffle backend, then reduces the reducer tasks it owns
    through ``cfg.reduce_backend``.  This is the deployment path for real
    multi-chip meshes; semantics match `build_job`.

    With ``counters=True`` the returned job yields ``(out_keys, out_vals,
    dropped, stats)`` where ``stats`` reduces the per-worker overflow
    counters across shards into true per-phase totals (the telemetry the
    single-controller traced path measures, which the fused ``shard_map``
    program otherwise collapses to one aggregate)::

        stats = {
            "dropped_send": int,   # shuffle send-buffer overflow, all workers
            "dropped_recv": int,   # reduce-bucket overflow, all workers
            "dropped_per_worker": (W, 2) ndarray,  # [send, recv] per worker
        }
    """
    W = mesh.shape[axis]
    if cfg.num_workers != W:
        raise ValueError(f"cfg.num_workers={cfg.num_workers} != mesh {W}")
    reduce_backend = _resolve_reduce_backend(app, cfg)
    shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
    if not shuffle.collective:
        # Direct build_job_sharded call with a non-collective config: the
        # sharded path's structural shuffle is the mesh collective.
        shuffle = _backends.SHUFFLE_BACKENDS["all_to_all"]

    M, R = cfg.num_mappers, cfg.num_reducers
    S = math.ceil(input_len / M)
    waves_m = cfg.map_waves
    M_pad = waves_m * W
    P = S * app.pairs_per_token
    n_local_pairs = waves_m * P

    def worker(splits, valid):  # (1(worker), waves, S) local shards
        # Local map waves: reuse the shared map phase with W_local = 1.
        splits = splits[0][:, None, :]   # (waves, 1, S)
        valid = valid[0][:, None, :]
        k, v, pv = map_phase(app, cfg, splits, valid)
        k = k.reshape(n_local_pairs)
        v = v.reshape(n_local_pairs)
        pv = pv.reshape(n_local_pairs)
        bk, bv, dropped = shuffle.exchange(cfg, axis, k, v, pv)
        ok, ov = reduce_local(app, cfg, bk, bv, reduce_backend)
        return ok[None], ov[None], dropped[None]

    from jax.sharding import PartitionSpec as P_

    spec_in = P_(axis, None, None)
    shard_fn = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(spec_in, spec_in),
        out_specs=(
            P_(axis, None, None), P_(axis, None, None), P_(axis, None),
        ),
        # pallas_call has no replication rule; every output is axis-sharded
        # anyway, so the check adds nothing here.
        check=False,
    )

    def job(tokens):
        pad_to = M_pad * S
        padded = jnp.zeros((pad_to,), jnp.int32).at[:input_len].set(tokens)
        valid = (jnp.arange(pad_to) < input_len)
        # Worker-major task layout: worker w owns tasks w, w+W, ...
        splits = padded.reshape(waves_m, W, S).transpose(1, 0, 2)
        vsplit = valid.reshape(waves_m, W, S).transpose(1, 0, 2)
        ok, ov, dropped = shard_fn(splits, vsplit)
        # (W, waves_r, cap) -> (R, cap) indexed by reducer id: reducer r
        # lives on worker r % W at local slot r // W, so row r of the
        # slot-major stacking is exactly reducer r's partition.
        ok = ok.transpose(1, 0, 2).reshape(-1, ok.shape[-1])[:R]
        ov = ov.transpose(1, 0, 2).reshape(-1, ov.shape[-1])[:R]
        # dropped: (W, 2) per-worker [send, recv] overflow counters.
        return ok, ov, dropped

    jitted = jax.jit(job)

    if not counters:
        def plain(tokens):
            ok, ov, dropped = jitted(tokens)
            return ok, ov, dropped.sum()
        return plain

    def with_counters(tokens):
        ok, ov, dropped = jitted(tokens)
        per_worker = np.asarray(dropped)
        stats = {
            "dropped_send": int(per_worker[:, 0].sum()),
            "dropped_recv": int(per_worker[:, 1].sum()),
            "dropped_per_worker": per_worker,
        }
        return ok, ov, dropped.sum(), stats

    return with_counters


def collect_results(out_keys, out_vals) -> dict[int, int]:
    """Gather (key -> aggregated value) from job output, host-side."""
    out_keys = np.asarray(out_keys).ravel()
    out_vals = np.asarray(out_vals).ravel()
    mask = out_keys != int(PAD_KEY)
    result: dict[int, int] = {}
    for k, v in zip(out_keys[mask], out_vals[mask]):
        result[int(k)] = result.get(int(k), 0) + int(v)
    return result
