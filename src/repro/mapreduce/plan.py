"""One wave-stepper execution core: every execution mode is a derivation.

The engine used to carry four hand-rolled lowerings of the same
map → shuffle → reduce pipeline: the fused ``build_job`` composition, the
recorder-fenced traced path, the ``shard_map``-fused sharded path, and
``ResumableJob``'s per-grant wave steppers.  Each could silently drift
from the others — and every drifted path is a profiled path whose time
the paper's models would mis-attribute.

:class:`ExecutionPlan` lowers one ``(MapReduceApp, JobConfig,
input_len)`` into a single canonical stepper set over **task-major
buffers**, and every entry point is a *mode* over that one plan:

* :meth:`fused`     — ``fori_loop`` over the steppers under one ``jit``:
  the zero-overhead hot path (``build_job``'s default);
* :meth:`traced`    — the same stepper loops jitted per phase, fenced and
  wall-clocked, feeding a :class:`repro.telemetry.PhaseRecorder`;
* :meth:`pipelined` — the fused pipeline with map/reduce waves
  software-pipelined at ``cfg.overlap_depth``: wave group g's compute
  overlaps group g-1's commit in one loop carry (prologue / steady
  state / epilogue), bit-exact vs fused by construction;
* :meth:`sharded`   — ``shard_map`` around the same phase primitives
  (workers = mesh axis, shuffle = literal ``all_to_all``); with a
  recorder the phases compile as *separate* mesh programs, which is what
  finally makes per-phase wall times possible on the sharded path;
* :meth:`resumable` — the raw steppers jitted per grant for
  :class:`repro.elastic.resumable.ResumableJob`'s wave-boundary
  stop/snapshot/regrant/resume loop.

The canonical stepper contract (all shapes static per plan):

* ``prep(tokens)``                        → ``(splits (M, S), valid (M, S))``
* ``map_step(W)(splits, valid, bk, bv, bp, start)``
                                          → updated ``(M, P)`` accumulators
* ``combine_step()(bk, bv, bp)``          → compacted ``(M, Pc)`` task rows
  (only when ``cfg.combiner``): per-task local segment-reduce +
  front-packing through the reduce backend's ``combine``, with
  ``Pc = min(P, key_space)`` — the static distinct-key bound — so every
  downstream capacity shrinks with the combined stream;
* ``shuffle_step(W)(bk, bv, bp)``         → ``(pk, pv, dropped, ok0, ov0)``
  with partitions ``(R, cap)``; the ``lexsort`` backend uses the
  *canonical* W-independent capacity ``partition_capacity(M·P, R, f)``,
  the ``all_to_all`` backend the capacity layout of a real W-device run
  (its pack/unpack halves vmapped over a worker axis, the collective
  replaced by the block transpose it implements);
* ``reduce_step(W)(pk, pv, ok, ov, start)`` → updated ``(R, cap)`` outputs.

A map task's output depends only on its split and the frozen config —
never on W or on which wave (or mode) ran it — and all buffers are
task-major with exactly M (or R) live rows, so bit-exactness across
modes is a property of construction, checked once by the equivalence
suite in ``tests/test_plan.py`` instead of once per hand-rolled path.
"""

from __future__ import annotations

import dataclasses
import math
import os as _os
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map as _shard_map
from repro.mapreduce import backends as _backends
from repro.mapreduce import phases
from repro.mapreduce.phases import PAD_KEY, map_phase, reduce_local, \
    run_map_task

__all__ = ["ExecutionPlan"]


# Parallelism ceiling recorded with every process-CPU-clock sample: the
# runtime (XLA) is free to use every host core inside one fenced phase,
# so the trace's CPU conservation law is cpu_s <= wall_s * cpu_workers.
_NCPU = float(_os.cpu_count() or 1)


def _pad_rows(arr, n_extra: int, fill):
    """Append ``n_extra`` fill-rows so dynamic W-row windows never clamp."""
    if n_extra == 0:
        return arr
    pad = jnp.full((n_extra,) + arr.shape[1:], fill, dtype=arr.dtype)
    return jnp.concatenate([arr, pad], axis=0)


class ExecutionPlan:
    """One (app, config, input size), lowered once; modes derive from it.

    ``cfg.num_workers`` is the *default* grant (the one :meth:`fused`,
    :meth:`traced`, and :meth:`meta` use); steppers are built per grant on
    demand and cached, which is what lets the resumable mode re-plan the
    remaining waves under a different W mid-flight.
    """

    def __init__(self, app, cfg, input_len: int):
        self.app = app
        self.cfg = cfg
        self.input_len = int(input_len)
        self.reduce_backend = _backends.get_reduce_backend(cfg.reduce_backend)
        if app.reduce_op not in self.reduce_backend.supported_ops:
            raise ValueError(
                f"reduce backend {self.reduce_backend.name!r} supports "
                f"{self.reduce_backend.supported_ops}, but app "
                f"{app.name!r} needs {app.reduce_op!r}"
            )
        self.shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
        self.combiner = bool(getattr(cfg, "combiner", False))
        if self.combiner and app.reduce_op not in phases.COMBINABLE_OPS:
            raise ValueError(
                f"combiner requires a commutative+associative reduce op "
                f"{phases.COMBINABLE_OPS}, but app {app.name!r} uses "
                f"{app.reduce_op!r}"
            )
        self.M = cfg.num_mappers
        self.R = cfg.num_reducers
        self.S = math.ceil(self.input_len / self.M)
        self.P = self.S * app.pairs_per_token
        #: combined per-task row width (static distinct-key bound)
        self.combine_cap = phases.combine_capacity(self.P, app.key_space)
        #: column width of the task rows entering the shuffle barrier
        self.shuffle_width = self.combine_cap if self.combiner else self.P
        #: canonical (W-independent) lexsort partition capacity — sized
        #: from the *combined* stream when the combiner is on, so the
        #: byte contraction propagates into the partition buffers too
        self.lex_capacity = phases.partition_capacity(
            self.M * self.shuffle_width, self.R, cfg.capacity_factor
        )
        # Per-grant jitted stepper caches (shared by every mode and every
        # ResumableJob derived from this plan).  Keys are canonicalized:
        # any grant W >= M (or R) compiles the same stepper as W == M, so
        # re-planning after a regrant to an equivalent grant is a cache
        # hit, not a re-trace.  Every key carries the combiner flag —
        # combined and uncombined grants must never share a jitted trace
        # (their buffer widths differ).
        self._jit_prep = None
        self._jit_map: dict[tuple[int, bool], callable] = {}
        self._jit_combine = None
        self._jit_shuffle: dict[tuple[int, bool], callable] = {}
        self._jit_reduce: dict[tuple[int, int, bool], callable] = {}
        self._jit_pipelined: dict[tuple[int, int, bool], callable] = {}
        self._cache_hits = 0
        self._cache_misses = 0

    # ------------------------------------------------------------- metadata

    def partition_cap(self, workers: int | None = None) -> int:
        """Partition capacity the shuffle barrier will allocate at a grant
        (lexsort: canonical, W-free; all_to_all: the W-shaped layout)."""
        if not self.shuffle.collective:
            return self.lex_capacity
        W = self.cfg.num_workers if workers is None else int(workers)
        cfg_w = dataclasses.replace(self.cfg, num_workers=W)
        n_local = cfg_w.map_waves * self.shuffle_width
        return phases.partition_capacity(
            W * n_local, self.R, self.cfg.capacity_factor
        )

    def meta(self, workers: int | None = None) -> dict:
        """Static shape facts telemetry and the cost estimator need."""
        W = self.cfg.num_workers if workers is None else int(workers)
        return {
            "input_len": self.input_len,
            "mappers": self.M,
            "reducers": self.R,
            "workers": W,
            "split_size": self.S,
            "map_waves": math.ceil(self.M / W),
            "reduce_waves": math.ceil(self.R / W),
            "n_pairs": self.M * self.P,
            "combiner": self.combiner,
            "combine_capacity": self.combine_cap,
            "shuffle_width": self.shuffle_width,
            "partition_capacity": self.partition_cap(W),
            "r_pad": self.R,
            "overlap_depth": getattr(self.cfg, "overlap_depth", 1),
        }

    # ------------------------------------------------- raw stepper builders

    def _prep_fn(self):
        M, S, input_len = self.M, self.S, self.input_len

        def prep(tokens):
            if tokens.shape != (input_len,):
                raise ValueError(
                    f"expected ({input_len},), got {tokens.shape}"
                )
            pad_to = M * S
            padded = jnp.zeros((pad_to,), jnp.int32).at[:input_len].set(
                tokens
            )
            valid = (jnp.arange(pad_to) < input_len).reshape(M, S)
            return padded.reshape(M, S), valid

        return prep

    def initial_map_buffers(self):
        M, P = self.M, self.P
        return (
            jnp.full((M, P), PAD_KEY, jnp.int32),
            jnp.zeros((M, P), jnp.int32),
            jnp.zeros((M, P), bool),
        )

    def initial_reduce_buffers(self, cap: int):
        R = self.R
        return (
            jnp.full((R, cap), PAD_KEY, jnp.int32),
            jnp.zeros((R, cap), jnp.int32),
        )

    def _map_step_fn(self, W: int):
        # Padding is only needed when the grant exceeds the task count
        # (slice size must fit the array).  For a final *partial* wave,
        # XLA clamps the dynamic start so the W-row window shifts onto
        # already-processed rows — which recompute bit-identically (map
        # tasks are deterministic and row-independent), so the in-place
        # window needs no per-wave pad/copy of the (M, P) carries.
        app, cfg, M = self.app, self.cfg, self.M
        pad = max(0, W - M)

        def step(splits, svalid, bk, bv, bp, start):
            tok = jax.lax.dynamic_slice_in_dim(
                _pad_rows(splits, pad, 0), start, W, 0
            )
            val = jax.lax.dynamic_slice_in_dim(
                _pad_rows(svalid, pad, False), start, W, 0
            )
            k, v, pv = jax.vmap(
                lambda t, m: run_map_task(app, cfg, t, m)
            )(tok, val)

            def upd(buf, blk, fill):
                return jax.lax.dynamic_update_slice_in_dim(
                    _pad_rows(buf, pad, fill), blk, start, 0
                )[:M]

            return upd(bk, k, PAD_KEY), upd(bv, v, 0), upd(bp, pv, False)

        return step

    def _combine_step_fn(self):
        """Map-side combine barrier: aggregate + compact every task row.

        W-independent like the lexsort barrier — combining is per-row, so
        one batched backend call covers all M tasks regardless of the
        grant held when the barrier executes (bit-exact under regrants by
        construction).
        """
        backend, op = self.reduce_backend, self.app.reduce_op
        cap = self.combine_cap

        def step(bk, bv, bp):
            return phases.combine_rows(backend, bk, bv, bp, op, cap)

        return step

    def _shuffle_step_fn(self, W: int):
        if self.shuffle.collective:
            return self._a2a_shuffle_fn(W)
        return self._lexsort_shuffle_fn()

    def _lexsort_shuffle_fn(self):
        """Canonical single-controller shuffle: W-independent capacity.

        Reuses :meth:`LexsortShuffle.partition` with a W=1 view of the
        config so its ``reduce_waves * W`` row padding degenerates to
        exactly R rows — the canonical partition block.
        """
        cfg_w1 = dataclasses.replace(self.cfg, num_workers=1)
        shuffle, R = self.shuffle, self.R
        init_out = self.initial_reduce_buffers

        def step(bk, bv, bp):
            n = bk.shape[0] * bk.shape[1]
            pk, pv, dropped = shuffle.partition(
                cfg_w1, bk.reshape(n), bv.reshape(n), bp.reshape(n)
            )
            ok, ov = init_out(pk.shape[1])
            return pk, pv, dropped, ok, ov

        return step

    def _a2a_shuffle_fn(self, W: int):
        """The collective shuffle, single-controller: vmap pack/unpack
        over a worker axis, block-transpose in place of ``all_to_all``.

        Reproduces the per-worker computation (and capacity layout) of a
        real W-device :meth:`sharded` run at the grant held when the
        barrier executes.
        """
        cfg_w = dataclasses.replace(self.cfg, num_workers=W)
        shuffle, M, R = self.shuffle, self.M, self.R
        waves_m = cfg_w.map_waves
        waves_r = cfg_w.reduce_waves
        M_pad = waves_m * W
        init_out = self.initial_reduce_buffers

        def step(bk, bv, bp):
            # Column width comes from the input, not the config: the
            # combiner hands this barrier compacted (M, Pc) rows, and the
            # per-worker stream (hence the exchange capacity) shrinks
            # with them — same contraction a real mesh run sees.
            Pb = bk.shape[1]
            n_local = waves_m * Pb

            # Worker-major local streams: worker w owns tasks w, w+W, ...
            def per_worker(buf, fill):
                padded = _pad_rows(buf, M_pad - M, fill)
                return padded.reshape(waves_m, W, Pb).transpose(
                    1, 0, 2
                ).reshape(W, n_local)

            k2 = per_worker(bk, PAD_KEY)
            v2 = per_worker(bv, 0)
            p2 = per_worker(bp, False)
            (send_k, send_v, send_r), sdrop = jax.vmap(
                lambda k, v, p: shuffle.pack(cfg_w, k, v, p)
            )(k2, v2, p2)
            # all_to_all(tiled): worker w's received row j is worker j's
            # send row w — a block transpose of the (W, W, cap) tensor.
            recv_k = send_k.transpose(1, 0, 2)
            recv_v = send_v.transpose(1, 0, 2)
            recv_r = send_r.transpose(1, 0, 2)
            (bk2, bv2), rdrop = jax.vmap(
                lambda k, v, r: shuffle.unpack(
                    cfg_w, n_local,
                    k.reshape(-1), v.reshape(-1), r.reshape(-1),
                )
            )(recv_k, recv_v, recv_r)
            # (W, waves_r, cap) -> reducer-indexed (R, cap): reducer r
            # lives on worker r % W at local slot r // W.
            cap = bk2.shape[-1]
            pk = bk2.transpose(1, 0, 2).reshape(waves_r * W, cap)[:R]
            pv = bv2.transpose(1, 0, 2).reshape(waves_r * W, cap)[:R]
            ok, ov = init_out(cap)
            return pk, pv, sdrop.sum() + rdrop.sum(), ok, ov

        return step

    def _reduce_step_fn(self, W: int):
        # Same clamped-window discipline as the map stepper: reduce
        # backends are row-independent by contract, so the shifted final
        # wave rewrites earlier rows with identical aggregates.
        app, cfg, R = self.app, self.cfg, self.R
        backend = self.reduce_backend
        pad = max(0, W - R)

        def step(pk, pv, ok_buf, ov_buf, start):
            kblk = jax.lax.dynamic_slice_in_dim(
                _pad_rows(pk, pad, PAD_KEY), start, W, 0
            )
            vblk = jax.lax.dynamic_slice_in_dim(
                _pad_rows(pv, pad, 0), start, W, 0
            )
            ok, ov = backend.reduce(kblk, vblk, app.reduce_op)
            ov = phases._masked_setup(cfg, kblk, ok, ov)

            def upd(buf, blk, fill):
                return jax.lax.dynamic_update_slice_in_dim(
                    _pad_rows(buf, pad, fill), blk, start, 0
                )[:R]

            return upd(ok_buf, ok, PAD_KEY), upd(ov_buf, ov, 0)

        return step

    # ------------------------------------- split compute/commit steppers
    #
    # The pipelined mode needs the wave step split at its data-dependency
    # boundary: ``compute`` reads only the immutable inputs (splits /
    # partitions) and produces a task block; ``commit`` writes the block
    # into the carried accumulators.  Wave group g's compute therefore has
    # no dependency on group g-1's commit, and the scheduler can overlap
    # them inside one loop iteration.  compute∘commit at the same start is
    # exactly the fused step — same slices, same clamping — so the split
    # changes scheduling, never values.

    def _map_compute_fn(self, Weff: int):
        app, cfg, M = self.app, self.cfg, self.M
        pad = max(0, Weff - M)

        def compute(splits, svalid, start):
            tok = jax.lax.dynamic_slice_in_dim(
                _pad_rows(splits, pad, 0), start, Weff, 0
            )
            val = jax.lax.dynamic_slice_in_dim(
                _pad_rows(svalid, pad, False), start, Weff, 0
            )
            return jax.vmap(
                lambda t, m: run_map_task(app, cfg, t, m)
            )(tok, val)

        return compute

    def _map_commit_fn(self, Weff: int):
        M = self.M
        pad = max(0, Weff - M)

        def commit(bufs, blk, start):
            bk, bv, bp = bufs
            k, v, pv = blk

            def upd(buf, b, fill):
                return jax.lax.dynamic_update_slice_in_dim(
                    _pad_rows(buf, pad, fill), b, start, 0
                )[:M]

            return upd(bk, k, PAD_KEY), upd(bv, v, 0), upd(bp, pv, False)

        return commit

    def _reduce_compute_fn(self, Weff: int):
        app, cfg = self.app, self.cfg
        backend = self.reduce_backend
        pad = max(0, Weff - self.R)

        def compute(pk, pv, start):
            kblk = jax.lax.dynamic_slice_in_dim(
                _pad_rows(pk, pad, PAD_KEY), start, Weff, 0
            )
            vblk = jax.lax.dynamic_slice_in_dim(
                _pad_rows(pv, pad, 0), start, Weff, 0
            )
            ok, ov = backend.reduce(kblk, vblk, app.reduce_op)
            ov = phases._masked_setup(cfg, kblk, ok, ov)
            return ok, ov

        return compute

    def _reduce_commit_fn(self, Weff: int):
        R = self.R
        pad = max(0, Weff - R)

        def commit(bufs, blk, start):
            ok_buf, ov_buf = bufs
            ok, ov = blk

            def upd(buf, b, fill):
                return jax.lax.dynamic_update_slice_in_dim(
                    _pad_rows(buf, pad, fill), b, start, 0
                )[:R]

            return upd(ok_buf, ok, PAD_KEY), upd(ov_buf, ov, 0)

        return commit

    @staticmethod
    def _software_pipeline(compute, commit, groups: int, stride: int,
                           init_bufs):
        """Prologue / steady-state / epilogue over ``groups`` wave groups.

        Iteration g of the steady-state ``fori_loop`` commits group g-1's
        block *and* computes group g's — the two halves touch disjoint
        state, so XLA's thunk scheduler may overlap them.  The commit
        order (0, 1, ..., G-1) and every slice/clamp is identical to the
        serial loop, so outputs are bit-exact by construction.
        """

        def run(*inputs):
            blk = compute(*inputs, 0)

            def body(g, carry):
                bufs, blk = carry
                bufs = commit(bufs, blk, (g - 1) * stride)
                return bufs, compute(*inputs, g * stride)

            bufs, blk = jax.lax.fori_loop(
                1, groups, body, (init_bufs(), blk)
            )
            return commit(bufs, blk, (groups - 1) * stride)

        return run

    def pipelined_phase_fns(self, workers: int | None = None,
                            depth: int | None = None) -> dict:
        """The pipeline's phase functions with map and reduce waves
        software-pipelined at overlap depth D: waves are grouped D at a
        time into blocks of ``W*D`` tasks, and the steady-state loop
        commits group g-1 while computing group g.  The shuffle is the
        global barrier between the two pipelines and is byte-identical
        to the serial mode's.  ``depth=1`` degenerates to
        :meth:`phase_fns` (today's schedule).
        """
        W = self.cfg.num_workers if workers is None else int(workers)
        D = (getattr(self.cfg, "overlap_depth", 1)
             if depth is None else int(depth))
        if D < 1:
            raise ValueError(f"overlap depth must be >= 1, got {D}")
        if D == 1:
            return self.phase_fns(W)
        Weff_m = min(W * D, self.M)
        Weff_r = min(W * D, self.R)
        groups_m = math.ceil(self.M / Weff_m)
        groups_r = math.ceil(self.R / Weff_r)
        prep = self._prep_fn()
        shuffle_step = self._shuffle_step_fn(
            W if self.shuffle.collective else 1
        )
        map_pipe = self._software_pipeline(
            self._map_compute_fn(Weff_m), self._map_commit_fn(Weff_m),
            groups_m, Weff_m, self.initial_map_buffers,
        )
        red_compute = self._reduce_compute_fn(Weff_r)
        red_commit = self._reduce_commit_fn(Weff_r)
        groups_r_, Weff_r_ = groups_r, Weff_r
        init_red = self.initial_reduce_buffers

        def phase_map(tokens):
            return map_pipe(*prep(tokens))

        def phase_shuffle(bk, bv, bp):
            pk, pv, dropped, _, _ = shuffle_step(bk, bv, bp)
            return pk, pv, dropped

        def phase_reduce(pk, pv):
            pipe = self._software_pipeline(
                red_compute, red_commit, groups_r_, Weff_r_,
                lambda: init_red(pk.shape[1]),
            )
            return pipe(pk, pv)

        fns = {"map": phase_map}
        if self.combiner:
            # The combine rides the compute side of the pipeline: pure
            # per-row work on the committed map buffers, ahead of the
            # global shuffle barrier (no commit state of its own).
            fns["combine"] = self._combine_step_fn()
        fns["shuffle"] = phase_shuffle
        fns["reduce"] = phase_reduce
        return fns

    # ----------------------------------------- jitted steppers (per grant)

    def prep(self):
        if self._jit_prep is None:
            self._jit_prep = jax.jit(self._prep_fn())
        return self._jit_prep

    def map_stepper(self, W: int):
        # A grant wider than the task count slices/updates the identical
        # M-row window (the pad rows are write-through ballast), so every
        # W >= M is the same stepper: canonicalize the key to min(W, M).
        key = (min(int(W), self.M), self.combiner)
        if key not in self._jit_map:
            self._cache_misses += 1
            self._jit_map[key] = jax.jit(self._map_step_fn(key[0]))
        else:
            self._cache_hits += 1
        return self._jit_map[key]

    def combine_stepper(self):
        # W-independent barrier (like the lexsort shuffle): one entry.
        if self._jit_combine is None:
            self._cache_misses += 1
            self._jit_combine = jax.jit(self._combine_step_fn())
        else:
            self._cache_hits += 1
        return self._jit_combine

    def shuffle_stepper(self, W: int):
        key = (W if self.shuffle.collective else 1, self.combiner)
        if key not in self._jit_shuffle:
            self._cache_misses += 1
            self._jit_shuffle[key] = jax.jit(self._shuffle_step_fn(key[0]))
        else:
            self._cache_hits += 1
        return self._jit_shuffle[key]

    def reduce_stepper(self, W: int, cap: int):
        key = (min(int(W), self.R), cap, self.combiner)
        if key not in self._jit_reduce:
            self._cache_misses += 1
            self._jit_reduce[key] = jax.jit(self._reduce_step_fn(key[0]))
        else:
            self._cache_hits += 1
        return self._jit_reduce[key]

    def cache_info(self) -> dict:
        """Stepper-cache occupancy and hit/miss counters (regrant
        re-planning should mostly *hit*; equivalent grants share keys)."""
        return {
            "map_entries": len(self._jit_map),
            "combine_entries": int(self._jit_combine is not None),
            "shuffle_entries": len(self._jit_shuffle),
            "reduce_entries": len(self._jit_reduce),
            "pipelined_entries": len(self._jit_pipelined),
            "hits": self._cache_hits,
            "misses": self._cache_misses,
        }

    # ------------------------------------------------- phase compositions

    def phase_fns(self, workers: int | None = None) -> dict:
        """The pipeline as three pure phase functions — each a stepper
        loop (``fori_loop`` over waves) at one grant.  Shared by the
        fused mode (composed under one jit), the traced mode (jitted and
        fenced per phase), and the XLA cost estimator (lowered per phase
        for abstract inputs).
        """
        W = self.cfg.num_workers if workers is None else int(workers)
        prep = self._prep_fn()
        map_step = self._map_step_fn(W)
        shuffle_step = self._shuffle_step_fn(
            W if self.shuffle.collective else 1
        )
        reduce_step = self._reduce_step_fn(W)
        map_waves = math.ceil(self.M / W)
        red_waves = math.ceil(self.R / W)
        init_map = self.initial_map_buffers
        init_red = self.initial_reduce_buffers

        def phase_map(tokens):
            splits, valid = prep(tokens)

            def body(i, bufs):
                return map_step(splits, valid, *bufs, i * W)

            return jax.lax.fori_loop(0, map_waves, body, init_map())

        def phase_shuffle(bk, bv, bp):
            pk, pv, dropped, _, _ = shuffle_step(bk, bv, bp)
            return pk, pv, dropped

        def phase_reduce(pk, pv):
            def body(i, bufs):
                return reduce_step(pk, pv, *bufs, i * W)

            return jax.lax.fori_loop(
                0, red_waves, body, init_red(pk.shape[1])
            )

        fns = {"map": phase_map}
        if self.combiner:
            fns["combine"] = self._combine_step_fn()
        fns["shuffle"] = phase_shuffle
        fns["reduce"] = phase_reduce
        return fns

    # ---------------------------------------------------------------- modes

    def fused(self, workers: int | None = None):
        """Mode ``fused``: the whole pipeline under one ``jit`` — the
        zero-overhead hot path.  Returns ``job(tokens) -> (out_keys
        (R, cap), out_vals (R, cap), dropped ())``.  Works for both
        shuffle families (the collective one runs its emulated
        single-controller form; use :meth:`sharded` for a real mesh)."""
        fns = self.phase_fns(workers)

        def job(tokens):
            bufs = fns["map"](tokens)
            if "combine" in fns:
                bufs = fns["combine"](*bufs)
            pk, pv, dropped = fns["shuffle"](*bufs)
            ok, ov = fns["reduce"](pk, pv)
            return ok, ov, dropped

        return jax.jit(job)

    def pipelined(self, workers: int | None = None,
                  depth: int | None = None):
        """Mode ``pipelined``: the fused pipeline with map and reduce
        waves software-pipelined at overlap depth D (default
        ``cfg.overlap_depth``) — wave group g's compute overlaps group
        g-1's commit inside one loop carry, prologue/epilogue included
        (see :meth:`pipelined_phase_fns`).  Fewer, wider loop iterations
        plus commit/compute overlap is where the wall-clock win comes
        from on wave-count-dominated (shuffle-heavy) configs.  Outputs
        are bit-exact vs :meth:`fused` by construction; jitted jobs are
        cached per ``(W, depth)`` grant."""
        W = self.cfg.num_workers if workers is None else int(workers)
        D = (getattr(self.cfg, "overlap_depth", 1)
             if depth is None else int(depth))
        if D < 1:
            raise ValueError(f"overlap depth must be >= 1, got {D}")
        key = (W, D, self.combiner)
        if key in self._jit_pipelined:
            self._cache_hits += 1
            return self._jit_pipelined[key]
        self._cache_misses += 1
        fns = self.pipelined_phase_fns(W, D)

        def job(tokens):
            bufs = fns["map"](tokens)
            if "combine" in fns:
                bufs = fns["combine"](*bufs)
            pk, pv, dropped = fns["shuffle"](*bufs)
            ok, ov = fns["reduce"](pk, pv)
            return ok, ov, dropped

        jitted = jax.jit(job)
        self._jit_pipelined[key] = jitted
        return jitted

    def traced(self, recorder, workers: int | None = None,
               depth: int | None = None):
        """Mode ``traced``: phase-fenced stepper loops feeding a
        :class:`repro.telemetry.PhaseRecorder`.  Same semantics and
        outputs as :meth:`fused`; counters are measured from the actual
        phase outputs (host-side numpy reductions), so conservation laws
        are checkable invariants rather than config-derived tautologies.

        With overlap depth D > 1 (``depth=`` or ``cfg.overlap_depth``)
        the map/reduce phases compile in their pipelined form and the
        trace gains a fourth ``"pipeline"`` phase carrying the
        cross-phase residual wall time (total minus the three fenced
        phases) plus ``overlap_depth`` / ``overlap_s`` counters — so the
        timing conservation law still closes over the phase list.
        """
        D = (getattr(self.cfg, "overlap_depth", 1)
             if depth is None else int(depth))
        fns = self.pipelined_phase_fns(workers, D)
        jit_map = jax.jit(fns["map"])
        jit_combine = (
            jax.jit(fns["combine"]) if "combine" in fns else None
        )
        jit_shuffle = jax.jit(fns["shuffle"])
        jit_reduce = jax.jit(fns["reduce"])
        m = self.meta(workers)
        pair_bytes = phases.PAIR_BYTES
        app, cfg = self.app, self.cfg

        def job(tokens):
            trace = recorder.start_job(app.name, cfg, m["input_len"])
            try:
                return _run(tokens, trace)
            except Exception:
                # A failed run must not leave a phantom/partial trace for
                # recorder.last / take_trace consumers to misread.
                if trace in recorder.traces:
                    recorder.traces.remove(trace)
                raise

        def _run(tokens, trace):
            t_job = _time.perf_counter()

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            bk, bv, bp = jax.block_until_ready(jit_map(tokens))
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            pairs_emitted = int(np.asarray(bp).sum())
            trace.record_phase(
                "map", dt,
                tasks=m["mappers"], waves=m["map_waves"],
                records_in=m["input_len"],
                pairs_emitted=pairs_emitted, pairs_capacity=m["n_pairs"],
                cpu_s=cpu, cpu_workers=_NCPU,
            )

            if jit_combine is not None:
                t0 = _time.perf_counter()
                c0 = _time.process_time()
                bk, bv, bp = jax.block_until_ready(
                    jit_combine(bk, bv, bp)
                )
                cpu = _time.process_time() - c0
                dt = _time.perf_counter() - t0
                pairs_combined = int(np.asarray(bp).sum())
                trace.record_phase(
                    "combine", dt,
                    tasks=m["mappers"],
                    pairs_in=pairs_emitted, pairs_out=pairs_combined,
                    bytes_in=pairs_emitted * pair_bytes,
                    bytes_out=pairs_combined * pair_bytes,
                    combine_capacity=m["combine_capacity"],
                    cpu_s=cpu, cpu_workers=_NCPU,
                    # Combining is map-local CPU work: it moves no fabric
                    # bytes (net_bytes == 0 is a checked invariant) — the
                    # contraction shows up in the *shuffle* counters.
                    net_bytes=0.0,
                )
                shuffle_pairs_in = pairs_combined
            else:
                shuffle_pairs_in = pairs_emitted

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            pk, pv, dropped = jax.block_until_ready(
                jit_shuffle(bk, bv, bp)
            )
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            n_dropped = int(dropped)
            pairs_out = int((np.asarray(pk) != int(PAD_KEY)).sum())
            trace.record_phase(
                "shuffle", dt,
                pairs_in=shuffle_pairs_in, pairs_out=pairs_out,
                pairs_dropped=n_dropped,
                bytes_in=shuffle_pairs_in * pair_bytes,
                bytes_out=pairs_out * pair_bytes,
                bytes_dropped=n_dropped * pair_bytes,
                partitions=m["reducers"],
                partition_capacity=int(pk.shape[1]),
                cpu_s=cpu, cpu_workers=_NCPU,
                # Fabric accounting: every pair entering the shuffle
                # crosses the wire (dropped ones included) — post-combine
                # pairs when the combiner is on, which is exactly the
                # byte contraction the fabric sees.
                net_bytes=shuffle_pairs_in * pair_bytes,
                net_s=dt,
            )

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            ok, ov = jax.block_until_ready(jit_reduce(pk, pv))
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            segments = int((np.asarray(ok) != int(PAD_KEY)).sum())
            trace.record_phase(
                "reduce", dt,
                tasks=m["reducers"], waves=m["reduce_waves"],
                segments_out=segments,
                segment_slots=m["reducers"] * int(pk.shape[1]),
                cpu_s=cpu, cpu_workers=_NCPU,
            )

            total = _time.perf_counter() - t_job
            if D > 1:
                # Overlap happens *inside* the fenced map/reduce phases
                # (their walls already absorb it), so the explicit
                # pipeline phase carries only the cross-phase residual —
                # conservation still closes over the phase list.  Host
                # bookkeeping moves no fabric bytes: net_bytes == 0 is a
                # checked invariant, not an omission.
                residual = max(0.0, total - trace.phase_time_sum())
                trace.record_phase(
                    "pipeline", residual,
                    overlap_depth=D, overlap_s=0.0,
                    net_bytes=0.0,
                )
            trace.finish(total)
            return ok, ov, dropped

        return job

    def resumable(self, recorder=None):
        """Mode ``resumable``: a :class:`repro.elastic.resumable.
        ResumableJob` whose wave steppers are this plan's (cursor and
        regrant bookkeeping live in the elastic layer; the pipeline
        lowering lives here, once)."""
        from repro.elastic.resumable import ResumableJob

        return ResumableJob.from_plan(self, recorder=recorder)

    # ------------------------------------------------------------- sharded

    def sharded(self, mesh, axis: str = "workers", counters: bool = False,
                recorder=None):
        """Mode ``sharded``: ``shard_map`` around the same phase
        primitives — workers are devices on ``mesh[axis]``, the shuffle a
        literal ``all_to_all``.  This is the deployment path for real
        multi-chip meshes; semantics match every other mode.

        ``recorder=None`` compiles the fused single-program form (one
        dispatch, zero overhead).  With a recorder, the three phases
        compile as *separate* mesh programs so each can be fenced and
        wall-clocked — per-phase wall times and measured counters on the
        sharded path, which the fused ``shard_map`` program inherently
        collapses to one aggregate.

        With ``counters=True`` the returned job additionally yields a
        ``stats`` dict reducing the per-worker overflow counters across
        shards (``dropped_send`` / ``dropped_recv`` /
        ``dropped_per_worker``).
        """
        cfg, app = self.cfg, self.app
        W = mesh.shape[axis]
        if cfg.num_workers != W:
            raise ValueError(
                f"cfg.num_workers={cfg.num_workers} != mesh {W}"
            )
        shuffle = self.shuffle
        if not shuffle.collective:
            # The sharded path's structural shuffle IS the mesh collective.
            shuffle = _backends.SHUFFLE_BACKENDS["all_to_all"]
        reduce_backend = self.reduce_backend
        M, R, S, P = self.M, self.R, self.S, self.P
        input_len = self.input_len
        waves_m = cfg.map_waves
        waves_r = cfg.reduce_waves
        M_pad = waves_m * W
        n_local = waves_m * P
        combiner = self.combiner
        combine_cap = self.combine_cap
        reduce_op = app.reduce_op
        #: per-worker stream width entering the collective — the combine
        #: contraction shrinks the literal all_to_all itself
        n_local_c = waves_m * (combine_cap if combiner else P)

        from jax.sharding import PartitionSpec as P_

        spec2 = P_(axis, None)
        spec3 = P_(axis, None, None)

        def smap(worker_fn, in_specs, out_specs):
            # pallas_call has no replication rule; every output is
            # axis-sharded anyway, so the check adds nothing here.
            return _shard_map(
                worker_fn, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check=False,
            )

        def prep(tokens):
            pad_to = M_pad * S
            padded = jnp.zeros((pad_to,), jnp.int32).at[:input_len].set(
                tokens
            )
            valid = (jnp.arange(pad_to) < input_len)
            # Worker-major task layout: worker w owns tasks w, w+W, ...
            splits = padded.reshape(waves_m, W, S).transpose(1, 0, 2)
            vsplit = valid.reshape(waves_m, W, S).transpose(1, 0, 2)
            return splits, vsplit

        def w_map(splits, valid):  # (1(worker), waves, S) local shards
            # Local map waves: reuse the shared map phase with W_local = 1.
            sp = splits[0][:, None, :]   # (waves, 1, S)
            va = valid[0][:, None, :]
            k, v, pv = map_phase(app, cfg, sp, va)
            return (
                k.reshape(1, n_local),
                v.reshape(1, n_local),
                pv.reshape(1, n_local),
            )

        def w_combine(k, v, pv):  # (1, n_local) local pair streams
            # Shard-local map-side combine: this worker's waves_m task
            # rows, aggregated + compacted before any byte crosses the
            # mesh — the per-worker stream (and the collective built on
            # it) shrinks from waves_m*P to waves_m*Pc.
            ck, cv, cp = phases.combine_rows(
                reduce_backend,
                k[0].reshape(waves_m, P),
                v[0].reshape(waves_m, P),
                pv[0].reshape(waves_m, P),
                reduce_op, combine_cap,
            )
            return (
                ck.reshape(1, n_local_c),
                cv.reshape(1, n_local_c),
                cp.reshape(1, n_local_c),
            )

        def w_shuffle(k, v, pv):  # (1, n_local[_c]) local pair streams
            bk, bv, dropped = shuffle.exchange(
                cfg, axis, k[0], v[0], pv[0]
            )
            return bk[None], bv[None], dropped[None]

        def w_reduce(bk, bv):  # (1, waves_r, cap) owned reduce slots
            ok, ov = reduce_local(app, cfg, bk[0], bv[0], reduce_backend)
            return ok[None], ov[None]

        def to_reducer_major(ok, ov):
            # (W, waves_r, cap) -> (R, cap) indexed by reducer id: reducer
            # r lives on worker r % W at local slot r // W, so row r of
            # the slot-major stacking is exactly reducer r's partition.
            cap = ok.shape[-1]
            ok = ok.transpose(1, 0, 2).reshape(-1, cap)[:R]
            ov = ov.transpose(1, 0, 2).reshape(-1, cap)[:R]
            return ok, ov

        def stats_from(per_worker: np.ndarray) -> dict:
            return {
                "dropped_send": int(per_worker[:, 0].sum()),
                "dropped_recv": int(per_worker[:, 1].sum()),
                "dropped_per_worker": per_worker,
            }

        if recorder is None:
            # Fused single mesh program (the zero-overhead deployment
            # path): all phases in one shard_map body.
            def worker(splits, valid):
                k, v, pv = w_map(splits, valid)
                if combiner:
                    k, v, pv = w_combine(k, v, pv)
                bk, bv, dropped = w_shuffle(k, v, pv)
                ok, ov = w_reduce(bk, bv)
                return ok, ov, dropped

            shard_fn = smap(
                worker, (spec3, spec3), (spec3, spec3, spec2)
            )

            def whole(tokens):
                splits, vsplit = prep(tokens)
                ok, ov, dropped = shard_fn(splits, vsplit)
                ok, ov = to_reducer_major(ok, ov)
                # dropped: (W, 2) per-worker [send, recv] counters.
                return ok, ov, dropped

            jitted = jax.jit(whole)

            if not counters:
                def plain(tokens):
                    ok, ov, dropped = jitted(tokens)
                    return ok, ov, dropped.sum()
                return plain

            def with_counters(tokens):
                ok, ov, dropped = jitted(tokens)
                per_worker = np.asarray(dropped)
                return ok, ov, dropped.sum(), stats_from(per_worker)

            return with_counters

        # Phase-fenced sharded execution: separate mesh programs, each
        # wall-clocked, counters cross-shard reduced on the host.
        pair_bytes = phases.PAIR_BYTES
        jit_map = jax.jit(
            lambda tokens: smap(w_map, (spec3, spec3),
                                (spec2, spec2, spec2))(*prep(tokens))
        )
        jit_combine = (
            jax.jit(
                smap(w_combine, (spec2, spec2, spec2),
                     (spec2, spec2, spec2))
            )
            if combiner else None
        )
        jit_shuffle = jax.jit(
            smap(w_shuffle, (spec2, spec2, spec2), (spec3, spec3, spec2))
        )
        jit_reduce = jax.jit(
            smap(w_reduce, (spec3, spec3), (spec3, spec3))
        )

        def traced_job(tokens):
            trace = recorder.start_job(app.name, cfg, input_len)
            try:
                return _run(tokens, trace)
            except Exception:
                if trace in recorder.traces:
                    recorder.traces.remove(trace)
                raise

        def _run(tokens, trace):
            t_job = _time.perf_counter()

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            k, v, pv = jax.block_until_ready(jit_map(tokens))
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            pairs_emitted = int(np.asarray(pv).sum())
            trace.record_phase(
                "map", dt,
                tasks=M, waves=waves_m, workers=W,
                records_in=input_len,
                pairs_emitted=pairs_emitted, pairs_capacity=W * n_local,
                cpu_s=cpu, cpu_workers=_NCPU,
            )

            if jit_combine is not None:
                t0 = _time.perf_counter()
                c0 = _time.process_time()
                k, v, pv = jax.block_until_ready(jit_combine(k, v, pv))
                cpu = _time.process_time() - c0
                dt = _time.perf_counter() - t0
                pairs_combined = int(np.asarray(pv).sum())
                trace.record_phase(
                    "combine", dt,
                    tasks=M, workers=W,
                    pairs_in=pairs_emitted, pairs_out=pairs_combined,
                    bytes_in=pairs_emitted * pair_bytes,
                    bytes_out=pairs_combined * pair_bytes,
                    combine_capacity=combine_cap,
                    cpu_s=cpu, cpu_workers=_NCPU,
                    net_bytes=0.0,
                )
                shuffle_pairs_in = pairs_combined
            else:
                shuffle_pairs_in = pairs_emitted

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            bk, bv, dropped = jax.block_until_ready(
                jit_shuffle(k, v, pv)
            )
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            per_worker = np.asarray(dropped)
            n_dropped = int(per_worker.sum())
            pairs_out = int((np.asarray(bk) != int(PAD_KEY)).sum())
            trace.record_phase(
                "shuffle", dt,
                pairs_in=shuffle_pairs_in, pairs_out=pairs_out,
                pairs_dropped=n_dropped,
                bytes_in=shuffle_pairs_in * pair_bytes,
                bytes_out=pairs_out * pair_bytes,
                bytes_dropped=n_dropped * pair_bytes,
                partitions=R, workers=W,
                # The capacity the executed exchange actually allocated
                # (the configured shuffle may have been substituted by
                # the collective on this path).
                partition_capacity=int(bk.shape[-1]),
                dropped_send=int(per_worker[:, 0].sum()),
                dropped_recv=int(per_worker[:, 1].sum()),
                cpu_s=cpu, cpu_workers=_NCPU,
                net_bytes=shuffle_pairs_in * pair_bytes,
                net_s=dt,
            )

            t0 = _time.perf_counter()
            c0 = _time.process_time()
            ok, ov = jax.block_until_ready(jit_reduce(bk, bv))
            cpu = _time.process_time() - c0
            dt = _time.perf_counter() - t0
            ok, ov = to_reducer_major(ok, ov)
            segments = int((np.asarray(ok) != int(PAD_KEY)).sum())
            trace.record_phase(
                "reduce", dt,
                tasks=R, waves=waves_r, workers=W,
                segments_out=segments,
                segment_slots=W * waves_r * int(bk.shape[-1]),
                cpu_s=cpu, cpu_workers=_NCPU,
            )

            trace.finish(_time.perf_counter() - t_job)
            if counters:
                return ok, ov, per_worker.sum(), stats_from(per_worker)
            return ok, ov, per_worker.sum()

        return traced_job
