"""The paper's two benchmark applications, on the TPU MapReduce engine.

* **WordCount** — each map task takes a split of word-ids and emits
  ``<word, 1>``; reducers sum per word.  (Paper §V.A, refs [33-34].)
* **Exim Mainlog parsing** — Exim logs are sequences of per-message records;
  the Hadoop job groups log lines by transaction id.  Our token encoding of a
  mainlog is a flat stream of fixed-width records
  ``[txn_id, event_type, size]``; map emits ``<txn_id, packed(event, size)>``
  and reducers aggregate per transaction (event count + total bytes packed in
  one int32).  (Paper §V.A, ref [35].)

Both apps are pure `jnp` map functions with static output sizes, as the
engine requires.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.mapreduce.engine import MapReduceApp, PAD_KEY

# ---------------------------------------------------------------------------
# WordCount
# ---------------------------------------------------------------------------


def _wordcount_map(tokens, valid):
    """<line of words> -> <word, 1> pairs."""
    keys = jnp.where(valid, tokens, PAD_KEY)
    values = jnp.where(valid, 1, 0).astype(jnp.int32)
    return keys, values, valid


def wordcount(vocab_size: int = 4096) -> MapReduceApp:
    return MapReduceApp(
        name="wordcount",
        key_space=vocab_size,
        map_fn=_wordcount_map,
        pairs_per_token=1,
        reduce_op="sum",
    )


# ---------------------------------------------------------------------------
# Exim Mainlog parsing
# ---------------------------------------------------------------------------

RECORD_WIDTH = 3  # [txn_id, event_type, size_bytes]


def _eximparse_map(tokens, valid):
    """Parse fixed-width records from a split; emit <txn_id, size>.

    A split of S tokens holds S // RECORD_WIDTH whole records; trailing
    partial records are invalid (in real Hadoop, input splits are
    line-aligned; our fixed-width records make alignment static).  Reducers
    sum sizes per transaction id — the per-transaction grouping/aggregation
    of the paper's Exim job.
    """
    S = tokens.shape[0]
    n_rec = S // RECORD_WIDTH
    rec = tokens[: n_rec * RECORD_WIDTH].reshape(n_rec, RECORD_WIDTH)
    rec_valid = valid[: n_rec * RECORD_WIDTH].reshape(n_rec, RECORD_WIDTH).all(
        axis=1
    )
    txn = rec[:, 0]
    size = rec[:, 2]
    keys = jnp.where(rec_valid, txn, PAD_KEY)
    values = jnp.where(rec_valid, size, 0).astype(jnp.int32)
    # Static output size: one pair per record slot; pad to S with invalid
    # pairs so every map task emits the same-shaped output.
    pad = S - n_rec
    keys = jnp.concatenate([keys, jnp.full((pad,), PAD_KEY, jnp.int32)])
    values = jnp.concatenate([values, jnp.zeros((pad,), jnp.int32)])
    pvalid = jnp.concatenate([rec_valid, jnp.zeros((pad,), bool)])
    return keys, values, pvalid


def eximparse(n_transactions: int = 1024) -> MapReduceApp:
    return MapReduceApp(
        name="eximparse",
        key_space=n_transactions,
        map_fn=_eximparse_map,
        pairs_per_token=1,
        reduce_op="sum",
    )
