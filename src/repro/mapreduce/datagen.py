"""Synthetic corpora for the two benchmark applications.

The paper profiles on 8 GB of real text / mail logs; we generate
statistically similar synthetic streams sized for the host: Zipf-distributed
word ids for WordCount (natural-language-like skew matters — it skews the
shuffle partition fill), and fixed-width Exim transaction records with
realistic event multiplicity (each mail transaction logs ~2-6 lines).
"""

from __future__ import annotations

import numpy as np

from repro.mapreduce.apps import RECORD_WIDTH


def wordcount_corpus(
    n_tokens: int, vocab_size: int = 4096, *, zipf_a: float = 1.3, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # Zipf over a finite vocab via rejection-free inverse-CDF on ranks.
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    return rng.choice(vocab_size, size=n_tokens, p=probs).astype(np.int32)


def exim_mainlog(
    n_tokens: int, n_transactions: int = 1024, *, seed: int = 0
) -> np.ndarray:
    """Flat [txn_id, event_type, size]* stream, truncated to n_tokens."""
    rng = np.random.default_rng(seed)
    n_records = n_tokens // RECORD_WIDTH + 1
    # Each transaction produces a burst of 2-6 consecutive events
    # (arrival, delivery attempts, completion) — like a real mainlog.
    txn_ids = []
    while len(txn_ids) < n_records:
        t = int(rng.integers(0, n_transactions))
        burst = int(rng.integers(2, 7))
        txn_ids.extend([t] * burst)
    txn = np.asarray(txn_ids[:n_records], dtype=np.int32)
    event = rng.integers(0, 8, size=n_records).astype(np.int32)
    size = rng.integers(200, 4000, size=n_records).astype(np.int32)
    stream = np.stack([txn, event, size], axis=1).reshape(-1)[:n_tokens]
    return stream.astype(np.int32)
