"""Resumable execution: the fused pipeline split at wave boundaries.

:func:`repro.mapreduce.build_job` compiles map → shuffle → reduce as one
program; nothing can stop it mid-flight.  :class:`ResumableJob` recompiles
the *same phase primitives* (:mod:`repro.mapreduce.phases`, the same
pluggable backends) as wave steppers over canonical task-major buffers, so
a job can stop at any wave boundary, snapshot, re-plan its remaining waves
under a different worker grant W', and resume **bit-identically**:

* **map** — one step runs the next W map tasks (``run_map_task`` vmapped
  over a wave) and writes their output into (M, P) task-major
  accumulators.  A map task's output depends only on its split and the
  frozen config, never on W or on which wave ran it, so any wave
  re-grouping produces the same rows.
* **shuffle** — one barrier step.  The ``lexsort`` backend partitions the
  canonical M·P pair stream with a *canonical* capacity
  (``partition_capacity(M*P, R, f)``, W-independent), so even the overflow
  accounting is identical under any grant history.  The ``all_to_all``
  backend is a mesh collective whose data movement is inherently
  W-shaped; here its :meth:`pack`/:meth:`unpack` halves are vmapped over a
  worker axis with the literal collective replaced by the block transpose
  it implements — identical per-worker computation, single-controller
  execution, and the capacity layout of a real W-device run at the grant
  held when the barrier executes.
* **reduce** — one step reduces the next W partitions through the
  configured :class:`~repro.mapreduce.backends.ReduceBackend` (row-
  independent by contract) into (R, cap) output accumulators.

Equivalences that follow (property-tested in ``tests/test_elastic.py``):
preempt-at-every-boundary-then-resume ≡ uninterrupted, for every reduce ×
shuffle backend combination; and for the ``lexsort`` shuffle the results
are bit-exact under *any* sequence of regrants.

Steppers are jit-compiled once per (grant, stage) and cached on the job,
so wave-stepped execution costs one dispatch per wave, not one compile.
"""

from __future__ import annotations

import dataclasses
import math
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce import backends as _backends
from repro.mapreduce import phases
from repro.mapreduce.engine import JobConfig, MapReduceApp, \
    _resolve_reduce_backend
from repro.mapreduce.phases import PAD_KEY, run_map_task

from repro.elastic.snapshot import ElasticState, JobCursor


def _pad_rows(arr, n_extra: int, fill):
    """Append ``n_extra`` fill-rows so dynamic W-row windows never clamp."""
    if n_extra == 0:
        return arr
    pad = jnp.full((n_extra,) + arr.shape[1:], fill, dtype=arr.dtype)
    return jnp.concatenate([arr, pad], axis=0)


class ResumableJob:
    """One (app, config, input size) compiled for wave-boundary stepping.

    ``cfg.num_workers`` is only the *initial* grant; the live grant rides
    in the cursor and per-grant steppers are compiled on demand.  The
    optional ``recorder`` (the :class:`repro.telemetry.PhaseRecorder`
    protocol) makes every :meth:`run` call emit one *segment trace*:
    per-phase wall times and measured counters covering exactly the waves
    that call executed, so per-phase models keep fitting on interrupted
    runs (merge segments with ``JobTrace.phase_times`` summing).
    """

    def __init__(self, app: MapReduceApp, cfg: JobConfig, input_len: int,
                 recorder=None):
        shuffle = _backends.get_shuffle_backend(cfg.shuffle_backend)
        self.app = app
        self.cfg = cfg
        self.input_len = int(input_len)
        self.recorder = recorder
        self._reduce_backend = _resolve_reduce_backend(app, cfg)
        self._shuffle = shuffle
        self.M = cfg.num_mappers
        self.R = cfg.num_reducers
        self.S = math.ceil(self.input_len / self.M)
        self.P = self.S * app.pairs_per_token
        #: canonical (W-independent) lexsort partition capacity
        self._lex_cap = phases.partition_capacity(
            self.M * self.P, self.R, cfg.capacity_factor
        )
        self._prep = jax.jit(self._build_prep())
        self._map_steppers: dict[int, callable] = {}
        self._shuffle_steppers: dict[int, callable] = {}
        self._reduce_steppers: dict[tuple[int, int], callable] = {}

    # ------------------------------------------------------------ lifecycle

    def initial_state(self) -> ElasticState:
        cfg = self.cfg
        cursor = JobCursor(
            app=self.app.name, input_len=self.input_len,
            mappers=self.M, reducers=self.R, workers=cfg.num_workers,
            combiner=cfg.combiner, capacity_factor=cfg.capacity_factor,
            setup_rounds=cfg.setup_rounds, setup_dim=cfg.setup_dim,
            reduce_backend=cfg.reduce_backend,
            shuffle_backend=cfg.shuffle_backend,
        )
        arrays = {
            "map_keys": jnp.full((self.M, self.P), PAD_KEY, jnp.int32),
            "map_vals": jnp.zeros((self.M, self.P), jnp.int32),
            "map_valid": jnp.zeros((self.M, self.P), bool),
        }
        return ElasticState(cursor=cursor, arrays=arrays)

    def check_cursor(self, cursor: JobCursor) -> None:
        """A cursor must belong to this job (identity fields match)."""
        mine = self.initial_state().cursor
        for f in ("app", "input_len", "mappers", "reducers", "combiner",
                  "capacity_factor", "setup_rounds", "setup_dim",
                  "reduce_backend", "shuffle_backend"):
            if getattr(cursor, f) != getattr(mine, f):
                raise ValueError(
                    f"cursor field {f}={getattr(cursor, f)!r} does not "
                    f"match this job ({getattr(mine, f)!r})"
                )

    def regrant(self, state: ElasticState, workers: int) -> ElasticState:
        """Re-plan the remaining waves under a new grant.

        Legal at any wave boundary — which is everywhere, because states
        only exist at boundaries.  Buffers are canonical, so this is a
        pure cursor update; the next step compiles (or reuses) steppers
        for the new grant.
        """
        if workers < 1:
            raise ValueError("workers must be >= 1")
        return ElasticState(
            cursor=dataclasses.replace(state.cursor, workers=workers),
            arrays=state.arrays,
        )

    # ------------------------------------------------------------- stepping

    def step(self, state: ElasticState, tokens) -> ElasticState:
        """Execute exactly one wave-boundary step (map wave / shuffle
        barrier / reduce wave) under the cursor's current grant."""
        c = state.cursor
        if c.done:
            raise ValueError("job already complete")
        W = c.workers
        arrays = dict(state.arrays)
        if not c.map_done:
            splits, svalid = self._prep(tokens)
            bk, bv, bp = self._map_stepper(W)(
                splits, svalid,
                arrays["map_keys"], arrays["map_vals"], arrays["map_valid"],
                c.map_tasks_done,
            )
            arrays.update(map_keys=bk, map_vals=bv, map_valid=bp)
            cursor = dataclasses.replace(
                c,
                map_tasks_done=min(self.M, c.map_tasks_done + W),
                waves_executed=c.waves_executed + 1,
            )
        elif not c.shuffled:
            pk, pv, dropped, ok, ov = self._shuffle_stepper(W)(
                arrays["map_keys"], arrays["map_vals"], arrays["map_valid"]
            )
            # Map accumulators are fully absorbed into the partitions;
            # dropping them shrinks every post-shuffle snapshot.
            arrays = {
                "part_keys": pk, "part_vals": pv,
                "out_keys": ok, "out_vals": ov,
            }
            cursor = dataclasses.replace(
                c, shuffled=True, partition_cap=int(pk.shape[1]),
                dropped=int(dropped),
                waves_executed=c.waves_executed + 1,
            )
        else:
            ok, ov = self._reduce_stepper(W, c.partition_cap)(
                arrays["part_keys"], arrays["part_vals"],
                arrays["out_keys"], arrays["out_vals"],
                c.reduce_tasks_done,
            )
            arrays.update(out_keys=ok, out_vals=ov)
            cursor = dataclasses.replace(
                c,
                reduce_tasks_done=min(self.R, c.reduce_tasks_done + W),
                waves_executed=c.waves_executed + 1,
            )
        return ElasticState(cursor=cursor, arrays=arrays)

    def run(self, tokens, state: ElasticState | None = None,
            preempt_after: int | None = None) -> ElasticState:
        """Run from ``state`` (or fresh) until done — or until
        ``preempt_after`` steps have executed *in this call*, leaving a
        wave-boundary state ready to snapshot/regrant/resume."""
        if state is None:
            state = self.initial_state()
        else:
            self.check_cursor(state.cursor)
        trace = None
        if self.recorder is not None:
            trace = self.recorder.start_job(
                self.app.name, self.cfg, self.input_len
            )
        executed = 0
        t_run = _time.perf_counter()
        try:
            while not state.cursor.done and (
                preempt_after is None or executed < preempt_after
            ):
                before = state.cursor
                t0 = _time.perf_counter()
                state = self.step(state, tokens)
                for leaf in state.arrays.values():
                    jax.block_until_ready(leaf)
                dt = _time.perf_counter() - t0
                executed += 1
                if trace is not None:
                    self._record_step(trace, before, state, dt)
        except Exception:
            if trace is not None and trace in self.recorder.traces:
                self.recorder.traces.remove(trace)
            raise
        if trace is not None:
            trace.finish(_time.perf_counter() - t_run)
        return state

    def result(self, state: ElasticState):
        """(out_keys (R, cap), out_vals (R, cap), dropped) of a done job."""
        if not state.cursor.done:
            raise ValueError(
                f"job not complete: {state.cursor.steps_remaining()} "
                "steps remain"
            )
        return (
            state.arrays["out_keys"],
            state.arrays["out_vals"],
            jnp.int32(state.cursor.dropped),
        )

    # ------------------------------------------------------ stepper builds

    def _build_prep(self):
        M, S, input_len = self.M, self.S, self.input_len

        def prep(tokens):
            if tokens.shape != (input_len,):
                raise ValueError(
                    f"expected ({input_len},), got {tokens.shape}"
                )
            pad_to = M * S
            padded = jnp.zeros((pad_to,), jnp.int32).at[:input_len].set(
                tokens
            )
            valid = (jnp.arange(pad_to) < input_len).reshape(M, S)
            return padded.reshape(M, S), valid

        return prep

    def _map_stepper(self, W: int):
        if W not in self._map_steppers:
            app, cfg = self.app, self.cfg
            M, P = self.M, self.P

            def step(splits, svalid, bk, bv, bp, start):
                tok = jax.lax.dynamic_slice_in_dim(
                    _pad_rows(splits, W - 1, 0), start, W, 0
                )
                val = jax.lax.dynamic_slice_in_dim(
                    _pad_rows(svalid, W - 1, False), start, W, 0
                )
                k, v, pv = jax.vmap(
                    lambda t, m: run_map_task(app, cfg, t, m)
                )(tok, val)

                def upd(buf, blk, fill):
                    return jax.lax.dynamic_update_slice_in_dim(
                        _pad_rows(buf, W - 1, fill), blk, start, 0
                    )[:M]

                return (
                    upd(bk, k, PAD_KEY), upd(bv, v, 0), upd(bp, pv, False)
                )

            self._map_steppers[W] = jax.jit(step)
        return self._map_steppers[W]

    def _shuffle_stepper(self, W: int):
        if W not in self._shuffle_steppers:
            if self._shuffle.collective:
                self._shuffle_steppers[W] = jax.jit(
                    self._build_a2a_shuffle(W)
                )
            else:
                self._shuffle_steppers[W] = jax.jit(
                    self._build_lexsort_shuffle()
                )
        return self._shuffle_steppers[W]

    def _build_lexsort_shuffle(self):
        """Canonical single-controller shuffle: W-independent capacity.

        Reuses :meth:`LexsortShuffle.partition` with a W=1 view of the
        config so its ``reduce_waves * W`` row padding degenerates to
        exactly R rows — the canonical partition block.
        """
        cfg_w1 = dataclasses.replace(self.cfg, num_workers=1)
        shuffle, R = self._shuffle, self.R

        def step(bk, bv, bp):
            n = bk.shape[0] * bk.shape[1]
            pk, pv, dropped = shuffle.partition(
                cfg_w1, bk.reshape(n), bv.reshape(n), bp.reshape(n)
            )
            cap = pk.shape[1]
            ok = jnp.full((R, cap), PAD_KEY, jnp.int32)
            ov = jnp.zeros((R, cap), jnp.int32)
            return pk, pv, dropped, ok, ov

        return step

    def _build_a2a_shuffle(self, W: int):
        """The collective shuffle, single-controller: vmap pack/unpack
        over a worker axis, block-transpose in place of ``all_to_all``.

        Reproduces the per-worker computation (and capacity layout) of a
        real W-device :func:`~repro.mapreduce.engine.build_job_sharded`
        run at the grant held when the barrier executes.
        """
        cfg_w = dataclasses.replace(self.cfg, num_workers=W)
        shuffle, M, R, P = self._shuffle, self.M, self.R, self.P
        waves_m = cfg_w.map_waves
        waves_r = cfg_w.reduce_waves
        M_pad = waves_m * W
        n_local = waves_m * P

        def step(bk, bv, bp):
            # Worker-major local streams: worker w owns tasks w, w+W, ...
            def per_worker(buf, fill):
                padded = _pad_rows(buf, M_pad - M, fill)
                return padded.reshape(waves_m, W, P).transpose(
                    1, 0, 2
                ).reshape(W, n_local)

            k2 = per_worker(bk, PAD_KEY)
            v2 = per_worker(bv, 0)
            p2 = per_worker(bp, False)
            (send_k, send_v, send_r), sdrop = jax.vmap(
                lambda k, v, p: shuffle.pack(cfg_w, k, v, p)
            )(k2, v2, p2)
            # all_to_all(tiled): worker w's received row j is worker j's
            # send row w — a block transpose of the (W, W, cap) tensor.
            recv_k = send_k.transpose(1, 0, 2)
            recv_v = send_v.transpose(1, 0, 2)
            recv_r = send_r.transpose(1, 0, 2)
            (bk2, bv2), rdrop = jax.vmap(
                lambda k, v, r: shuffle.unpack(
                    cfg_w, n_local,
                    k.reshape(-1), v.reshape(-1), r.reshape(-1),
                )
            )(recv_k, recv_v, recv_r)
            # (W, waves_r, cap) -> reducer-indexed (R, cap): reducer r
            # lives on worker r % W at local slot r // W.
            cap = bk2.shape[-1]
            pk = bk2.transpose(1, 0, 2).reshape(waves_r * W, cap)[:R]
            pv = bv2.transpose(1, 0, 2).reshape(waves_r * W, cap)[:R]
            ok = jnp.full((R, cap), PAD_KEY, jnp.int32)
            ov = jnp.zeros((R, cap), jnp.int32)
            return pk, pv, sdrop.sum() + rdrop.sum(), ok, ov

        return step

    def _reduce_stepper(self, W: int, cap: int):
        key = (W, cap)
        if key not in self._reduce_steppers:
            app, cfg, R = self.app, self.cfg, self.R
            backend = self._reduce_backend

            def step(pk, pv, ok_buf, ov_buf, start):
                kblk = jax.lax.dynamic_slice_in_dim(
                    _pad_rows(pk, W - 1, PAD_KEY), start, W, 0
                )
                vblk = jax.lax.dynamic_slice_in_dim(
                    _pad_rows(pv, W - 1, 0), start, W, 0
                )
                ok, ov = backend.reduce(kblk, vblk, app.reduce_op)
                ov = phases._masked_setup(cfg, kblk, ok, ov)

                def upd(buf, blk, fill):
                    return jax.lax.dynamic_update_slice_in_dim(
                        _pad_rows(buf, W - 1, fill), blk, start, 0
                    )[:R]

                return upd(ok_buf, ok, PAD_KEY), upd(ov_buf, ov, 0)

            self._reduce_steppers[key] = jax.jit(step)
        return self._reduce_steppers[key]

    # ----------------------------------------------------------- telemetry

    def _record_step(self, trace, before: JobCursor, after: ElasticState,
                     wall_s: float) -> None:
        """One trace phase entry per executed step, counters measured from
        the actual buffers (same discipline as the engine's traced path)."""
        c_after = after.cursor
        if before.map_tasks_done != c_after.map_tasks_done:
            lo, hi = before.map_tasks_done, c_after.map_tasks_done
            pv = np.asarray(after.arrays["map_valid"][lo:hi])
            trace.record_phase(
                "map", wall_s,
                tasks=hi - lo, waves=1, workers=before.workers,
                pairs_emitted=int(pv.sum()),
                records_in=min(self.input_len, hi * self.S)
                - min(self.input_len, lo * self.S),
            )
        elif before.shuffled != c_after.shuffled:
            pairs_out = int(
                (np.asarray(after.arrays["part_keys"]) != int(PAD_KEY)).sum()
            )
            n_dropped = c_after.dropped
            pair_bytes = phases.PAIR_BYTES
            pairs_in = pairs_out + n_dropped
            trace.record_phase(
                "shuffle", wall_s,
                pairs_in=pairs_in, pairs_out=pairs_out,
                pairs_dropped=n_dropped,
                bytes_in=pairs_in * pair_bytes,
                bytes_out=pairs_out * pair_bytes,
                bytes_dropped=n_dropped * pair_bytes,
                partitions=self.R, workers=before.workers,
                partition_capacity=c_after.partition_cap,
            )
        else:
            lo, hi = before.reduce_tasks_done, c_after.reduce_tasks_done
            seg = np.asarray(after.arrays["out_keys"][lo:hi])
            trace.record_phase(
                "reduce", wall_s,
                tasks=hi - lo, waves=1, workers=before.workers,
                segments_out=int((seg != int(PAD_KEY)).sum()),
            )


def run_resumable(job: ResumableJob, tokens,
                  state: ElasticState | None = None,
                  preempt_after: int | None = None) -> ElasticState:
    """Run ``job`` from ``state`` (or fresh), preempting after
    ``preempt_after`` wave-boundary steps — module-level spelling of
    :meth:`ResumableJob.run` for the engine-integration entry point."""
    return job.run(tokens, state=state, preempt_after=preempt_after)
