"""Resumable execution: the plan's wave steppers + cursor bookkeeping.

:func:`repro.mapreduce.build_job` compiles map → shuffle → reduce as one
program; nothing can stop it mid-flight.  :class:`ResumableJob` drives
the **same** canonical wave steppers — the ones
:class:`repro.mapreduce.plan.ExecutionPlan` lowers once and every other
execution mode (fused / traced / sharded) derives from — one
wave-boundary step at a time, so a job can stop at any boundary,
snapshot, re-plan its remaining waves under a different worker grant W',
and resume **bit-identically**:

* **map** — one step runs the next W map tasks into the plan's (M, P)
  task-major accumulators.  A map task's output depends only on its
  split and the frozen config, never on W or on which wave ran it, so
  any wave re-grouping produces the same rows.
* **combine** — combiner jobs run one extra W-independent barrier step
  between map and shuffle: each task row is aggregated and compacted in
  place (the map accumulators shrink to the plan's combine capacity), so
  a job can be preempted either side of the combine and the cursor's
  ``combined`` flag says which side it stopped on.
* **shuffle** — one barrier step.  The ``lexsort`` backend partitions
  the canonical M·P pair stream with a *canonical* W-independent
  capacity, so even the overflow accounting is identical under any
  grant history.  The ``all_to_all`` backend's pack/unpack halves are
  vmapped over a worker axis with the literal collective replaced by
  the block transpose it implements — identical per-worker computation,
  single-controller execution, the capacity layout of a real W-device
  run at the grant held when the barrier executes.
* **reduce** — one step reduces the next W partitions through the
  configured :class:`~repro.mapreduce.backends.ReduceBackend` (row-
  independent by contract) into (R, cap) output accumulators.

This module owns only what is *elastic* about resumable execution: the
cursor lifecycle, grant changes, segment telemetry.  The pipeline
lowering lives in the plan — there is no private stepper copy here, so
resumable execution can never drift from the profiled modes.

Equivalences that follow (property-tested in ``tests/test_plan.py``):
preempt-at-every-boundary-then-resume ≡ fused ≡ traced, for every reduce
× shuffle backend combination; and for the ``lexsort`` shuffle the
results are bit-exact under *any* sequence of regrants.

Steppers are jit-compiled once per (grant, stage) and cached on the
plan — shared with every other consumer of the same plan — so
wave-stepped execution costs one dispatch per wave, not one compile.
"""

from __future__ import annotations

import dataclasses
import time as _time

import jax
import numpy as np

from repro.mapreduce import phases
from repro.mapreduce.engine import JobConfig, MapReduceApp  # noqa: F401
from repro.mapreduce.phases import PAD_KEY
from repro.mapreduce.plan import _NCPU, ExecutionPlan

from repro.elastic.snapshot import ElasticState, JobCursor


class ResumableJob:
    """One :class:`ExecutionPlan` compiled for wave-boundary stepping.

    ``cfg.num_workers`` is only the *initial* grant; the live grant rides
    in the cursor and per-grant steppers are compiled on demand.  The
    optional ``recorder`` (the :class:`repro.telemetry.PhaseRecorder`
    protocol) makes every :meth:`run` call emit one *segment trace*:
    per-phase wall times and measured counters covering exactly the waves
    that call executed, so per-phase models keep fitting on interrupted
    runs (merge segments with ``JobTrace.phase_times`` summing).
    """

    def __init__(self, app: MapReduceApp, cfg: JobConfig, input_len: int,
                 recorder=None, plan: ExecutionPlan | None = None):
        self.plan = plan if plan is not None else ExecutionPlan(
            app, cfg, input_len
        )
        self.app = self.plan.app
        self.cfg = self.plan.cfg
        self.input_len = self.plan.input_len
        self.recorder = recorder
        self.M = self.plan.M
        self.R = self.plan.R
        self.S = self.plan.S
        self.P = self.plan.P

    @classmethod
    def from_plan(cls, plan: ExecutionPlan, recorder=None) -> "ResumableJob":
        """The resumable *mode* of an existing plan (stepper caches
        shared with every other mode derived from it)."""
        return cls(plan.app, plan.cfg, plan.input_len,
                   recorder=recorder, plan=plan)

    # ------------------------------------------------------------ lifecycle

    def initial_state(self) -> ElasticState:
        cfg = self.cfg
        cursor = JobCursor(
            app=self.app.name, input_len=self.input_len,
            mappers=self.M, reducers=self.R, workers=cfg.num_workers,
            combiner=cfg.combiner, capacity_factor=cfg.capacity_factor,
            setup_rounds=cfg.setup_rounds, setup_dim=cfg.setup_dim,
            reduce_backend=cfg.reduce_backend,
            shuffle_backend=cfg.shuffle_backend,
        )
        bk, bv, bp = self.plan.initial_map_buffers()
        arrays = {"map_keys": bk, "map_vals": bv, "map_valid": bp}
        return ElasticState(cursor=cursor, arrays=arrays)

    def check_cursor(self, cursor: JobCursor) -> None:
        """A cursor must belong to this job (identity fields match)."""
        mine = self.initial_state().cursor
        for f in ("app", "input_len", "mappers", "reducers", "combiner",
                  "capacity_factor", "setup_rounds", "setup_dim",
                  "reduce_backend", "shuffle_backend"):
            if getattr(cursor, f) != getattr(mine, f):
                raise ValueError(
                    f"cursor field {f}={getattr(cursor, f)!r} does not "
                    f"match this job ({getattr(mine, f)!r})"
                )

    def regrant(self, state: ElasticState, workers: int) -> ElasticState:
        """Re-plan the remaining waves under a new grant.

        Legal at any wave boundary — which is everywhere, because states
        only exist at boundaries.  Buffers are canonical, so this is a
        pure cursor update; the next step compiles (or reuses) steppers
        for the new grant.
        """
        if workers < 1:
            raise ValueError("workers must be >= 1")
        return ElasticState(
            cursor=dataclasses.replace(state.cursor, workers=workers),
            arrays=state.arrays,
        )

    # ------------------------------------------------------------- stepping

    def step(self, state: ElasticState, tokens) -> ElasticState:
        """Execute exactly one wave-boundary step (map wave / shuffle
        barrier / reduce wave) under the cursor's current grant."""
        c = state.cursor
        if c.done:
            raise ValueError("job already complete")
        W = c.workers
        plan = self.plan
        arrays = dict(state.arrays)
        if not c.map_done:
            splits, svalid = plan.prep()(tokens)
            bk, bv, bp = plan.map_stepper(W)(
                splits, svalid,
                arrays["map_keys"], arrays["map_vals"], arrays["map_valid"],
                c.map_tasks_done,
            )
            arrays.update(map_keys=bk, map_vals=bv, map_valid=bp)
            cursor = dataclasses.replace(
                c,
                map_tasks_done=min(self.M, c.map_tasks_done + W),
                waves_executed=c.waves_executed + 1,
            )
        elif plan.combiner and not c.combined and not c.shuffled:
            # Map-side combine barrier: aggregate + compact the task rows
            # in place.  W-independent (pure per-row work), so the result
            # is identical under any grant history.
            ck, cv, cp = plan.combine_stepper()(
                arrays["map_keys"], arrays["map_vals"], arrays["map_valid"]
            )
            arrays.update(map_keys=ck, map_vals=cv, map_valid=cp)
            cursor = dataclasses.replace(
                c, combined=True, waves_executed=c.waves_executed + 1
            )
        elif not c.shuffled:
            pk, pv, dropped, ok, ov = plan.shuffle_stepper(W)(
                arrays["map_keys"], arrays["map_vals"], arrays["map_valid"]
            )
            # Map accumulators are fully absorbed into the partitions;
            # dropping them shrinks every post-shuffle snapshot.
            arrays = {
                "part_keys": pk, "part_vals": pv,
                "out_keys": ok, "out_vals": ov,
            }
            cursor = dataclasses.replace(
                c, shuffled=True, partition_cap=int(pk.shape[1]),
                dropped=int(dropped),
                waves_executed=c.waves_executed + 1,
            )
        else:
            ok, ov = plan.reduce_stepper(W, c.partition_cap)(
                arrays["part_keys"], arrays["part_vals"],
                arrays["out_keys"], arrays["out_vals"],
                c.reduce_tasks_done,
            )
            arrays.update(out_keys=ok, out_vals=ov)
            cursor = dataclasses.replace(
                c,
                reduce_tasks_done=min(self.R, c.reduce_tasks_done + W),
                waves_executed=c.waves_executed + 1,
            )
        return ElasticState(cursor=cursor, arrays=arrays)

    def run(self, tokens, state: ElasticState | None = None,
            preempt_after: int | None = None) -> ElasticState:
        """Run from ``state`` (or fresh) until done — or until
        ``preempt_after`` steps have executed *in this call*, leaving a
        wave-boundary state ready to snapshot/regrant/resume."""
        if state is None:
            state = self.initial_state()
        else:
            self.check_cursor(state.cursor)
        trace = None
        if self.recorder is not None:
            trace = self.recorder.start_job(
                self.app.name, self.cfg, self.input_len
            )
        executed = 0
        t_run = _time.perf_counter()
        try:
            while not state.cursor.done and (
                preempt_after is None or executed < preempt_after
            ):
                before = state.cursor
                before_arrays = state.arrays
                t0 = _time.perf_counter()
                c0 = _time.process_time()
                state = self.step(state, tokens)
                for leaf in state.arrays.values():
                    jax.block_until_ready(leaf)
                cpu = _time.process_time() - c0
                dt = _time.perf_counter() - t0
                executed += 1
                if trace is not None:
                    self._record_step(
                        trace, before, before_arrays, state, dt, cpu
                    )
        except Exception:
            if trace is not None and trace in self.recorder.traces:
                self.recorder.traces.remove(trace)
            raise
        if trace is not None:
            trace.finish(_time.perf_counter() - t_run)
        return state

    def result(self, state: ElasticState):
        """(out_keys (R, cap), out_vals (R, cap), dropped) of a done job."""
        if not state.cursor.done:
            raise ValueError(
                f"job not complete: {state.cursor.steps_remaining()} "
                "steps remain"
            )
        import jax.numpy as jnp

        return (
            state.arrays["out_keys"],
            state.arrays["out_vals"],
            jnp.int32(state.cursor.dropped),
        )

    # ----------------------------------------------------------- telemetry

    def _record_step(self, trace, before: JobCursor, before_arrays: dict,
                     after: ElasticState, wall_s: float,
                     cpu_s: float = 0.0) -> None:
        """One trace phase entry per executed step, counters measured from
        the actual buffers (same discipline as the engine's traced path).
        ``before_arrays`` is the pre-step buffer dict — the combine entry's
        ``pairs_in`` is the live count the barrier consumed, which only the
        pre-combine map accumulators still hold."""
        c_after = after.cursor
        if before.map_tasks_done != c_after.map_tasks_done:
            lo, hi = before.map_tasks_done, c_after.map_tasks_done
            pv = np.asarray(after.arrays["map_valid"][lo:hi])
            trace.record_phase(
                "map", wall_s,
                tasks=hi - lo, waves=1, workers=before.workers,
                pairs_emitted=int(pv.sum()),
                records_in=min(self.input_len, hi * self.S)
                - min(self.input_len, lo * self.S),
                cpu_s=cpu_s, cpu_workers=_NCPU,
            )
        elif before.combined != c_after.combined:
            pairs_in = int(np.asarray(before_arrays["map_valid"]).sum())
            pairs_out = int(np.asarray(after.arrays["map_valid"]).sum())
            pair_bytes = phases.PAIR_BYTES
            trace.record_phase(
                "combine", wall_s,
                tasks=self.M, waves=1, workers=before.workers,
                pairs_in=pairs_in, pairs_out=pairs_out,
                bytes_in=pairs_in * pair_bytes,
                bytes_out=pairs_out * pair_bytes,
                combine_capacity=self.plan.combine_cap,
                cpu_s=cpu_s, cpu_workers=_NCPU,
                net_bytes=0.0,  # combining is local: no fabric traffic
            )
        elif before.shuffled != c_after.shuffled:
            pairs_out = int(
                (np.asarray(after.arrays["part_keys"]) != int(PAD_KEY)).sum()
            )
            n_dropped = c_after.dropped
            pair_bytes = phases.PAIR_BYTES
            pairs_in = pairs_out + n_dropped
            trace.record_phase(
                "shuffle", wall_s,
                pairs_in=pairs_in, pairs_out=pairs_out,
                pairs_dropped=n_dropped,
                bytes_in=pairs_in * pair_bytes,
                bytes_out=pairs_out * pair_bytes,
                bytes_dropped=n_dropped * pair_bytes,
                partitions=self.R, workers=before.workers,
                partition_capacity=c_after.partition_cap,
                cpu_s=cpu_s, cpu_workers=_NCPU,
                net_bytes=pairs_in * pair_bytes,
                net_s=wall_s,
            )
        else:
            lo, hi = before.reduce_tasks_done, c_after.reduce_tasks_done
            seg = np.asarray(after.arrays["out_keys"][lo:hi])
            trace.record_phase(
                "reduce", wall_s,
                tasks=hi - lo, waves=1, workers=before.workers,
                segments_out=int((seg != int(PAD_KEY)).sum()),
                cpu_s=cpu_s, cpu_workers=_NCPU,
            )


def run_resumable(job: ResumableJob, tokens,
                  state: ElasticState | None = None,
                  preempt_after: int | None = None) -> ElasticState:
    """Run ``job`` from ``state`` (or fresh), preempting after
    ``preempt_after`` wave-boundary steps — module-level spelling of
    :meth:`ResumableJob.run` for the engine-integration entry point."""
    return job.run(tokens, state=state, preempt_after=preempt_after)
