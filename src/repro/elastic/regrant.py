"""Regrant economics: is shrinking/growing a running job's grant worth it?

The paper's regression models predict a job's *total* time at any
(M, R, W) — which is exactly what a mid-flight re-provisioning decision
needs (arXiv:1203.4367's argument): compare the predicted time to finish
the remaining waves under the current grant W against the predicted time
under a candidate grant W' *plus* the measured snapshot/restore overhead.

:class:`WorkProgress` is the scheduler-visible cursor (task counts only —
no engine buffers), shared between the elastic cluster simulator's
accounting and this cost model.  :class:`RegrantCostModel` scales
model-predicted totals by the wave-quantized remaining-work fraction; it
deliberately consumes *predictions* (the paper's regression basis, via
whatever model the calling policy has fitted) and *measured* overheads
(EWMA over observed snapshot/restore walls, seeded with configured
estimates), never oracle truth.
"""

from __future__ import annotations

import dataclasses


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class WorkProgress:
    """Wave-boundary progress of one job, in task space.

    The same denomination as :class:`repro.elastic.snapshot.JobCursor`
    (tasks, not waves) so the fraction-remaining math is grant-agnostic.
    """

    mappers: int
    reducers: int
    map_tasks_done: int = 0
    shuffled: bool = False
    reduce_tasks_done: int = 0
    #: barrier steps between map and shuffle (1 when the job runs a
    #: map-side combiner, else 0) and whether the barrier has passed —
    #: combiner jobs have one more wave-boundary step, and the regrant
    #: cost model must price the remaining fraction against it.
    combine_steps: int = 0
    combined: bool = False

    def __post_init__(self):
        if self.mappers < 1 or self.reducers < 1:
            raise ValueError(f"bad progress {self}")
        if self.combine_steps not in (0, 1):
            raise ValueError(f"bad progress {self}")

    @property
    def done(self) -> bool:
        return self.shuffled and self.reduce_tasks_done >= self.reducers

    def steps_total(self, workers: int) -> int:
        return (
            _ceil_div(self.mappers, workers) + self.combine_steps + 1
            + _ceil_div(self.reducers, workers)
        )

    def steps_remaining(self, workers: int) -> int:
        return (
            _ceil_div(max(0, self.mappers - self.map_tasks_done), workers)
            + (0 if self.combined else self.combine_steps)
            + (0 if self.shuffled else 1)
            + _ceil_div(
                max(0, self.reducers - self.reduce_tasks_done), workers
            )
        )

    def remaining_fraction(self, workers: int) -> float:
        """Wave-quantized fraction of the job still ahead under a grant."""
        return self.steps_remaining(workers) / self.steps_total(workers)


@dataclasses.dataclass(frozen=True)
class RegrantDecision:
    """The cost model's answer for one candidate regrant."""

    current_workers: int
    new_workers: int
    t_remaining_current: float   # predicted: finish under current grant
    t_remaining_new: float       # predicted: finish under candidate grant
    overhead_s: float            # measured snapshot + restore cost
    gain_s: float                # t_rem_current - (t_rem_new + overhead)
    worth_it: bool               # gain_s > min_gain_s (speed-motivated move)
    shrink_ok: bool              # job-side gate for externally-motivated
    #                              shrinks (enough work left, overhead small
    #                              relative to the remaining run)


class RegrantCostModel:
    """Prices a candidate regrant from predictions + measured overheads.

    Two kinds of moves ask different questions:

    * a **grow** (or any speed-motivated regrant) is worth it when the
      job itself finishes earlier even after paying the checkpoint:
      ``worth_it`` = gain above ``min_gain_s``;
    * a **shrink** is externally motivated (the scheduler wants the
      workers for a deadline-risk job), so the job-side question is only
      whether the move is *cheap*: ``shrink_ok`` demands at least
      ``min_remaining_frac`` of the job still ahead (never checkpoint a
      nearly-finished job) and overhead at most ``max_overhead_frac`` of
      the remaining run.  Whether the freed workers buy anything is the
      policy's side of the ledger.

    ``record_overhead`` folds *measured* snapshot/restore walls (from
    :func:`repro.elastic.snapshot.save_snapshot` / ``load_snapshot``, or
    the simulator's configured costs) into an EWMA, so the model tracks
    the real price of a preemption as the system runs.
    """

    def __init__(
        self,
        *,
        snapshot_overhead_s: float = 0.02,
        restore_overhead_s: float = 0.02,
        min_gain_s: float = 0.0,
        min_remaining_frac: float = 0.15,
        max_overhead_frac: float = 0.25,
        ewma_alpha: float = 0.3,
    ):
        if snapshot_overhead_s < 0 or restore_overhead_s < 0:
            raise ValueError("overheads must be >= 0")
        if not 0 < ewma_alpha <= 1:
            raise ValueError("ewma_alpha must be in (0, 1]")
        self.snapshot_overhead_s = float(snapshot_overhead_s)
        self.restore_overhead_s = float(restore_overhead_s)
        self.min_gain_s = float(min_gain_s)
        self.min_remaining_frac = float(min_remaining_frac)
        self.max_overhead_frac = float(max_overhead_frac)
        self.ewma_alpha = float(ewma_alpha)
        self.n_observed = 0

    @property
    def overhead_s(self) -> float:
        return self.snapshot_overhead_s + self.restore_overhead_s

    def record_overhead(self, save_s: float, restore_s: float) -> None:
        """Fold one measured (snapshot, restore) wall pair into the EWMA."""
        a = self.ewma_alpha
        self.snapshot_overhead_s = (
            (1 - a) * self.snapshot_overhead_s + a * float(save_s)
        )
        self.restore_overhead_s = (
            (1 - a) * self.restore_overhead_s + a * float(restore_s)
        )
        self.n_observed += 1

    def evaluate(
        self,
        *,
        t_total_current: float,
        t_total_new: float,
        progress: WorkProgress,
        current_workers: int,
        new_workers: int,
    ) -> RegrantDecision:
        """Price one candidate regrant.

        ``t_total_current`` / ``t_total_new``: model-predicted *total* job
        times at the current / candidate grant (the paper's regression
        evaluated at (M, R, W, size) and (M, R, W', size)) — scaled here
        by each grant's own wave-quantized remaining fraction, because
        wave counts requantize when the grant changes.
        """
        if current_workers < 1 or new_workers < 1:
            raise ValueError("worker grants must be >= 1")
        frac_cur = progress.remaining_fraction(current_workers)
        t_rem_cur = float(t_total_current) * frac_cur
        t_rem_new = (
            float(t_total_new) * progress.remaining_fraction(new_workers)
        )
        overhead = self.overhead_s
        gain = t_rem_cur - (t_rem_new + overhead)
        shrink_ok = (
            frac_cur >= self.min_remaining_frac
            and overhead <= self.max_overhead_frac * max(t_rem_cur, 1e-12)
        )
        return RegrantDecision(
            current_workers=current_workers,
            new_workers=new_workers,
            t_remaining_current=t_rem_cur,
            t_remaining_new=t_rem_new,
            overhead_s=overhead,
            gain_s=gain,
            worth_it=gain > self.min_gain_s,
            shrink_ok=shrink_ok,
        )
