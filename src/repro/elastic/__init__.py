"""Elastic execution: checkpointable jobs + preemptive regrant scheduling.

The paper's models predict how a job's time depends on its configuration;
PR 2-3 used them to pick a configuration *at admission*.  This package
makes the worker grant W re-decidable **mid-flight**:

    snapshot.py  — wave-boundary job state: JobCursor + ElasticState
                   pytrees, persisted via repro.checkpoint (atomic
                   commit, keep= GC, template-free restore)
    resumable.py — the engine's phase pipeline split at wave boundaries:
                   ResumableJob / run_resumable stop, snapshot, re-plan
                   under a different W, and resume bit-identically
    regrant.py   — WorkProgress + RegrantCostModel: predicted remaining
                   time under W' + measured snapshot/restore overhead vs
                   remaining time under W ("is this regrant worth it?")
    sim.py       — ElasticCluster: the event-driven simulator grown
                   preempt/resume/regrant events, shrink/grow worker
                   accounting with conservation invariants, and
                   segment-summed telemetry traces

Entry points: the ``predict-elastic`` policy
(:mod:`repro.cluster.policies`), ``python -m repro.launch.cluster
--elastic --policies predict-elastic`` (CLI), ``python -m benchmarks.run
--sections elastic`` (deadline-attainment comparison), and
``examples/elastic_preempt.py`` (engine-level walkthrough).
"""

from repro.elastic.regrant import (
    RegrantCostModel,
    RegrantDecision,
    WorkProgress,
)
from repro.elastic.resumable import ResumableJob, run_resumable
from repro.elastic.sim import (
    ElasticCluster,
    Regrant,
    RunningView,
    SuspendedView,
)
from repro.elastic.snapshot import (
    ElasticState,
    JobCursor,
    load_snapshot,
    save_snapshot,
    state_to_tree,
    tree_to_state,
)

__all__ = [
    "ElasticCluster",
    "ElasticState",
    "JobCursor",
    "Regrant",
    "RegrantCostModel",
    "RegrantDecision",
    "ResumableJob",
    "RunningView",
    "SuspendedView",
    "WorkProgress",
    "load_snapshot",
    "run_resumable",
    "save_snapshot",
    "state_to_tree",
    "tree_to_state",
]
