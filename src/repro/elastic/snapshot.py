"""Wave-boundary job state: cursors, snapshots, checkpoint persistence.

A MapReduce job only has clean interruption points at *wave boundaries* —
between map waves, at the shuffle barrier, and between reduce waves.  This
module defines what the engine's state *is* at such a boundary:

* :class:`JobCursor` — the scalar progress record (which tasks are done,
  whether the shuffle barrier has passed, the monotone wave counter, the
  current worker grant).  Task-denominated, **not** wave-denominated:
  waves are a property of the grant (``ceil(tasks / W)``), and the whole
  point of the elastic layer is that the grant can change mid-flight.
* :class:`ElasticState` — the cursor plus the canonical array buffers
  (map-output accumulators before the shuffle; reduce partitions and
  output accumulators after it).  All buffers are *canonical* — task-major
  with exactly M (or R) rows — so they are grant-independent and a job
  preempted under W resumes bit-identically under W'.
* :func:`save_snapshot` / :func:`load_snapshot` — persistence through the
  existing :class:`repro.checkpoint.manager.CheckpointManager` (atomic
  directory commit, ``keep=`` retention GC, template-free restore).  The
  snapshot is a nested-dict pytree whose leaves are the canonical buffers
  plus one unicode leaf carrying the cursor as JSON, so a snapshot is
  fully self-describing: restore needs only the directory.

The "RNG/counter state" of a job is the cursor's ``waves_executed``
counter — the engine itself is deterministic per task (its only
data-dependent seed is the task input, which is re-derived from the
corpus), so no separate RNG key needs to be carried.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.elastic.regrant import WorkProgress

#: snapshot schema version (bump on layout changes; load refuses unknowns).
SNAPSHOT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class JobCursor:
    """Scalar progress of one job at a wave boundary.

    Identity fields (``app`` .. ``shuffle_backend``) pin the job to its
    configuration — everything except the worker grant is frozen at
    admission.  ``workers`` is the *current* grant and is the only field
    :func:`repro.elastic.resumable.regrant` may change.
    """

    app: str
    input_len: int
    mappers: int
    reducers: int
    workers: int
    combiner: bool
    capacity_factor: float
    setup_rounds: int
    setup_dim: int
    reduce_backend: str
    shuffle_backend: str
    map_tasks_done: int = 0
    combined: bool = False      # map-side combine barrier passed (combiner
    #                             jobs only; defaulted so older snapshots
    #                             load — the barrier is idempotent for the
    #                             combinable op set, so a legacy resume
    #                             stays value-correct)
    shuffled: bool = False
    partition_cap: int = 0      # partition width, fixed at shuffle time
    reduce_tasks_done: int = 0
    waves_executed: int = 0     # monotone step counter (the counter state)
    dropped: int = 0            # shuffle overflow accounting, set at shuffle

    def __post_init__(self):
        if not (0 <= self.map_tasks_done <= self.mappers + self.workers):
            raise ValueError(f"bad cursor {self}")
        if self.workers < 1:
            raise ValueError("cursor workers must be >= 1")

    # ---- progress queries -------------------------------------------------
    # The wave-count arithmetic lives in exactly one place — WorkProgress
    # (the scheduler-side cursor) — so the engine cursor and the regrant
    # cost model can never disagree on what a "remaining wave" is.

    def progress(self) -> WorkProgress:
        return WorkProgress(
            mappers=self.mappers, reducers=self.reducers,
            map_tasks_done=self.map_tasks_done, shuffled=self.shuffled,
            reduce_tasks_done=self.reduce_tasks_done,
            combine_steps=1 if self.combiner else 0,
            combined=self.combined,
        )

    @property
    def done(self) -> bool:
        return self.progress().done

    @property
    def map_done(self) -> bool:
        return self.map_tasks_done >= self.mappers

    def steps_total(self, workers: int | None = None) -> int:
        """Wave-boundary step count for the whole job under a grant:
        map waves + the combine barrier (combiner jobs) + the shuffle
        barrier + reduce waves."""
        return self.progress().steps_total(
            self.workers if workers is None else workers
        )

    def steps_remaining(self, workers: int | None = None) -> int:
        return self.progress().steps_remaining(
            self.workers if workers is None else workers
        )

    # ---- (de)serialization ------------------------------------------------

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["_version"] = SNAPSHOT_VERSION
        return json.dumps(d, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "JobCursor":
        d = json.loads(s)
        version = d.pop("_version", None)
        if version != SNAPSHOT_VERSION:
            raise ValueError(
                f"unsupported snapshot version {version!r} "
                f"(this build reads {SNAPSHOT_VERSION})"
            )
        return JobCursor(**d)


@dataclasses.dataclass
class ElasticState:
    """Cursor + canonical buffers: everything a job needs to resume.

    ``arrays`` keys by phase of life:

    * before the shuffle: ``map_keys``/``map_vals``/``map_valid`` — the
      (M, P) task-major map-output accumulators (rows past
      ``cursor.map_tasks_done`` are still PAD/0/False);
    * from the shuffle on: ``part_keys``/``part_vals`` — (R, cap) reduce
      partitions — and ``out_keys``/``out_vals`` — (R, cap) reduce-output
      accumulators.  The map buffers are dropped at the barrier (their
      content is fully absorbed into the partitions), which also shrinks
      every post-shuffle snapshot.
    """

    cursor: JobCursor
    arrays: dict


def state_to_tree(state: ElasticState) -> dict:
    """Encode a state as a pure nested-dict pytree of numpy leaves.

    The cursor rides along as a 0-d unicode leaf (JSON), which
    ``np.save(allow_pickle=False)`` stores natively — no pickle, no side
    files, and the checkpoint manager's manifest stays the single source
    of truth for the layout.
    """
    return {
        "cursor": np.asarray(state.cursor.to_json()),
        "arrays": {k: np.asarray(v) for k, v in state.arrays.items()},
    }


def tree_to_state(tree: dict) -> ElasticState:
    cursor = JobCursor.from_json(str(np.asarray(tree["cursor"])[()]))
    return ElasticState(cursor=cursor, arrays=dict(tree["arrays"]))


def save_snapshot(manager, state: ElasticState, step: int | None = None,
                  ) -> tuple[int, float]:
    """Persist a wave-boundary snapshot through ``manager`` (a
    :class:`~repro.checkpoint.manager.CheckpointManager`).

    ``step`` defaults to the cursor's ``waves_executed`` counter, so
    successive snapshots of one job land in distinct slots and ``keep=``
    retention applies across them.  Returns ``(step, wall_seconds)`` — the
    measured save overhead is exactly what the regrant cost model charges
    for a preemption (:meth:`repro.elastic.regrant.RegrantCostModel.record_overhead`).
    """
    if step is None:
        step = state.cursor.waves_executed
    t0 = time.perf_counter()
    manager.save(step, state_to_tree(state))
    return step, time.perf_counter() - t0


def load_snapshot(manager, step: int | None = None,
                  ) -> tuple[ElasticState, int, float]:
    """Restore a snapshot (latest by default): (state, step, wall_seconds).

    Template-free: the checkpoint manifest carries the key-paths, shapes
    and dtypes, so the restoring process needs no knowledge of the grant
    the job was preempted under.
    """
    t0 = time.perf_counter()
    tree, step = manager.restore(step, like=None)
    return tree_to_state(tree), step, time.perf_counter() - t0
