"""Elastic cluster simulation: preempt / resume / regrant events.

:class:`ElasticCluster` extends the event-driven simulator with the one
capability the base :class:`~repro.cluster.cluster.Cluster` lacks: a
dispatched job's worker grant is no longer frozen.  A policy may answer a
scheduling event with a :class:`Regrant` action; the simulator applies it
at the job's **next wave boundary** (a job cannot stop mid-wave), charges
the configured snapshot + restore overhead, requantizes the remaining
tasks into waves of the new grant through the oracle's
``remaining_segments``, and reschedules the job's completion.

Mechanics and invariants:

* jobs run as a schedule of wave-boundary segments (from
  ``oracle.remaining_segments``); progress is tracked in task space
  (:class:`~repro.elastic.regrant.WorkProgress`) and advanced lazily;
* a **shrink** releases ``W - W'`` workers at the boundary; a **grow**
  reserves ``W' - W`` from the free pool at request time (so concurrent
  decisions cannot oversubscribe) and applies at the boundary;
* worker conservation — ``free + Σ granted + Σ reserved == total`` — is
  asserted after every mutation, every job completes exactly once, and a
  job's recorded segments tile its [start, finish] interval exactly
  (checkpoint/restore gaps are the only holes, and they are charged to
  ``JobRecord.overhead_s``);
* completed jobs carry a synthesized per-phase :class:`JobTrace` whose
  map/shuffle/reduce walls are summed across *all* executed segments,
  with preemption overhead recorded as a separate ``regrant`` phase — so
  the online per-phase refit loop keeps fitting on interrupted runs;
* a policy that never regrants reproduces the base simulator's schedule
  decision-for-decision (segment walls sum to the same oracle times
  modulo float associativity) — tested in ``tests/test_elastic.py``.

Beyond in-place regrants, two capabilities ride on the same machinery:

* **suspend-to-disk** — ``Regrant(job_id, workers=0)`` snapshots the job
  at its next boundary, releases its *whole* grant, and parks it in a
  suspended queue (:meth:`ElasticCluster.suspended_jobs`); a later
  ``Regrant(job_id, W>=1)`` restores it and re-plans the remaining waves
  under the new grant.  Suspended wall time is accounted as its own
  ``suspended`` trace phase so phase walls still tile the turnaround;
* **measured-overhead scheduling** — when the oracle exposes
  ``regrant_overhead`` (the EngineOracle: a real ``save_snapshot`` /
  ``load_snapshot`` round-trip on the live engine), every preemption is
  charged the *measured* walls instead of the configured estimates, and
  the pair is fed to the policy's ``observe_overhead`` hook so its
  :class:`~repro.elastic.regrant.RegrantCostModel` EWMA tracks real
  checkpoint costs.

Policies discover elastic support via ``cluster.supports_elastic`` and
inspect in-flight work through :meth:`ElasticCluster.running_jobs`, which
exposes only scheduler-observable facts (grants, wave progress, pending
regrants) — never oracle truth about future segment durations.
"""

from __future__ import annotations

import dataclasses
import heapq
import math

from repro.cluster.cluster import (
    Cluster,
    Dispatch,
    JobRecord,
    Reject,
    TraceResult,
    _JobSource,
)
from repro.cluster.workload import JobSpec
from repro.elastic.regrant import WorkProgress


@dataclasses.dataclass(frozen=True)
class Regrant:
    """Policy action: change a running job's grant to ``workers`` at its
    next wave boundary (shrink frees the difference there; grow reserves
    it from the free pool now).

    ``workers=0`` **suspends to disk**: at the boundary the job is
    snapshotted, its whole grant is released, and it leaves the running
    set for the suspended queue (``ElasticCluster.suspended_jobs``).  A
    later ``Regrant(job_id, W>=1)`` addressed at a suspended job restores
    the snapshot and re-plans the remaining waves under the new grant —
    the engine side of this is ``save_snapshot``/``load_snapshot`` +
    ``ResumableJob.regrant``, which the simulator prices.
    """

    job_id: int
    workers: int
    reason: str = ""

    def __post_init__(self):
        if self.workers < 0:
            raise ValueError(f"bad regrant {self}")


@dataclasses.dataclass(frozen=True)
class RunningView:
    """Scheduler-observable state of one running job."""

    job_id: int
    spec: JobSpec
    plan: object                 # the admission Plan (M, R fixed for life)
    workers: int                 # current grant
    pending_workers: int | None  # grant a pending regrant will apply
    shrunk_from: int | None      # pre-shrink grant, if currently shrunk
    progress: WorkProgress
    started: float

    @property
    def steps_remaining(self) -> int:
        return self.progress.steps_remaining(self.workers)


@dataclasses.dataclass(frozen=True)
class SuspendedView:
    """Scheduler-observable state of one suspended-to-disk job."""

    job_id: int
    spec: JobSpec
    plan: object                 # the admission Plan (M, R fixed for life)
    workers_before: int          # grant held when the suspend applied
    progress: WorkProgress
    suspended_at: float


@dataclasses.dataclass
class _Running:
    spec: JobSpec
    rec: JobRecord
    workers: int
    #: remaining wave-boundary segments [(kind, duration), ...]
    segments: list
    seg_start: float             # absolute start time of segments[0]
    m_done: int = 0
    combined: bool = False       # combine barrier passed (combiner jobs)
    shuffled: bool = False
    r_done: int = 0
    pending: tuple[int, float] | None = None   # (new_W, boundary time)
    reserved: int = 0            # grow workers held from the free pool
    shrunk_from: int | None = None
    epoch: int = 0               # invalidates stale heap events
    phase_wall: dict = dataclasses.field(default_factory=dict)
    # Suspend-to-disk bookkeeping (set while the job sits in _suspended).
    suspended_at: float | None = None
    workers_at_suspend: int = 0
    save_charged: float = 0.0    # snapshot wall charged at suspend time
    pending_restore_s: float = 0.0

    def progress(self) -> WorkProgress:
        return WorkProgress(
            mappers=self.rec.plan.mappers,
            reducers=self.rec.plan.reducers,
            map_tasks_done=self.m_done,
            shuffled=self.shuffled,
            reduce_tasks_done=self.r_done,
            combine_steps=(
                1 if getattr(self.rec.plan, "combiner", False) else 0
            ),
            combined=self.combined,
        )

    def advance(self, t: float) -> None:
        """Consume segments ending at or before ``t`` (progress + walls)."""
        M = self.rec.plan.mappers
        R = self.rec.plan.reducers
        while self.segments:
            kind, dur = self.segments[0]
            start = self.seg_start
            end = start + dur
            if end > t:
                break
            self.segments.pop(0)
            self.seg_start = end
            self.phase_wall[kind] = self.phase_wall.get(kind, 0.0) + dur
            # Wave log for the span exporter: each consumed segment is one
            # executed wave; boundaries reuse the exact event-time floats,
            # so waves tile their execution segment with no slack.
            if self.rec.waves is not None:
                self.rec.waves.append([start, end, kind, self.workers])
            if kind == "map":
                self.m_done = min(M, self.m_done + self.workers)
            elif kind == "combine":
                self.combined = True
            elif kind == "shuffle":
                self.shuffled = True
            else:
                self.r_done = min(R, self.r_done + self.workers)

    def finish_time(self) -> float:
        t = self.seg_start
        for _, dur in self.segments:
            t += dur
        return t

    def next_boundary(self) -> float:
        return self.seg_start + self.segments[0][1]


class ElasticCluster(Cluster):
    """The event-driven simulator, with regrantable worker grants."""

    supports_elastic = True

    def __init__(
        self,
        total_workers: int,
        oracle,
        *,
        snapshot_overhead_s: float = 0.02,
        restore_overhead_s: float = 0.02,
        metrics=None,
    ):
        super().__init__(total_workers, oracle, metrics=metrics)
        if not hasattr(oracle, "remaining_segments"):
            raise TypeError(
                f"{type(oracle).__name__} cannot price partial execution; "
                "ElasticCluster needs oracle.remaining_segments"
            )
        if snapshot_overhead_s < 0 or restore_overhead_s < 0:
            raise ValueError("overheads must be >= 0")
        self.snapshot_overhead_s = float(snapshot_overhead_s)
        self.restore_overhead_s = float(restore_overhead_s)
        #: measured-overhead scheduling: an oracle exposing
        #: ``regrant_overhead`` (EngineOracle: a real save/load snapshot
        #: round-trip) prices each preemption with *measured* walls; the
        #: configured costs above are the fallback (AnalyticOracle).
        self._measure_overhead = getattr(oracle, "regrant_overhead", None)

    def _regrant_overheads(self, rj: "_Running") -> tuple[float, float]:
        """(save_s, restore_s) for preempting ``rj`` now — measured from
        the engine when the oracle can, configured otherwise."""
        if self._measure_overhead is None:
            return self.snapshot_overhead_s, self.restore_overhead_s
        rec = rj.rec
        save_s, restore_s = self._measure_overhead(
            rj.spec.app, rec.plan.backend, rj.spec.size,
            rec.plan.mappers, rec.plan.reducers,
            map_tasks_done=rj.m_done, shuffled=rj.shuffled,
            reduce_tasks_done=rj.r_done,
            **self._combine_kwargs(rec.plan),
        )
        return float(save_s), float(restore_s)

    @staticmethod
    def _combine_kwargs(plan, rj: "_Running | None" = None) -> dict:
        """Combiner kwargs for oracle calls — only when the plan turns
        the combiner on, so combiner-unaware oracles keep working."""
        if not getattr(plan, "combiner", False):
            return {}
        extra = {"combiner": True}
        if rj is not None:
            extra["combined"] = rj.combined
        return extra

    @staticmethod
    def _notify_overhead(policy, save_s: float, restore_s: float) -> None:
        """Feed one (snapshot, restore) wall pair to the policy's cost
        model (``observe_overhead`` is optional — see
        :meth:`repro.elastic.regrant.RegrantCostModel.record_overhead`)."""
        hook = getattr(policy, "observe_overhead", None)
        if hook is not None:
            hook(save_s, restore_s)

    # ------------------------------------------------------------- queries

    def suspended_jobs(self, now: float | None = None,
                       ) -> tuple[SuspendedView, ...]:
        """Jobs currently suspended to disk (grant 0), oldest first."""
        views = [
            SuspendedView(
                job_id=rj.spec.job_id,
                spec=rj.spec,
                plan=rj.rec.plan,
                workers_before=rj.workers_at_suspend,
                progress=rj.progress(),
                suspended_at=rj.suspended_at,
            )
            for rj in self._suspended.values()
        ]
        return tuple(sorted(views, key=lambda v: v.suspended_at))

    def running_jobs(self, now: float) -> tuple[RunningView, ...]:
        views = []
        for rj in self._running.values():
            rj.advance(now)
            views.append(RunningView(
                job_id=rj.spec.job_id,
                spec=rj.spec,
                plan=rj.rec.plan,
                workers=rj.workers,
                pending_workers=rj.pending[0] if rj.pending else None,
                shrunk_from=rj.shrunk_from,
                progress=rj.progress(),
                started=rj.rec.start,
            ))
        return tuple(views)

    # ----------------------------------------------------------- invariant

    def _check_conservation(self) -> None:
        granted = sum(rj.workers for rj in self._running.values())
        reserved = sum(rj.reserved for rj in self._running.values())
        if self._free < 0 or (
            self._free + granted + reserved != self.total_workers
        ):
            raise AssertionError(
                f"worker accounting broken: free={self._free} "
                f"granted={granted} reserved={reserved} "
                f"total={self.total_workers}"
            )

    # ------------------------------------------------------------ the loop

    def run(self, jobs: list[JobSpec], policy) -> TraceResult:
        jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
        if len({j.job_id for j in jobs}) != len(jobs):
            raise ValueError("duplicate job_id in trace")
        return self._run(jobs, policy, sorted({j.app for j in jobs}))

    def _run(
        self, jobs, policy, apps, *, health_every=None, on_health=None
    ) -> TraceResult:
        source = _JobSource(jobs)
        records: dict[int, JobRecord] = {}
        order: list[int] = []
        pending: list[JobSpec] = []
        self._running: dict[int, _Running] = {}
        self._suspended: dict[int, _Running] = {}
        self._free = self.total_workers
        #: event heap: (time, seq, kind, job_id, epoch)
        self._events: list[tuple[float, int, str, int, int]] = []
        self._seq = 0
        policy.prepare(self, apps)
        first = source.peek()
        now = first.arrival if first is not None else 0.0
        next_health = (
            now + health_every if health_every is not None else None
        )
        stalled = False  # nothing scheduled, but suspended/pending remain
        if self.metrics is not None:
            self.metrics.on_run_start(now)

        while (
            source.peek() is not None or pending
            or self._running or self._suspended
        ):
            nxt = source.peek()
            next_arrival = nxt.arrival if nxt is not None else math.inf
            next_event = self._events[0][0] if self._events else math.inf
            if (
                (pending or self._suspended) and not self._running
                and next_arrival == math.inf and next_event == math.inf
            ):
                # No arrival or event will ever come.  Give the policy
                # one last pass at the current time (it may resume a
                # suspended job or dispatch into the now-free pool);
                # a second stalled pass means it never will.
                if stalled:
                    stuck = sorted(
                        [j.job_id for j in pending]
                        + list(self._suspended)
                    )
                    raise RuntimeError(
                        f"policy {policy.name!r} stranded jobs {stuck}: "
                        f"no dispatch at free={self._free}/"
                        f"{self.total_workers} workers"
                        + (
                            f" ({sorted(self._suspended)} suspended to "
                            "disk and never resumed)"
                            if self._suspended else ""
                        )
                    )
                stalled = True
            else:
                stalled = False
                now = min(next_arrival, next_event)

            while (nxt := source.peek()) is not None and nxt.arrival <= now:
                job = source.pop()
                records[job.job_id] = JobRecord(spec=job)
                order.append(job.job_id)
                pending.append(job)
                if self.metrics is not None:
                    self.metrics.on_arrival(job.arrival, job)
            while self._events and self._events[0][0] <= now:
                t, _, kind, job_id, epoch = heapq.heappop(self._events)
                rj = self._running.get(job_id)
                if rj is None or rj.epoch != epoch:
                    continue    # stale (superseded by a regrant)
                if kind == "finish":
                    self._complete(rj, t, policy)
                else:
                    self._apply_regrant(rj, t, policy)

            while pending:
                decision = policy.select(tuple(pending), self._free, now)
                if decision is None:
                    break
                if isinstance(decision, Reject):
                    rec = records[decision.job.job_id]
                    rec.admitted = False
                    rec.reject_reason = decision.reason
                    rec.reject_time = now
                    pending.remove(decision.job)
                    if self.metrics is not None:
                        self.metrics.on_reject(now, rec)
                    continue
                if isinstance(decision, Regrant):
                    self._request_regrant(decision, now)
                    continue
                if not isinstance(decision, Dispatch):
                    raise TypeError(
                        f"policy returned {type(decision).__name__}; "
                        "expected Dispatch, Reject, Regrant, or None"
                    )
                job, plan = decision.job, decision.plan
                if job not in pending:
                    raise ValueError(
                        f"policy dispatched job {job.job_id} not in queue"
                    )
                if plan.workers > self._free:
                    raise ValueError(
                        f"plan for job {job.job_id} wants {plan.workers} "
                        f"workers but only {self._free} are free"
                    )
                pending.remove(job)
                self._dispatch(records[job.job_id], job, plan, now)

            # The dispatch loop above only runs while jobs are queued,
            # but elastic moves are also warranted on an *empty* queue —
            # canonically a regrow right after the last queued job left.
            # Elastic-aware policies expose them via ``idle``.
            idle = getattr(policy, "idle", None)
            if idle is not None:
                while True:
                    action = idle(self._free, now)
                    if action is None:
                        break
                    if not isinstance(action, Regrant):
                        raise TypeError(
                            f"policy idle() returned "
                            f"{type(action).__name__}; expected Regrant "
                            "or None"
                        )
                    self._request_regrant(action, now)
            if self.metrics is not None:
                self.metrics.sample(
                    now, len(pending), self.total_workers - self._free,
                    len(self._suspended),
                )
            if next_health is not None and now >= next_health:
                if on_health is not None:
                    on_health(
                        now,
                        self._health_snapshot(
                            now, pending, self._free, len(self._suspended)
                        ),
                    )
                while next_health <= now:
                    next_health += health_every

        if self._free != self.total_workers:
            raise AssertionError("worker accounting leaked")
        return TraceResult(
            policy=policy.name,
            total_workers=self.total_workers,
            records=[records[job_id] for job_id in order],
        )

    # ------------------------------------------------------------- actions

    def _push(self, t: float, kind: str, job_id: int, epoch: int) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, job_id, epoch))

    def _dispatch(self, rec: JobRecord, job: JobSpec, plan, now: float,
                  ) -> None:
        rec.plan = plan
        rec.start = now
        rec.segments = [[now, None, plan.workers]]
        rec.waves = []
        rec.gaps = []
        segments = [
            list(seg) for seg in self.oracle.remaining_segments(
                job.app, plan.backend, job.size,
                plan.mappers, plan.reducers, plan.workers,
                job_id=job.job_id,
                **self._combine_kwargs(plan),
            )
        ]
        rj = _Running(
            spec=job, rec=rec, workers=plan.workers,
            segments=segments, seg_start=now,
        )
        self._running[job.job_id] = rj
        self._free -= plan.workers
        self._push(rj.finish_time(), "finish", job.job_id, rj.epoch)
        if self.metrics is not None:
            self.metrics.on_dispatch(now, rec)
        self._check_conservation()

    def _request_regrant(self, action: Regrant, now: float) -> None:
        rj = self._running.get(action.job_id)
        if rj is None:
            srj = self._suspended.get(action.job_id)
            if srj is not None:
                self._resume(srj, action, now)
                return
            raise ValueError(
                f"regrant for job {action.job_id}, which is not running"
            )
        if rj.pending is not None:
            raise ValueError(
                f"job {action.job_id} already has a pending regrant"
            )
        if action.workers == rj.workers:
            raise ValueError(
                f"regrant to the current grant ({rj.workers}) is a no-op"
            )
        rj.advance(now)
        if len(rj.segments) <= 1:
            raise ValueError(
                f"job {action.job_id} is in its final wave; a regrant "
                "could never take effect (check steps_remaining first)"
            )
        delta = action.workers - rj.workers
        if delta > 0:
            if delta > self._free:
                raise ValueError(
                    f"grow of job {action.job_id} wants {delta} more "
                    f"workers but only {self._free} are free"
                )
            self._free -= delta
            rj.reserved = delta
        boundary = rj.next_boundary()
        rj.pending = (action.workers, boundary)
        self._push(boundary, "regrant", action.job_id, rj.epoch)
        self._check_conservation()

    def _apply_regrant(self, rj: _Running, t: float, policy) -> None:
        rj.advance(t)
        new_w, _ = rj.pending
        rj.pending = None
        old_w = rj.workers
        save_s, restore_s = self._regrant_overheads(rj)
        self._notify_overhead(policy, save_s, restore_s)
        if new_w == 0:
            self._suspend(rj, t, old_w, save_s, restore_s)
            return
        overhead = save_s + restore_s
        resume_t = t + overhead
        if new_w < old_w:
            self._free += old_w - new_w
            rj.shrunk_from = (
                rj.shrunk_from if rj.shrunk_from is not None else old_w
            )
        else:
            rj.reserved = 0
            if rj.shrunk_from is not None and new_w >= rj.shrunk_from:
                rj.shrunk_from = None
        rj.workers = new_w
        rj.epoch += 1
        rec = rj.rec
        rec.segments[-1][1] = t
        rec.segments.append([resume_t, None, new_w])
        if rec.gaps is not None and resume_t > t:
            # The snapshot/restore hole between segments: workers held
            # (the post-regrant grant) but no waves execute.
            rec.gaps.append([t, resume_t, "regrant", new_w])
        rec.n_regrants += 1
        rec.overhead_s += overhead
        rj.phase_wall["regrant"] = (
            rj.phase_wall.get("regrant", 0.0) + overhead
        )
        if self.metrics is not None:
            self.metrics.on_regrant(
                t, "shrink" if new_w < old_w else "grow", overhead
            )
        rj.segments = [
            list(seg) for seg in self.oracle.remaining_segments(
                rj.spec.app, rec.plan.backend, rj.spec.size,
                rec.plan.mappers, rec.plan.reducers, new_w,
                map_tasks_done=rj.m_done, shuffled=rj.shuffled,
                reduce_tasks_done=rj.r_done,
                job_id=rj.spec.job_id,
                **self._combine_kwargs(rec.plan, rj),
            )
        ]
        if not rj.segments:
            raise AssertionError(
                "regrant applied at a boundary with no remaining work"
            )
        rj.seg_start = resume_t
        self._push(rj.finish_time(), "finish", rj.spec.job_id, rj.epoch)
        self._check_conservation()

    # ------------------------------------------------- suspend-to-disk

    def _suspend(self, rj: _Running, t: float, old_w: int,
                 save_s: float, restore_s: float) -> None:
        """Apply a grant-0 regrant at a boundary: snapshot (charge
        ``save_s``), release the whole grant, move the job to the
        suspended queue.  No segments are scheduled until a resume
        re-plans the remaining waves."""
        del self._running[rj.spec.job_id]
        self._free += old_w
        rec = rj.rec
        rec.segments[-1][1] = t
        rec.n_regrants += 1
        rec.n_suspends += 1
        rec.overhead_s += save_s
        rj.phase_wall["regrant"] = (
            rj.phase_wall.get("regrant", 0.0) + save_s
        )
        rj.epoch += 1            # invalidate the stale finish event
        rj.workers = 0
        rj.reserved = 0
        rj.suspended_at = t
        rj.workers_at_suspend = old_w
        rj.save_charged = save_s
        rj.pending_restore_s = restore_s
        rj.segments = []
        self._suspended[rj.spec.job_id] = rj
        if self.metrics is not None:
            self.metrics.on_regrant(t, "suspend", save_s)
            self.metrics.on_suspend(t, save_s)
        self._check_conservation()

    def _resume(self, rj: _Running, action: Regrant, now: float) -> None:
        """Restore a suspended job under ``action.workers`` (charge the
        restore wall), re-plan its remaining waves, reschedule."""
        W = action.workers
        if W < 1:
            raise ValueError(
                f"job {action.job_id} is already suspended; resume it "
                "with workers >= 1"
            )
        if W > self._free:
            raise ValueError(
                f"resume of job {action.job_id} wants {W} workers but "
                f"only {self._free} are free"
            )
        restore_s = rj.pending_restore_s
        resume_t = now + restore_s
        del self._suspended[rj.spec.job_id]
        self._free -= W
        rec = rj.rec
        rec.n_regrants += 1
        rec.overhead_s += restore_s
        rec.segments.append([resume_t, None, W])
        if rec.gaps is not None:
            # Tile the suspend hole: snapshot (no workers), disk wait
            # (no workers), restore (the resume grant) — contiguous with
            # the surrounding execution segments.
            save_end = min(now, rj.suspended_at + rj.save_charged)
            if save_end > rj.suspended_at:
                rec.gaps.append(
                    [rj.suspended_at, save_end, "regrant", 0]
                )
            if now > save_end:
                rec.gaps.append([save_end, now, "suspended", 0])
            if resume_t > now:
                rec.gaps.append([now, resume_t, "regrant", W])
        rj.phase_wall["regrant"] = (
            rj.phase_wall.get("regrant", 0.0) + restore_s
        )
        # Disk-queued wall: the gap between suspend and resume that is
        # not checkpoint overhead (keeps phase walls tiling the
        # turnaround for the synthesized trace).
        rj.phase_wall["suspended"] = rj.phase_wall.get(
            "suspended", 0.0
        ) + max(0.0, now - rj.suspended_at - rj.save_charged)
        if rj.shrunk_from is None and W < rj.workers_at_suspend:
            rj.shrunk_from = rj.workers_at_suspend
        elif rj.shrunk_from is not None and W >= rj.shrunk_from:
            rj.shrunk_from = None
        rj.workers = W
        rj.suspended_at = None
        rj.save_charged = 0.0
        rj.pending_restore_s = 0.0
        rj.epoch += 1
        rj.segments = [
            list(seg) for seg in self.oracle.remaining_segments(
                rj.spec.app, rec.plan.backend, rj.spec.size,
                rec.plan.mappers, rec.plan.reducers, W,
                map_tasks_done=rj.m_done, shuffled=rj.shuffled,
                reduce_tasks_done=rj.r_done,
                job_id=rj.spec.job_id,
                **self._combine_kwargs(rec.plan, rj),
            )
        ]
        if not rj.segments:
            raise AssertionError(
                "resume applied with no remaining work"
            )
        rj.seg_start = resume_t
        self._running[rj.spec.job_id] = rj
        self._push(rj.finish_time(), "finish", rj.spec.job_id, rj.epoch)
        if self.metrics is not None:
            self.metrics.on_regrant(now, "resume", restore_s)
            self.metrics.on_resume(now, restore_s)
        self._check_conservation()

    def _complete(self, rj: _Running, t: float, policy) -> None:
        rj.advance(t)
        if rj.segments or not rj.progress().done:
            raise AssertionError(
                f"job {rj.spec.job_id} completed with work remaining"
            )
        del self._running[rj.spec.job_id]
        self._free += rj.workers
        rec = rj.rec
        rec.finish = t
        rec.true_time = t - rec.start
        rec.segments[-1][1] = t
        rec.trace = self._synthesize_trace(rj)
        if self.metrics is not None:
            self.metrics.on_finish(t, rec)
        policy.observe(rec)
        self._check_conservation()

    # ----------------------------------------------------------- telemetry

    def _synthesize_trace(self, rj: _Running):
        """Segment-summed per-phase trace of one (possibly interrupted)
        job, in the engine's JobTrace shape — preemption overhead is its
        own ``regrant`` phase so phase walls still sum to the turnaround."""
        from repro.telemetry.trace import JobTrace

        rec = rj.rec
        trace = JobTrace(
            app=rj.spec.app,
            config={
                "num_mappers": rec.plan.mappers,
                "num_reducers": rec.plan.reducers,
                "num_workers": rec.plan.workers,
                "final_workers": rj.workers,
                "reduce_backend": rec.plan.backend,
                "input_len": int(rj.spec.size),
                "n_regrants": rec.n_regrants,
                "segments": [list(s) for s in rec.segments],
            },
        )
        counters = {
            "map": {"tasks": rec.plan.mappers},
            "combine": {"tasks": rec.plan.mappers},
            "shuffle": {"partitions": rec.plan.reducers},
            "reduce": {"tasks": rec.plan.reducers},
            "regrant": {"events": rec.n_regrants},
            "suspended": {"events": rec.n_suspends},
        }
        for kind in (
            "map", "combine", "shuffle", "reduce", "regrant", "suspended"
        ):
            wall = rj.phase_wall.get(kind)
            if wall:
                trace.record_phase(kind, wall, **counters[kind])
        trace.finish(rec.true_time)
        return trace
