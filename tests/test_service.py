"""Service mode: open-ended streams, windowed SLO metrics, overload control.

Four layers under test, bottom-up:

* **streams** — every arrival process / job stream is seed-deterministic
  and restartable (two iterations, or two identically-configured
  instances, yield the identical job sequence), and the Poisson thinning
  sampler rejects rate functions that escape their envelope;
* **windows** — the bucketed sliding-window quantile agrees exactly with
  a from-scratch recompute over its own retained span while buckets are
  exact (≤ 5 observations), stays within the P² approximation bounds
  when dense, and expires old observations;
* **SLO monitor** — the multi-window burn-rate state machine trips only
  on sustained two-window burn with enough evidence, clears when the
  budget recovers, and keeps honest lifetime error-budget accounts;
* **control loop** — the alarm-driven controller sheds from the queue
  head down to its floor, opens/closes the suspend valve, leaves no job
  stranded, and on a flash-crowd stream beats the no-admission baseline
  on tail latency while the auditable decision log explains every move.
"""

from __future__ import annotations

import json
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    JobStream,
    PoissonProcess,
    RenewalProcess,
    constant_rate,
    diurnal_rate,
    flash_crowd_rate,
    get_policy,
    merge_processes,
    take,
)
from repro.cluster.cluster import Reject
from repro.cluster.workload import JobSpec
from repro.elastic import ElasticCluster
from repro.obs import (
    ClusterMetrics,
    ControlledPolicy,
    EwmaRate,
    OverloadController,
    RollingSum,
    SLOMonitor,
    SLOPolicy,
    StaticAdmission,
    WindowedQuantile,
)


def exact_quantile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]


# ------------------------------------------------------------------ streams


class TestStreams:
    def test_poisson_process_restartable(self):
        p = PoissonProcess(1.5, seed=3)
        assert take(p, 50) == take(p, 50)

    def test_identical_streams_identical_jobs(self):
        def make():
            return JobStream(
                PoissonProcess(
                    diurnal_rate(1.0, amplitude=0.4),
                    peak_rate=1.4, seed=9,
                ),
                seed=9,
            )

        a, b = make(), make()
        jobs_a, jobs_b = take(a, 80), take(b, 80)
        assert jobs_a == jobs_b
        assert take(a, 80) == jobs_a          # re-iteration too
        assert [j.job_id for j in jobs_a] == list(range(80))
        arr = [j.arrival for j in jobs_a]
        assert arr == sorted(arr)

    def test_poisson_envelope_violation_raises(self):
        p = PoissonProcess(lambda t: 2.0, peak_rate=1.0, seed=0)
        with pytest.raises(ValueError, match="envelope"):
            take(p, 5)

    def test_poisson_needs_peak_for_callable(self):
        with pytest.raises(ValueError, match="peak_rate"):
            PoissonProcess(lambda t: 1.0, seed=0)

    def test_rate_fn_validation(self):
        with pytest.raises(ValueError):
            constant_rate(-1.0)
        with pytest.raises(ValueError):
            diurnal_rate(1.0, amplitude=1.5)
        with pytest.raises(ValueError):
            flash_crowd_rate(1.0, [(10.0, 5.0, 2.0)])

    def test_flash_crowd_rate_steps(self):
        f = flash_crowd_rate(2.0, [(10.0, 20.0, 3.0)])
        assert f(5.0) == 2.0
        assert f(10.0) == 6.0
        assert f(19.99) == 6.0
        assert f(20.0) == 2.0

    def test_renewal_process_restartable_and_validated(self):
        r = RenewalProcess("bursty", mean_interarrival=0.5, seed=4)
        assert take(r, 40) == take(r, 40)
        with pytest.raises(ValueError, match="unknown arrival"):
            RenewalProcess("weird", mean_interarrival=0.5)

    def test_merge_processes_is_sorted_superposition(self):
        a = PoissonProcess(1.0, seed=1)
        b = PoissonProcess(1.0, seed=2)
        merged = take(merge_processes(iter(a), iter(b)), 60)
        assert merged == sorted(merged)
        # The first 60 merged events are the 60 smallest of the union.
        union = sorted(take(a, 60) + take(b, 60))
        assert merged == union[:60]

    def test_jobstream_deadline_needs_estimate(self):
        with pytest.raises(ValueError, match="service_estimate"):
            JobStream(PoissonProcess(1.0, seed=0), deadline_fraction=0.5)

    def test_jobstream_deadlines_assigned(self):
        s = JobStream(
            PoissonProcess(1.0, seed=5), seed=5,
            deadline_fraction=0.5, service_estimate=lambda j: 2.0,
        )
        jobs = take(s, 100)
        with_dl = [j for j in jobs if j.deadline is not None]
        assert 20 < len(with_dl) < 80
        assert all(j.deadline > j.arrival for j in with_dl)


# -------------------------------------------------------------- run_service


def _stream(seed=7, rate=0.9):
    return JobStream(PoissonProcess(rate, seed=seed), seed=seed)


class TestRunService:
    def test_needs_a_bound(self):
        c = Cluster(4, AnalyticOracle(seed=1))
        with pytest.raises(ValueError, match="unbounded"):
            c.run_service(_stream(), get_policy("fifo-static"))

    def test_until_jobs_exact_count(self):
        c = Cluster(8, AnalyticOracle(seed=1))
        res = c.run_service(
            _stream(), get_policy("fifo-static"), until_jobs=37
        )
        assert len(res.records) == 37
        assert all(r.completed for r in res.records)

    def test_until_time_bounds_arrivals(self):
        c = Cluster(8, AnalyticOracle(seed=1))
        res = c.run_service(
            _stream(), get_policy("fifo-static"), until_time=40.0
        )
        assert res.records
        assert all(r.spec.arrival <= 40.0 for r in res.records)
        assert all(r.completed for r in res.records)

    def test_service_equals_batch_on_bounded_stream(self):
        jobs = take(_stream(), 40)
        r_batch = Cluster(8, AnalyticOracle(seed=2)).run(
            jobs, get_policy("fifo-static")
        )
        r_service = Cluster(8, AnalyticOracle(seed=2)).run_service(
            _stream(), get_policy("fifo-static"), until_jobs=40
        )
        finishes = [r.finish for r in r_batch.records]
        assert finishes == [r.finish for r in r_service.records]

    def test_health_ticks_fire_with_gauges(self):
        snaps = []
        metrics = ClusterMetrics(window_s=20.0)
        c = Cluster(8, AnalyticOracle(seed=1))
        c.metrics = metrics
        c.run_service(
            _stream(), get_policy("fifo-static"), until_jobs=60,
            health_every=10.0,
            on_health=lambda now, s: snaps.append((now, s)),
        )
        assert len(snaps) >= 3
        times = [t for t, _ in snaps]
        assert times == sorted(times)
        for _, s in snaps:
            assert {"t", "queue_depth", "busy_workers",
                    "free_workers"} <= set(s)
        assert any("windowed" in s for _, s in snaps)

    def test_health_every_validated(self):
        c = Cluster(4, AnalyticOracle(seed=1))
        with pytest.raises(ValueError, match="health_every"):
            c.run_service(
                _stream(), get_policy("fifo-static"), until_jobs=5,
                health_every=-1.0,
            )


# ------------------------------------------------------------------ windows


class TestWindowedQuantile:
    @given(gaps=st.lists(st.floats(0.05, 3.0), min_size=10, max_size=60),
           p=st.sampled_from([0.5, 0.9, 0.99]))
    @settings(max_examples=25)
    def test_matches_exact_recompute_over_retained_span(self, gaps, p):
        wq = WindowedQuantile(p, window_s=8.0, n_buckets=4)
        t, obs = 0.0, []
        for i, g in enumerate(gaps):
            t += g
            x = float((i * 37) % 101) + g
            wq.observe(t, x)
            obs.append((t, x))
        now = t
        start = wq.window_start(now)
        win = [(tt, x) for tt, x in obs if tt >= start]
        est = wq.value(now)
        assert est is not None
        vals = [x for _, x in win]
        assert min(vals) <= est <= max(vals)
        # While every live bucket is still exact (<= 5 observations) the
        # merged estimate IS the ceil-index order statistic.
        bucket_s = 8.0 / 4
        per_bucket: dict[int, int] = {}
        for tt, _ in win:
            e = int(math.floor(tt / bucket_s))
            per_bucket[e] = per_bucket.get(e, 0) + 1
        if all(n <= 5 for n in per_bucket.values()):
            assert est == exact_quantile(vals, p)

    def test_dense_window_bounded_error(self):
        import numpy as np

        rng = np.random.default_rng(0)
        wq = WindowedQuantile(0.99, window_s=10.0, n_buckets=8)
        obs = []
        for i in range(800):
            t = i * 0.02
            x = float(rng.random())
            wq.observe(t, x)
            obs.append((t, x))
        now = obs[-1][0]
        win = [x for t, x in obs if t >= wq.window_start(now)]
        assert abs(wq.value(now) - exact_quantile(win, 0.99)) < 0.1

    def test_old_observations_expire(self):
        wq = WindowedQuantile(0.5, window_s=4.0, n_buckets=4)
        wq.observe(0.0, 1000.0)
        for i in range(20):
            wq.observe(10.0 + i * 0.1, 1.0)
        assert wq.value(12.0) == 1.0
        assert wq.window_count(12.0) == 20

    def test_deterministic_across_instances(self):
        a = WindowedQuantile(0.9, window_s=5.0)
        b = WindowedQuantile(0.9, window_s=5.0)
        for i in range(200):
            t, x = i * 0.05, float((i * 13) % 47)
            a.observe(t, x)
            b.observe(t, x)
        assert a.value(10.0) == b.value(10.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            WindowedQuantile(1.5, window_s=5.0)
        with pytest.raises(ValueError):
            WindowedQuantile(0.5, window_s=0.0)
        with pytest.raises(ValueError):
            WindowedQuantile(0.5, window_s=5.0, n_buckets=1)


class TestRatesAndSums:
    def test_ewma_rate_converges_and_decays(self):
        r = EwmaRate(tau_s=5.0)
        for i in range(400):
            r.observe(i * 0.5)          # 2 events/s
        assert r.rate(200.0) == pytest.approx(2.0, rel=0.1)
        assert r.rate(500.0) < 1e-10    # long silence -> decayed away
        with pytest.raises(ValueError):
            EwmaRate(tau_s=0.0)

    def test_rolling_sum_expires(self):
        rs = RollingSum(window_s=10.0, n_buckets=5)
        rs.observe(0.0, 100.0)
        rs.observe(20.0, 1.0)
        rs.observe(21.0, 2.0)
        assert rs.total(21.0) == 3.0
        assert rs.count(21.0) == 2
        assert rs.mean(21.0) == 1.5
        assert rs.rate(21.0) == pytest.approx(0.3)
        assert rs.mean(100.0) is None


# -------------------------------------------------------------- SLO monitor


def _monitor(**kw):
    kw.setdefault("fast_window_s", 10.0)
    kw.setdefault("slow_window_s", 40.0)
    kw.setdefault("min_events", 5)
    return SLOMonitor(SLOPolicy(2.0, objective=0.9), **kw)


class TestSLOMonitor:
    def test_policy_validation(self):
        with pytest.raises(ValueError):
            SLOPolicy(0.0)
        with pytest.raises(ValueError):
            SLOPolicy(1.0, objective=1.0)
        with pytest.raises(ValueError, match="slow window"):
            SLOMonitor(SLOPolicy(2.0), fast_window_s=30, slow_window_s=30)
        with pytest.raises(ValueError, match="clear_burn"):
            SLOMonitor(SLOPolicy(2.0), trip_burn=1.0, clear_burn=2.0)

    def test_is_good_deadline_override(self):
        slo = SLOPolicy(2.0, use_deadlines=True)
        assert slo.is_good(10.0, met_deadline=True)
        assert not slo.is_good(1.0, met_deadline=False)
        assert slo.is_good(1.0, met_deadline=None)   # best-effort fallback
        assert not SLOPolicy(2.0).is_good(10.0, met_deadline=True)

    def test_trip_requires_min_events(self):
        m = _monitor(min_events=50)
        for i in range(20):
            m.observe(i * 0.1, 100.0)   # all bad, but too few
        assert m.update(2.0) is None
        assert not m.tripped

    def test_trip_then_clear_cycle(self):
        m = _monitor()
        for i in range(10):
            m.observe(i * 0.5, 100.0)   # sustained badness
        alarm = m.update(5.0)
        assert alarm is not None and alarm.event == "trip"
        assert m.tripped and m.update(5.1) is None   # no re-fire
        # Far future: both windows empty = budget recovering.
        alarm = m.update(500.0)
        assert alarm is not None and alarm.event == "clear"
        assert not m.tripped
        assert [a.event for a in m.alarms] == ["trip", "clear"]

    def test_burn_rates_and_budget_accounting(self):
        m = _monitor()
        assert m.burn_rates(1.0) == (0.0, 0.0)
        for i in range(8):
            m.observe(i * 0.5, 1.0)     # good
        for i in range(2):
            m.observe(4.0 + i * 0.1, 100.0)  # bad
        fast, slow = m.burn_rates(4.2)
        assert fast == pytest.approx((2 / 10) / 0.1)
        assert slow == pytest.approx((2 / 10) / 0.1)
        b = m.budget()
        assert b["events"] == 10 and b["bad_events"] == 2
        assert b["allowed_bad"] == pytest.approx(1.0)
        assert b["remaining_frac"] == pytest.approx(-1.0)


# ------------------------------------------------------------ control loop


class _InertPolicy:
    name = "inert"

    def __init__(self):
        self.prepared = False
        self.observed = []

    def prepare(self, cluster, apps):
        self.prepared = True

    def select(self, queue, free_workers, now):
        return None

    def observe(self, record):
        self.observed.append(record)


def _specs(n):
    return tuple(
        JobSpec(job_id=i, app="wordcount", size=1 << 14, arrival=float(i))
        for i in range(n)
    )


class TestOverloadController:
    def test_validation(self):
        with pytest.raises(ValueError):
            OverloadController(_monitor(), queue_floor=-1)

    def test_sheds_from_head_down_to_floor_when_tripped(self):
        m = _monitor()
        for i in range(10):
            m.observe(i * 0.5, 100.0)
        ctrl = OverloadController(m, queue_floor=2)
        queue = _specs(5)
        d = ctrl.decide(queue, 0, 5.0)
        assert isinstance(d, Reject) and d.job.job_id == 0   # drop-head
        assert m.tripped
        sheds = [a for a in ctrl.log if a.action == "shed"]
        assert len(sheds) == 1 and sheds[0].job_id == 0
        assert ctrl.log[0].action == "trip"
        # At the floor: no more shedding.
        assert ctrl.decide(_specs(2), 0, 5.1) is None

    def test_admits_everything_when_not_tripped(self):
        ctrl = OverloadController(_monitor(), queue_floor=0)
        assert ctrl.decide(_specs(30), 0, 1.0) is None
        assert ctrl.log == []

    def test_static_admission_caps_tail(self):
        ctrl = StaticAdmission(3)
        d = ctrl.decide(_specs(4), 0, 1.0)
        assert isinstance(d, Reject) and d.job.job_id == 3   # drop-tail
        assert ctrl.decide(_specs(3), 0, 1.1) is None
        assert [a.action for a in ctrl.log] == ["shed"]
        with pytest.raises(ValueError):
            StaticAdmission(-1)

    def test_controlled_policy_delegates(self):
        inner = _InertPolicy()
        ctrl = StaticAdmission(100)
        cp = ControlledPolicy(inner, ctrl)
        assert cp.name == "inert+static-admission"
        cp.prepare(None, ["wordcount"])
        assert inner.prepared
        assert cp.select(_specs(2), 4, 0.0) is None   # falls through
        rec = type("R", (), {"finish": None})()
        cp.observe(rec)
        assert inner.observed == [rec]


# ------------------------------------------------------- end-to-end service


def _flash_stream(seed=11):
    rate = flash_crowd_rate(
        diurnal_rate(0.85, amplitude=0.3, period_s=600.0),
        [(120.0, 200.0, 4.5)],
    )
    return JobStream(
        PoissonProcess(rate, peak_rate=0.85 * 1.3 * 4.5, seed=seed),
        seed=seed,
    )


def _serve(policy):
    metrics = ClusterMetrics(window_s=30.0)
    cluster = ElasticCluster(8, AnalyticOracle(noise=0.02, seed=11))
    cluster.metrics = metrics
    result = cluster.run_service(_flash_stream(), policy, until_jobs=400)
    done = [r for r in result.records if r.completed]
    return result, [r.turnaround for r in done]


class TestServiceEndToEnd:
    def test_burn_control_beats_no_admission_on_flash_crowd(self):
        monitor = SLOMonitor(
            SLOPolicy(6.0, objective=0.95),
            fast_window_s=15.0, slow_window_s=60.0,
            trip_burn=1.5, clear_burn=0.5,
        )
        ctrl = OverloadController(monitor, queue_floor=4, max_suspended=1)
        res_b, turn_b = _serve(
            ControlledPolicy(get_policy("fifo-static"), ctrl)
        )
        _res_n, turn_n = _serve(get_policy("fifo-static"))

        assert any(a.event == "trip" for a in monitor.alarms)
        n_sheds = sum(1 for a in ctrl.log if a.action == "shed")
        assert n_sheds > 0
        assert exact_quantile(turn_b, 0.99) < exact_quantile(turn_n, 0.99)
        # Every decision is audited with the burn rates that justified it.
        assert all(
            a.action in ("trip", "clear", "shed", "suspend", "resume")
            for a in ctrl.log
        )
        shed_ids = {a.job_id for a in ctrl.log if a.action == "shed"}
        rejected = {
            r.spec.job_id for r in res_b.records if not r.admitted
        }
        assert shed_ids == rejected

    def test_suspend_valve_opens_and_no_job_is_stranded(self):
        monitor = SLOMonitor(
            SLOPolicy(6.0, objective=0.95),
            fast_window_s=15.0, slow_window_s=60.0,
            trip_burn=1.5, clear_burn=0.5,
        )
        ctrl = OverloadController(monitor, queue_floor=4, max_suspended=2)
        res, _ = _serve(ControlledPolicy(get_policy("fifo-static"), ctrl))
        suspends = [a for a in ctrl.log if a.action == "suspend"]
        resumes = [a for a in ctrl.log if a.action == "resume"]
        assert suspends, "valve never opened on a 4.5x flash crowd"
        assert len(resumes) >= len(suspends)  # every suspend resumed
        # Drain guarantee: every admitted job completed.
        assert all(r.completed for r in res.records if r.admitted)

    def test_controller_without_elastic_cluster_only_sheds(self):
        monitor = SLOMonitor(
            SLOPolicy(6.0, objective=0.95),
            fast_window_s=15.0, slow_window_s=60.0,
            trip_burn=1.5, clear_burn=0.5,
        )
        ctrl = OverloadController(monitor, queue_floor=4)
        policy = ControlledPolicy(get_policy("fifo-static"), ctrl)
        cluster = Cluster(8, AnalyticOracle(noise=0.02, seed=11))
        res = cluster.run_service(_flash_stream(), policy, until_jobs=400)
        assert all(
            a.action in ("trip", "clear", "shed") for a in ctrl.log
        )
        assert all(r.completed for r in res.records if r.admitted)


# ------------------------------------------------------------------ the CLI


class TestServiceCLI:
    def test_service_mode_writes_prom_and_json(self, tmp_path, capsys):
        from repro.launch.cluster import main

        out_json = tmp_path / "svc.json"
        out_prom = tmp_path / "svc.prom"
        main([
            "--service", "--until-jobs", "60", "--stream", "constant",
            "--rate", "1.2", "--workers", "4", "--admission", "burn",
            "--health-every", "0", "--json", str(out_json),
            "--metrics-out", str(out_prom),
        ])
        table = capsys.readouterr().out
        assert "fifo-static+burn-control" in table
        data = json.loads(out_json.read_text())
        assert data["burn"]["n_arrived"] == 60
        assert data["burn"]["p99_turnaround_s"] > 0
        prom = out_prom.read_text()
        assert "# TYPE" in prom and "jobs_completed" in prom

    def test_service_mode_requires_a_bound(self):
        from repro.launch.cluster import main

        with pytest.raises(SystemExit, match="duration"):
            main(["--service"])
