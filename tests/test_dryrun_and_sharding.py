"""Sharding rules + small-mesh dry-run (subprocess with 8 host devices)."""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.models import transformer as tf
from repro.sharding import rules


class TestParamSpecs:
    def _specs(self, arch="llama3-8b", **kw):
        cfg = smoke_config(arch)
        params = jax.eval_shape(
            lambda k: tf.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        return params, rules.param_specs(params, rules.MeshAxes(), **kw)

    def test_every_leaf_has_matching_rank(self):
        for arch in ("llama3-8b", "jamba-v0.1-52b", "rwkv6-3b",
                     "arctic-480b", "hubert-xlarge"):
            params, specs = self._specs(arch)
            jax.tree.map(
                lambda p, s: None if len(s) == len(p.shape) else
                pytest.fail(f"rank mismatch {s} vs {p.shape}"),
                params, specs,
                is_leaf=lambda x: isinstance(x, P),
            )

    def test_tp_rules_applied(self):
        _, specs = self._specs()
        blk = specs["blocks"]["pos0"]
        assert blk["attn"]["wq"] == P(None, None, "model")
        assert blk["attn"]["wo"] == P(None, "model", None)
        assert blk["ffn"]["w_down"] == P(None, "model", None)
        assert specs["embed"] == P("model", None)

    def test_fsdp_adds_data_axis(self):
        _, plain = self._specs(fsdp=False)
        _, fsdp = self._specs(fsdp=True, fsdp_min_size=8)
        wq_plain = plain["blocks"]["pos0"]["attn"]["wq"]
        wq_fsdp = fsdp["blocks"]["pos0"]["attn"]["wq"]
        assert "data" not in jax.tree.leaves(tuple(wq_plain or ()))
        assert "data" in (wq_fsdp or ())

    def test_divisibility_sanitization(self):
        params, _ = self._specs("hubert-xlarge")
        specs = rules.param_specs(
            params, rules.MeshAxes(),
            mesh_shape={"data": 4, "model": 3},  # 3 divides nothing here
        )
        head = specs["lm_head"]
        assert head == P(None, None)  # vocab_padded 512 % 3 != 0 -> dropped

    def test_moe_expert_parallel(self):
        _, specs = self._specs("arctic-480b")
        moe = specs["blocks"]["pos0"]["moe"]
        assert moe["w_gate"][1] == "model"  # (stack, E, D, F): E on model


class TestDecodeStateSpecs:
    def test_kv_fallback_hierarchy(self):
        cfg = smoke_config("llama3-8b")
        # kv heads = 2, model axis 4 -> heads not divisible -> seq gets model
        state = jax.eval_shape(
            lambda: tf.init_decode_state(cfg, 8, 64)
        )
        specs = rules.decode_state_specs(
            state["layers"], rules.MeshAxes(),
            mesh_shape={"data": 4, "model": 4},
        )
        kv = specs["pos0"]["kv"]["k"]
        assert kv == P(None, "data", "model", None, None)

    def test_batch1_sequence_parallel(self):
        cfg = smoke_config("jamba-v0.1-52b")
        state = jax.eval_shape(
            lambda: tf.init_decode_state(cfg, 1, 256)
        )
        specs = rules.decode_state_specs(
            state["layers"], rules.MeshAxes(),
            mesh_shape={"data": 4, "model": 4},
        )
        kv = specs["pos4"]["kv"]["k"]
        assert kv == P(None, None, ("data", "model"), None, None)


_DRYRUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import repro.configs as C
from repro.launch.mesh import make_mesh
from repro.launch import cells

small = {
    "train_4k": dataclasses.replace(C.SHAPES["train_4k"], seq_len=128,
                                    global_batch=8),
    "prefill_32k": dataclasses.replace(C.SHAPES["prefill_32k"], seq_len=256,
                                       global_batch=4),
    "decode_32k": dataclasses.replace(C.SHAPES["decode_32k"], seq_len=256,
                                      global_batch=8),
    "long_500k": dataclasses.replace(C.SHAPES["long_500k"], seq_len=1024,
                                     global_batch=1),
}
C.SHAPES.clear(); C.SHAPES.update(small)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))  # multi-pod shape
for arch in ("llama3-8b", "granite-moe-1b-a400m", "rwkv6-3b"):
    cfg = dataclasses.replace(C.smoke_config(arch), param_dtype="bfloat16")
    for shape in C.applicable_shapes(cfg):
        r = cells.analyze_cell_extrapolated(arch, shape, mesh, cfg=cfg)
        roof = r["roofline"]
        assert roof["compute_s"] > 0, (arch, shape)
        assert roof["dominant"] in ("compute", "memory", "collective")
        assert r["memory"]["peak_bytes"] > 0
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.slow
def test_small_multipod_dryrun(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SCRIPT],
        capture_output=True, text=True, timeout=580,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": str(tmp_path)},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "DRYRUN_SMALL_OK" in proc.stdout, proc.stderr[-3000:]
