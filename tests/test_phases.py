"""Direct unit coverage of the shared phase primitives: segment reduction
with reduce_op='max', capacity-bounded bucket scatter, and partition
overflow accounting."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.mapreduce import JobConfig, get_shuffle_backend
from repro.mapreduce.phases import (
    PAD_KEY,
    bucket_scatter,
    hash_to_reducer,
    partition_capacity,
    segment_sum_sorted,
)


class TestSegmentSumSortedMax:
    def test_max_per_run(self):
        keys = jnp.asarray([1, 1, 1, 4, 4, 9], jnp.int32)
        vals = jnp.asarray([3, 7, 5, -2, -8, 0], jnp.int32)
        valid = jnp.ones(6, bool)
        ok, ov, first = segment_sum_sorted(keys, vals, valid, "max")
        np.testing.assert_array_equal(
            np.asarray(ok), [1, PAD_KEY, PAD_KEY, 4, PAD_KEY, 9]
        )
        np.testing.assert_array_equal(np.asarray(ov), [7, 0, 0, -2, 0, 0])
        np.testing.assert_array_equal(
            np.asarray(first), [1, 0, 0, 1, 0, 1]
        )

    def test_max_ignores_invalid_tail(self):
        keys = jnp.asarray([2, 2, PAD_KEY, PAD_KEY], jnp.int32)
        vals = jnp.asarray([-5, -9, 1000, 1000], jnp.int32)
        valid = keys != PAD_KEY
        ok, ov, _ = segment_sum_sorted(keys, vals, valid, "max")
        assert int(ov[0]) == -5  # poison values in padding never leak
        assert int(ok[1]) == int(PAD_KEY)

    def test_max_negative_values_not_clamped_to_zero(self):
        keys = jnp.asarray([3, 3], jnp.int32)
        vals = jnp.asarray([-7, -4], jnp.int32)
        ok, ov, _ = segment_sum_sorted(keys, vals, jnp.ones(2, bool), "max")
        assert int(ov[0]) == -4

    def test_unknown_op_rejected(self):
        keys = jnp.asarray([1], jnp.int32)
        with pytest.raises(ValueError):
            segment_sum_sorted(keys, keys, keys != PAD_KEY, "mean")


class TestBucketScatter:
    def test_exact_dropped_count(self):
        # 7 entries for bucket 0, capacity 4 -> exactly 3 dropped.
        ids = jnp.asarray([0] * 7 + [1] * 2, jnp.int32)
        vals = jnp.arange(9, dtype=jnp.int32)
        (out,), dropped = bucket_scatter(
            ids, 2, 2, 4, (vals,), (jnp.int32(-1),)
        )
        assert int(dropped) == 3
        np.testing.assert_array_equal(np.asarray(out[0]), [0, 1, 2, 3])
        np.testing.assert_array_equal(np.asarray(out[1]), [7, 8, -1, -1])

    def test_invalid_ids_not_counted_as_dropped(self):
        ids = jnp.asarray([0, 0, 5, 5, 5], jnp.int32)  # 5 >= n_buckets=2
        vals = jnp.arange(5, dtype=jnp.int32)
        (out,), dropped = bucket_scatter(
            ids, 2, 2, 1, (vals,), (jnp.int32(-1),)
        )
        assert int(dropped) == 1  # only the second bucket-0 entry
        np.testing.assert_array_equal(np.asarray(out), [[0], [-1]])

    def test_padding_rows_stay_at_fill(self):
        ids = jnp.asarray([0, 1], jnp.int32)
        vals = jnp.asarray([10, 20], jnp.int32)
        (out,), dropped = bucket_scatter(
            ids, 2, 4, 2, (vals,), (jnp.int32(-1),)
        )  # rows 2..3 are wave padding
        assert int(dropped) == 0
        np.testing.assert_array_equal(np.asarray(out[2:]), -np.ones((2, 2)))


class TestPartitionOverflowAccounting:
    def test_lexsort_dropped_is_exact(self):
        """All-one-key input: dropped must equal n_valid - capacity."""
        cfg = JobConfig(num_mappers=1, num_reducers=4, capacity_factor=1.0)
        n = 400
        keys = jnp.zeros((n,), jnp.int32)
        vals = jnp.ones((n,), jnp.int32)
        pvalid = jnp.ones((n,), bool)
        backend = get_shuffle_backend("lexsort")
        part_k, part_v, dropped = backend.partition(cfg, keys, vals, pvalid)
        cap = partition_capacity(n, 4, 1.0)
        assert int(dropped) == n - cap
        kept = int((np.asarray(part_k) != int(PAD_KEY)).sum())
        assert kept + int(dropped) == n  # conservation

    def test_generous_capacity_drops_nothing(self):
        cfg = JobConfig(num_mappers=1, num_reducers=4, capacity_factor=8.0)
        rng = np.random.default_rng(3)
        keys = jnp.asarray(rng.integers(0, 50, 300).astype(np.int32))
        vals = jnp.ones((300,), jnp.int32)
        backend = get_shuffle_backend("lexsort")
        _, _, dropped = backend.partition(
            cfg, keys, vals, jnp.ones((300,), bool)
        )
        assert int(dropped) == 0

    def test_partition_capacity_clamps(self):
        assert partition_capacity(100, 4, 1.0) == 25
        assert partition_capacity(100, 4, 100.0) == 100  # never beyond n
        assert partition_capacity(100, 1000, 1.0) == 1  # never below 1


class TestHashToReducer:
    def test_range_and_determinism(self):
        keys = jnp.arange(1000, dtype=jnp.int32)
        rid = np.asarray(hash_to_reducer(keys, 7))
        assert rid.min() >= 0 and rid.max() < 7
        np.testing.assert_array_equal(
            rid, np.asarray(hash_to_reducer(keys, 7))
        )

    def test_spreads_keys(self):
        keys = jnp.arange(10_000, dtype=jnp.int32)
        counts = np.bincount(np.asarray(hash_to_reducer(keys, 8)))
        assert counts.min() > 10_000 / 8 * 0.5  # no starved reducer
