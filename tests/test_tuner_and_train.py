"""Tuner (beyond-paper autotuning) + end-to-end training-loop integration."""


import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import (
    grid,
    mesh_factorizations,
    tune,
    tune_categorical,
    validate,
)
from repro.data import DataConfig
from repro.launch.train import TrainLoopConfig, run_training


class TestTuner:
    def test_finds_optimum_on_smooth_surface(self):
        def cost(p):
            m, r = p[0], p[1]
            return 10 + 0.02 * (m - 22) ** 2 + 0.05 * (r - 9) ** 2

        space = grid([(5, 40, 1), (5, 40, 1)])
        result = tune(cost, space, n_samples=40, seed=1)
        result = validate(result, cost, space)
        assert result.regret_pct < 5.0

    def test_mesh_factorizations(self):
        f = mesh_factorizations(16)
        assert [tuple(map(int, r)) for r in f] == [
            (1, 16), (2, 8), (4, 4), (8, 2), (16, 1)
        ]

    def test_categorical_picks_best_backend(self):
        """One model per category; the joint argmin finds the cheap one."""

        def make_cost(overhead):
            def cost(p):
                m, r = p[0], p[1]
                return overhead + 0.02 * (m - 22) ** 2 + 0.05 * (r - 9) ** 2
            return cost

        space = grid([(5, 40, 1), (5, 40, 1)])
        result = tune_categorical(
            {"slow": make_cost(30.0), "fast": make_cost(5.0)},
            space, n_samples=40, seed=1,
        )
        assert result.best_category == "fast"
        assert set(result.per_category) == {"slow", "fast"}
        times = result.predicted_times()
        assert times["fast"] < times["slow"]
        # the numeric optimum is still found within the winning category
        m, r = result.best_config
        assert abs(m - 22) <= 3 and abs(r - 9) <= 3

    def test_categorical_empty_rejected(self):
        import pytest

        with pytest.raises(ValueError, match="category"):
            tune_categorical({}, grid([(5, 40, 5), (5, 40, 5)]))

    def test_sample_budget_respected(self):
        calls = []

        def cost(p):
            calls.append(tuple(p))
            return float(p[0] + p[1])

        space = grid([(5, 40, 5), (5, 40, 5)])
        tune(cost, space, n_samples=20, seed=0)
        assert len(set(calls)) <= 24  # sample + top-up only, not the space


class TestTrainLoop:
    def test_loss_decreases_and_failure_recovery(self, tmp_path):
        cfg = smoke_config("qwen3-0.6b")
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=8, structure=0.9)
        out = run_training(
            cfg, data,
            TrainLoopConfig(
                steps=100, ckpt_dir=str(tmp_path), ckpt_every=20,
                log_every=0, fail_at_step=50, lr=3e-3,
            ),
        )
        assert out["last_step"] == 100
        assert out["losses"][-1] < out["losses"][0] - 0.3
        # failure at step 50 restored from step 40: extra replayed steps
        assert len(out["losses"]) > 100 - 1

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        cfg = smoke_config("qwen3-0.6b")
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
        run_training(cfg, data, TrainLoopConfig(
            steps=10, ckpt_dir=str(tmp_path), ckpt_every=5, log_every=0))
        out2 = run_training(cfg, data, TrainLoopConfig(
            steps=12, ckpt_dir=str(tmp_path), log_every=0))
        assert out2["last_step"] == 12
        assert len(out2["losses"]) == 2  # only steps 10..12 re-run

    def test_deterministic_replay(self, tmp_path):
        """Same seed + same data cursor -> identical loss trajectory."""
        cfg = smoke_config("qwen3-0.6b")
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4, seed=7)
        a = run_training(cfg, data, TrainLoopConfig(steps=5, log_every=0))
        b = run_training(cfg, data, TrainLoopConfig(steps=5, log_every=0))
        np.testing.assert_allclose(a["losses"], b["losses"], rtol=1e-6)
