"""ExecutionPlan mode equivalence: the one property suite.

Every execution mode — fused, traced, resumable (preempted at *every*
wave boundary), and the sharded mesh path — derives from the same
lowered plan, so bit-exact agreement is a property of construction.
This suite checks it once, for every reduce × shuffle backend
combination, replacing the per-path equivalence copies that used to
live in ``test_backends.py`` / ``test_elastic.py``.
"""

import time as _time
from collections import Counter

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.elastic import load_snapshot, run_resumable, save_snapshot
from repro.mapreduce import (
    ExecutionPlan,
    JobConfig,
    REDUCE_BACKENDS,
    build_job,
    build_job_sharded,
    collect_results,
    wordcount,
    wordcount_corpus,
)
from repro.telemetry import PhaseRecorder

ALL_REDUCE = sorted(REDUCE_BACKENDS)
ALL_SHUFFLE = ("lexsort", "all_to_all")

CORPUS = wordcount_corpus(360, vocab_size=53, seed=9)
APP = wordcount(53)
WANT = dict(Counter(np.asarray(CORPUS).tolist()))


def _cfg(**kw):
    kw.setdefault("num_mappers", 5)
    kw.setdefault("num_reducers", 3)
    kw.setdefault("num_workers", 2)
    kw.setdefault("capacity_factor", 8.0)
    return JobConfig(**kw)


def _assert_same(a, b, ctx=None):
    ok_a, ov_a, d_a = a
    ok_b, ov_b, d_b = b
    assert np.array_equal(np.asarray(ok_a), np.asarray(ok_b)), ctx
    assert np.array_equal(np.asarray(ov_a), np.asarray(ov_b)), ctx
    assert int(d_a) == int(d_b), ctx


@pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
@pytest.mark.parametrize("shuffle_backend", ALL_SHUFFLE)
class TestModeEquivalence:
    """fused == traced == resumable, bit-exact, per backend combination."""

    def test_fused_traced_resumable_bit_exact(self, reduce_backend,
                                              shuffle_backend):
        cfg = _cfg(reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        fused = plan.fused()(CORPUS)
        recorder = PhaseRecorder()
        traced = plan.traced(recorder)(CORPUS)
        job = plan.resumable()
        state = run_resumable(job, CORPUS)
        resumable = job.result(state)
        _assert_same(fused, traced, "traced")
        _assert_same(fused, resumable, "resumable")
        assert collect_results(fused[0], fused[1]) == WANT
        assert recorder.last.check_conservation() == []

    def test_preempt_every_boundary_bit_exact(self, reduce_backend,
                                              shuffle_backend):
        """Preempt after k steps then resume, for every k: identical
        outputs, counts, and merged-trace conservation laws — and all of
        it equal to the fused mode of the *same* plan."""
        cfg = _cfg(reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        ref = plan.fused()(CORPUS)
        recorder = PhaseRecorder()
        job = plan.resumable(recorder=recorder)
        ref_state = run_resumable(job, CORPUS)
        _assert_same(ref, job.result(ref_state), "uninterrupted")
        ref_trace = recorder.last
        total_steps = ref_state.cursor.waves_executed
        assert total_steps == 3 + 1 + 2  # map waves + shuffle + red waves
        for k in range(1, total_steps):
            recorder.clear()
            part = run_resumable(job, CORPUS, preempt_after=k)
            assert part.cursor.waves_executed == k
            assert not part.cursor.done
            full = run_resumable(job, CORPUS, state=part)
            _assert_same(ref, job.result(full), k)
            merged = _merge_segments(recorder.traces)
            assert merged.check_conservation() == [], k
            # Bit-exact counts: the interrupted run measured the same
            # phase totals as the uninterrupted one.
            for phase, name in (
                ("map", "pairs_emitted"),
                ("shuffle", "pairs_out"),
                ("shuffle", "pairs_dropped"),
                ("reduce", "segments_out"),
            ):
                assert merged.counter(phase, name) == ref_trace.counter(
                    phase, name
                ), (k, phase, name)


def _merge_segments(traces):
    """One trace holding all segment phases (conservation spans
    segments)."""
    from repro.telemetry import JobTrace

    merged = JobTrace(app=traces[0].app, config=dict(traces[0].config))
    for t in traces:
        merged.phases.extend(t.phases)
    merged.finish(sum(t.total_s for t in traces))
    return merged


class TestShardedEquivalence:
    """The real mesh mode against the single-controller modes (W=1 mesh
    in-process; the 4-device run lives in test_mapreduce_sharded)."""

    @pytest.fixture(scope="class")
    def mesh1(self):
        return jax.make_mesh((1,), ("workers",))

    @pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
    def test_sharded_matches_fused_and_lexsort(self, mesh1, reduce_backend):
        corpus = wordcount_corpus(1200, vocab_size=97, seed=4)
        app = wordcount(97)
        want = dict(Counter(np.asarray(corpus).tolist()))
        lex_cfg = _cfg(num_mappers=4, num_workers=1,
                       reduce_backend=reduce_backend)
        lex = ExecutionPlan(app, lex_cfg, len(corpus)).fused()(corpus)
        cfg = _cfg(num_mappers=4, num_workers=1,
                   reduce_backend=reduce_backend,
                   shuffle_backend="all_to_all")
        plan = ExecutionPlan(app, cfg, len(corpus))
        emulated = plan.fused()(corpus)
        sharded = plan.sharded(mesh1)(corpus)
        _assert_same(emulated, sharded, reduce_backend)
        assert sharded[0].shape[0] == cfg.num_reducers
        assert collect_results(sharded[0], sharded[1]) == want
        # The two shuffle families agree on results + overflow counts.
        assert collect_results(lex[0], lex[1]) == want
        assert int(lex[2]) == int(sharded[2])

    @pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
    def test_sharded_combiner_bit_exact(self, mesh1, reduce_backend):
        """The combine barrier runs before the collective too: the mesh
        mode of a combined plan matches the uncombined fused output."""
        corpus = wordcount_corpus(1200, vocab_size=97, seed=4)
        app = wordcount(97)
        want = dict(Counter(np.asarray(corpus).tolist()))
        cfg = _cfg(num_mappers=4, num_workers=1,
                   reduce_backend=reduce_backend,
                   shuffle_backend="all_to_all", combiner=True)
        plan = ExecutionPlan(app, cfg, len(corpus))
        ref = plan.fused()(corpus)
        sharded = plan.sharded(mesh1)(corpus)
        _assert_same(ref, sharded, reduce_backend)
        assert collect_results(sharded[0], sharded[1]) == want
        assert int(sharded[2]) == 0

    def test_sharded_dropped_matches_lexsort_under_skew(self, mesh1):
        corpus = np.zeros(600, dtype=np.int32)  # one key: max skew
        app = wordcount(16)
        lex_cfg = JobConfig(num_mappers=2, num_reducers=4, num_workers=1,
                            capacity_factor=1.0)
        lex = ExecutionPlan(app, lex_cfg, len(corpus)).fused()(corpus)
        cfg = JobConfig(num_mappers=2, num_reducers=4, num_workers=1,
                        capacity_factor=1.0, shuffle_backend="all_to_all")
        sharded = ExecutionPlan(app, cfg, len(corpus)).sharded(mesh1)(
            corpus
        )
        assert int(lex[2]) > 0  # skew actually overflows
        _assert_same(lex, sharded)

    def test_sharded_traced_per_phase_walls(self, mesh1):
        """The new capability: per-phase wall times + measured counters
        on the sharded path (three fenced mesh programs)."""
        cfg = _cfg(num_workers=1, shuffle_backend="all_to_all")
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        fused = plan.sharded(mesh1)(CORPUS)
        recorder = PhaseRecorder()
        traced = plan.sharded(mesh1, recorder=recorder)(CORPUS)
        _assert_same(fused, traced, "sharded traced")
        trace = recorder.last
        assert trace.phase_names() == ["map", "shuffle", "reduce"]
        assert all(p.wall_s > 0 for p in trace.phases)
        assert trace.check_conservation() == []
        assert trace.counter("map", "pairs_emitted") == len(CORPUS)
        assert trace.counter("shuffle", "dropped_send") == 0

    def test_sharded_traced_counters_stats(self, mesh1):
        """recorder + counters=True compose: per-worker overflow stats
        ride alongside the per-phase trace."""
        corpus = np.zeros(600, dtype=np.int32)
        app = wordcount(16)
        cfg = JobConfig(num_mappers=2, num_reducers=4, num_workers=1,
                        capacity_factor=1.0, shuffle_backend="all_to_all")
        plan = ExecutionPlan(app, cfg, len(corpus))
        recorder = PhaseRecorder()
        ok, ov, dropped, stats = plan.sharded(
            mesh1, counters=True, recorder=recorder
        )(corpus)
        assert int(dropped) > 0
        assert stats["dropped_per_worker"].shape == (1, 2)
        assert stats["dropped_send"] + stats["dropped_recv"] == int(dropped)
        trace = recorder.last
        assert trace.counter("shuffle", "pairs_dropped") == int(dropped)
        assert trace.check_conservation() == []


class TestBuildJobWrappers:
    """build_job / build_job_sharded are thin mode selectors."""

    def test_build_job_routes_collective_with_recorder(self):
        mesh = jax.make_mesh((1,), ("workers",))
        cfg = _cfg(num_workers=1, shuffle_backend="all_to_all")
        recorder = PhaseRecorder()
        job = build_job(APP, cfg, len(CORPUS), mesh=mesh,
                        recorder=recorder)
        ok, ov, dropped = job(CORPUS)
        assert collect_results(ok, ov) == WANT
        assert recorder.last.phase_names() == ["map", "shuffle", "reduce"]

    def test_build_job_collective_still_requires_mesh(self):
        cfg = JobConfig(num_mappers=2, num_reducers=2,
                        shuffle_backend="all_to_all")
        with pytest.raises(ValueError, match="mesh"):
            build_job(wordcount(16), cfg, 100)

    def test_build_job_sharded_counters_contract(self):
        mesh = jax.make_mesh((1,), ("workers",))
        cfg = JobConfig(num_mappers=2, num_reducers=4, num_workers=1,
                        capacity_factor=1.0, shuffle_backend="all_to_all")
        corpus = np.zeros(600, dtype=np.int32)
        ok, ov, dropped, stats = build_job_sharded(
            wordcount(16), cfg, len(corpus), mesh, counters=True
        )(corpus)
        assert stats["dropped_send"] + stats["dropped_recv"] == int(dropped)

    def test_plan_validates_reduce_op_at_lowering(self):
        """pallas is sum-only; a max-op app must fail fast at plan
        construction, not mis-reduce."""
        from repro.mapreduce import MapReduceApp

        app = MapReduceApp(
            name="maxapp", key_space=8,
            map_fn=lambda t, v: (t, t, v), reduce_op="max",
        )
        cfg = JobConfig(num_mappers=2, num_reducers=2,
                        reduce_backend="pallas")
        with pytest.raises(ValueError, match="supports"):
            ExecutionPlan(app, cfg, 64)


class TestCanonicalCapacity:
    """The shuffle capacity is a property of the plan, not the grant."""

    def test_lexsort_capacity_grant_free(self):
        for W in (1, 2, 3, 5):
            cfg = _cfg(num_mappers=7, num_reducers=4, num_workers=W)
            plan = ExecutionPlan(APP, cfg, len(CORPUS))
            assert plan.partition_cap() == plan.lex_capacity
            ok, ov, dropped = plan.fused()(CORPUS)
            assert ok.shape == (4, plan.lex_capacity)
            assert collect_results(ok, ov) == WANT

    def test_grant_changes_never_change_lexsort_output(self):
        """W is a pure scheduling knob: any grant produces the identical
        (R, cap) output block — the invariant that makes fused == the
        wave-by-wave modes under arbitrary regrant histories."""
        ref = None
        for W in (1, 2, 3, 4, 7):
            cfg = _cfg(num_mappers=7, num_reducers=4, num_workers=W)
            out = ExecutionPlan(APP, cfg, len(CORPUS)).fused()(CORPUS)
            if ref is None:
                ref = out
            else:
                _assert_same(ref, out, W)

    def test_meta_shape_facts(self):
        cfg = _cfg(num_mappers=6, num_reducers=4, num_workers=2)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        m = plan.meta()
        assert m["mappers"] == 6 and m["reducers"] == 4
        assert m["map_waves"] == 3 and m["reduce_waves"] == 2
        assert m["n_pairs"] == plan.M * plan.P
        assert m["partition_capacity"] == plan.lex_capacity
        assert m["overlap_depth"] == 1


@pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
@pytest.mark.parametrize("shuffle_backend", ALL_SHUFFLE)
class TestPipelinedEquivalence:
    """Mode ``pipelined`` is the fused lowering with a different
    schedule: bit-exact at every depth, for every backend combination,
    on ragged (W∤M) wave configurations."""

    def test_pipelined_bit_exact_vs_fused(self, reduce_backend,
                                          shuffle_backend):
        # The default fixture config is already ragged: M=5 over W=2
        # (3 map waves, last partial) and R=3 over W=2 (2 reduce waves).
        cfg = _cfg(reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        fused = plan.fused()(CORPUS)
        for depth in (1, 2, 3):
            out = plan.pipelined(depth=depth)(CORPUS)
            _assert_same(fused, out, (depth, "pipelined"))
            assert collect_results(out[0], out[1]) == WANT

    def test_pipelined_ragged_wave_groups(self, reduce_backend,
                                          shuffle_backend):
        """D∤waves and W∤M at once: the epilogue group is partial both
        in waves-per-group and tasks-per-wave."""
        cfg = _cfg(num_mappers=7, num_reducers=5, num_workers=3,
                   reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        fused = plan.fused()(CORPUS)
        for depth in (2, 3):
            _assert_same(
                fused, plan.pipelined(depth=depth)(CORPUS), depth
            )

    def test_traced_pipelined_records_pipeline_phase(self, reduce_backend,
                                                     shuffle_backend):
        cfg = _cfg(reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend, overlap_depth=2)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        fused = plan.fused()(CORPUS)
        recorder = PhaseRecorder()
        traced = plan.traced(recorder)(CORPUS)  # depth from the config
        _assert_same(fused, traced, "traced depth=2")
        trace = recorder.last
        assert trace.phase_names() == ["map", "shuffle", "reduce",
                                       "pipeline"]
        assert trace.counter("pipeline", "overlap_depth") == 2
        assert trace.config["overlap_depth"] == 2
        assert trace.check_conservation() == []


@pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
@pytest.mark.parametrize("shuffle_backend", ALL_SHUFFLE)
class TestCombinerEquivalence:
    """Map-side combining is a pure byte-contraction: for every
    commutative+associative app the combined plan is bit-exact against
    the uncombined one, in every execution mode, on a ragged (W∤M)
    wave configuration."""

    def test_all_modes_bit_exact_vs_uncombined(self, reduce_backend,
                                               shuffle_backend):
        # M=7 over W=2: 4 map waves, last partial — the ragged case.
        kw = dict(num_mappers=7, num_reducers=3, num_workers=2,
                  reduce_backend=reduce_backend,
                  shuffle_backend=shuffle_backend)
        base = ExecutionPlan(APP, _cfg(**kw), len(CORPUS)).fused()(CORPUS)
        plan = ExecutionPlan(APP, _cfg(combiner=True, **kw), len(CORPUS))
        ref = plan.fused()(CORPUS)
        # Against the uncombined plan: identical *results* (the combined
        # plan's output buffers are narrower — lex_capacity is sized
        # from the contracted stream — so padded shapes differ).
        assert collect_results(ref[0], ref[1]) == WANT
        assert collect_results(base[0], base[1]) == WANT
        assert int(ref[2]) == int(base[2]) == 0
        # Within the combined plan: every mode bit-exact vs its fused.
        recorder = PhaseRecorder()
        _assert_same(ref, plan.traced(recorder)(CORPUS), "traced")
        for depth in (2, 3):
            _assert_same(ref, plan.pipelined(depth=depth)(CORPUS),
                         (depth, "pipelined"))
        job = plan.resumable()
        _assert_same(ref, job.result(run_resumable(job, CORPUS)),
                     "resumable")
        # The traced run recorded the combine stage and its contraction,
        # and the combined trace satisfies every conservation law.
        trace = recorder.last
        assert "combine" in trace.phase_names()
        assert trace.check_conservation() == []
        assert trace.counter("combine", "pairs_in") == trace.counter(
            "map", "pairs_emitted"
        )
        assert trace.counter("combine", "pairs_out") <= trace.counter(
            "combine", "pairs_in"
        )
        assert trace.counter("shuffle", "pairs_in") == trace.counter(
            "combine", "pairs_out"
        )

    def test_preempt_every_boundary_with_combiner(self, reduce_backend,
                                                  shuffle_backend):
        """The combine barrier is a first-class preemption boundary:
        preempt after k steps then resume, for every k."""
        cfg = _cfg(reduce_backend=reduce_backend,
                   shuffle_backend=shuffle_backend, combiner=True)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        ref = plan.fused()(CORPUS)
        job = plan.resumable()
        total = run_resumable(job, CORPUS).cursor.waves_executed
        assert total == 3 + 1 + 1 + 2  # map + combine + shuffle + reduce
        for k in range(1, total):
            part = run_resumable(job, CORPUS, preempt_after=k)
            assert part.cursor.waves_executed == k
            assert not part.cursor.done
            full = run_resumable(job, CORPUS, state=part)
            _assert_same(ref, job.result(full), k)


class TestCombinerValidation:
    """Order-dependent reduce ops must be rejected at plan construction
    — a map-side combine would silently reorder their merges."""

    def test_combiner_rejects_order_dependent_op(self):
        from repro.mapreduce import MapReduceApp

        app = MapReduceApp(
            name="firstapp", key_space=8,
            map_fn=lambda t, v: (t, t, v), reduce_op="first",
        )
        cfg = JobConfig(num_mappers=2, num_reducers=2, combiner=True)
        with pytest.raises(ValueError, match="combiner"):
            ExecutionPlan(app, cfg, 64)
        # The same app lowers fine without the combiner.
        ExecutionPlan(
            app, JobConfig(num_mappers=2, num_reducers=2), 64
        )


class TestPipelinedRouting:
    """build_job routes overlap_depth; bad depths fail fast."""

    def test_build_job_routes_overlap_depth(self):
        ref = build_job(APP, _cfg(), len(CORPUS))(CORPUS)
        out = build_job(APP, _cfg(overlap_depth=3), len(CORPUS))(CORPUS)
        _assert_same(ref, out, "build_job depth=3")

    def test_config_validates_depth(self):
        with pytest.raises(ValueError, match="overlap_depth"):
            _cfg(overlap_depth=0)

    def test_plan_validates_depth(self):
        plan = ExecutionPlan(APP, _cfg(), len(CORPUS))
        with pytest.raises(ValueError, match="depth"):
            plan.pipelined(depth=0)


class TestPipelinedPreemption:
    """``resumable`` only materializes states at wave boundaries, so a
    snapshot taken while a pipelined job is preempted has — by
    construction — drained the in-flight wave group; resuming from any
    such snapshot (through a real checkpoint round trip) reproduces the
    pipelined output bit-exactly."""

    def test_snapshot_mid_pipeline_drains_in_flight_wave(self, tmp_path):
        cfg = _cfg(overlap_depth=3)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        ref = plan.pipelined()(CORPUS)
        job = plan.resumable()
        total = run_resumable(job, CORPUS).cursor.waves_executed
        for k in range(1, total):
            part = run_resumable(job, CORPUS, preempt_after=k)
            mgr = CheckpointManager(str(tmp_path / f"k{k}"))
            save_snapshot(mgr, part)
            restored, _, _ = load_snapshot(mgr)
            full = run_resumable(job, CORPUS, state=restored)
            _assert_same(ref, job.result(full), k)


class TestStepperCaches:
    """Per-grant jit caches: equivalent grants share one stepper, and
    cache_info() exposes occupancy + hit/miss counters."""

    def test_equivalent_grants_share_steppers(self):
        plan = ExecutionPlan(APP, _cfg(), len(CORPUS))  # M=5, R=3
        # Any W >= M is the same map stepper (the regrant re-trace bug).
        assert plan.map_stepper(5) is plan.map_stepper(9)
        assert plan.map_stepper(2) is not plan.map_stepper(3)
        cap = plan.partition_cap()
        assert plan.reduce_stepper(3, cap) is plan.reduce_stepper(7, cap)
        info = plan.cache_info()
        assert info["map_entries"] == 3  # keys {5, 2, 3}
        assert info["reduce_entries"] == 1
        assert info["hits"] == 2
        assert info["misses"] == 4

    def test_combiner_flag_in_every_stepper_cache_key(self):
        """Combined and uncombined grants must never share a jitted
        trace (their buffer widths differ): the combine stepper is one
        W-independent entry, and every per-grant key carries the
        combiner flag."""
        on = ExecutionPlan(APP, _cfg(combiner=True), len(CORPUS))
        off = ExecutionPlan(APP, _cfg(), len(CORPUS))
        stepper = on.combine_stepper()
        assert on.combine_stepper() is stepper  # W-independent: cached
        assert on.cache_info()["combine_entries"] == 1
        assert on.cache_info()["hits"] == 1
        assert off.cache_info()["combine_entries"] == 0
        on.map_stepper(2)
        off.map_stepper(2)
        on.reduce_stepper(2, on.partition_cap())
        off.reduce_stepper(2, off.partition_cap())
        assert set(on._jit_map) == {(2, True)}
        assert set(off._jit_map) == {(2, False)}
        assert all(k[-1] is True for k in on._jit_reduce)
        assert all(k[-1] is False for k in off._jit_reduce)
        # The contraction is structural: the combined plan's partition
        # buffers are sized from the combined stream.
        assert on.meta()["combiner"] is True
        assert on.shuffle_width <= off.shuffle_width
        assert on.lex_capacity <= off.lex_capacity

    def test_pipelined_jobs_cached_per_grant_and_depth(self):
        plan = ExecutionPlan(APP, _cfg(), len(CORPUS))
        a = plan.pipelined(depth=2)
        assert plan.pipelined(depth=2) is a
        assert plan.pipelined(depth=3) is not a
        assert plan.pipelined(workers=3, depth=2) is not a
        assert plan.cache_info()["pipelined_entries"] == 3


@pytest.mark.slow
class TestPipelinedPerfSmoke:
    def test_depth2_not_slower_than_fused_beyond_noise(self):
        """On a shuffle-heavy (all_to_all, high wave count) config the
        pipelined schedule must at minimum not lose to fused beyond
        measurement noise; the real speedup target lives in
        benchmarks/pipeline_bench.py."""
        tokens = 8192
        corpus = wordcount_corpus(tokens, vocab_size=101, seed=3)
        app = wordcount(101)
        cfg = JobConfig(num_mappers=32, num_reducers=32, num_workers=2,
                        shuffle_backend="all_to_all", capacity_factor=8.0)
        plan = ExecutionPlan(app, cfg, tokens)

        def best(fn, reps=3):
            jax.block_until_ready(fn(corpus))  # compile + warm
            vals = []
            for _ in range(reps):
                t0 = _time.perf_counter()
                jax.block_until_ready(fn(corpus))
                vals.append(_time.perf_counter() - t0)
            return min(vals)

        t_fused = best(plan.fused())
        t_pipe = best(plan.pipelined(depth=2))
        assert t_pipe <= t_fused * 1.25, (t_pipe, t_fused)
