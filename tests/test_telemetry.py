"""Telemetry layer: traced execution, counter conservation, decomposed
models, resource-qualified ModelDatabase keys, XLA cost estimates."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.predictor import ModelDatabase
from repro.core.regression import fit
from repro.mapreduce import (
    JobConfig,
    REDUCE_BACKENDS,
    build_job,
    collect_results,
    wordcount,
    wordcount_corpus,
)
from repro.mapreduce.phases import PAIR_BYTES, count_live
from repro.telemetry import (
    JobTrace,
    PhaseRecorder,
    PhaseModelSet,
    collect_traced,
    composed_vs_monolithic,
    fit_phase_models,
    phase_resource_key,
    split_resource_key,
    stage_cost_estimates,
    targets_from_traces,
)

ALL_REDUCE = sorted(REDUCE_BACKENDS)


def traced_run(app, corpus, *, collect=False, **cfg_kwargs):
    """One traced execution; returns (trace, job output)."""
    recorder = PhaseRecorder()
    cfg = JobConfig(**cfg_kwargs)
    job = build_job(app, cfg, len(corpus), recorder=recorder)
    out = job(corpus)
    trace = recorder.last
    if collect:
        collect_traced(trace, out[0], out[1])
    return trace, out


class TestTracedExecution:
    def test_traced_output_matches_fused(self):
        corpus = wordcount_corpus(3000, vocab_size=211, seed=1)
        app = wordcount(211)
        kw = dict(num_mappers=5, num_reducers=4, capacity_factor=8.0)
        fused = build_job(app, JobConfig(**kw), len(corpus))(corpus)
        trace, traced = traced_run(app, corpus, **kw)
        assert collect_results(*fused[:2]) == collect_results(*traced[:2])
        assert int(fused[2]) == int(traced[2])
        assert trace.phase_names() == ["map", "shuffle", "reduce"]

    def test_recorder_accumulates_per_call(self):
        corpus = wordcount_corpus(1000, vocab_size=64, seed=0)
        app = wordcount(64)
        recorder = PhaseRecorder()
        job = build_job(app, JobConfig(num_mappers=2, num_reducers=2),
                        len(corpus), recorder=recorder)
        for _ in range(3):
            job(corpus)
        assert len(recorder) == 3
        assert recorder.last is recorder.traces[-1]

    def test_trace_counters_measured_not_config_derived(self):
        corpus = wordcount_corpus(2000, vocab_size=97, seed=2)
        app = wordcount(97)
        trace, (ok, ov, dropped) = traced_run(
            app, corpus, num_mappers=4, num_reducers=3, capacity_factor=8.0
        )
        assert trace.counter("map", "pairs_emitted") == 2000
        assert trace.counter("shuffle", "pairs_out") == 2000 - int(dropped)
        assert trace.counter("shuffle", "bytes_out") == (
            trace.counter("shuffle", "pairs_out") * PAIR_BYTES
        )
        assert trace.counter("reduce", "segments_out") == float(
            count_live(ok)
        )

    def test_collect_traced_appends_phase(self):
        corpus = wordcount_corpus(1000, vocab_size=64, seed=0)
        app = wordcount(64)
        trace, _ = traced_run(
            app, corpus, collect=True, num_mappers=2, num_reducers=2,
            capacity_factor=8.0,
        )
        assert trace.phase_names() == ["map", "shuffle", "reduce", "collect"]
        assert trace.counter("collect", "unique_keys") > 0

    def test_recorder_on_collective_shuffle_needs_mesh(self):
        """Per-phase telemetry now works on the sharded path (separate
        mesh programs — see tests/test_plan.py), but the collective
        shuffle still demands a mesh to run on."""
        cfg = JobConfig(num_mappers=2, num_reducers=2,
                        shuffle_backend="all_to_all")
        with pytest.raises(ValueError, match="mesh"):
            build_job(wordcount(16), cfg, 100, recorder=PhaseRecorder())

    def test_phase_times_sum_to_total(self):
        corpus = wordcount_corpus(4000, vocab_size=211, seed=3)
        app = wordcount(211)
        trace, _ = traced_run(
            app, corpus, num_mappers=6, num_reducers=5, capacity_factor=8.0
        )
        assert trace.total_s is not None
        assert trace.phase_time_sum() <= trace.total_s * 1.01
        assert abs(trace.total_s - trace.phase_time_sum()) <= max(
            0.5 * trace.total_s, 0.1
        )


class TestConservation:
    @pytest.mark.parametrize("backend", ALL_REDUCE)
    def test_no_overflow_conserves(self, backend):
        corpus = wordcount_corpus(1500, vocab_size=97, seed=4)
        trace, _ = traced_run(
            wordcount(97), corpus, num_mappers=4, num_reducers=3,
            capacity_factor=8.0, reduce_backend=backend,
        )
        assert trace.check_conservation() == []
        assert trace.counter("shuffle", "pairs_dropped") == 0

    @pytest.mark.parametrize("backend", ALL_REDUCE)
    def test_overflow_accounted_in_bytes(self, backend):
        corpus = np.zeros(600, dtype=np.int32)  # one key: max skew
        trace, (_, _, dropped) = traced_run(
            wordcount(16), corpus, num_mappers=2, num_reducers=4,
            capacity_factor=1.0, reduce_backend=backend,
        )
        assert int(dropped) > 0
        assert trace.counter("shuffle", "bytes_dropped") == (
            int(dropped) * PAIR_BYTES
        )
        assert trace.check_conservation() == []

    def test_counters_identical_across_reduce_backends(self):
        corpus = wordcount_corpus(1200, vocab_size=64, seed=5)
        app = wordcount(64)
        # cpu_s / net_s are clock measurements (they vary run to run);
        # every *deterministic* counter must match across backends.
        timing = {"cpu_s", "net_s"}
        per_backend = {}
        for backend in ALL_REDUCE:
            trace, _ = traced_run(
                app, corpus, collect=True, num_mappers=5, num_reducers=4,
                capacity_factor=4.0, reduce_backend=backend,
            )
            per_backend[backend] = {
                p.phase: {
                    k: v for k, v in p.counters.items() if k not in timing
                }
                for p in trace.phases
            }
        ref = per_backend[ALL_REDUCE[0]]
        for backend, counters in per_backend.items():
            assert counters == ref, backend

    @given(
        n=st.integers(300, 1500),
        m=st.integers(1, 8),
        r=st.integers(1, 8),
        vocab=st.integers(2, 48),
        capf=st.sampled_from([1.0, 2.0, 8.0]),
    )
    @settings(max_examples=6, deadline=None)
    def test_property_conservation_all_backends(self, n, m, r, vocab, capf):
        corpus = wordcount_corpus(n, vocab_size=vocab, seed=n + m + r)
        app = wordcount(vocab)
        per_backend = {}
        for backend in ALL_REDUCE:
            trace, (_, _, dropped) = traced_run(
                app, corpus, num_mappers=m, num_reducers=r,
                capacity_factor=capf, reduce_backend=backend,
            )
            assert trace.check_conservation() == [], backend
            assert (
                trace.counter("shuffle", "pairs_out") + int(dropped) == n
            ), backend
            per_backend[backend] = {
                p.phase: {
                    k: v for k, v in p.counters.items()
                    if k not in ("cpu_s", "net_s")  # clock-valued
                }
                for p in trace.phases
            }
        ref = per_backend[ALL_REDUCE[0]]
        assert all(c == ref for c in per_backend.values())

    def test_check_conservation_flags_violations(self):
        trace = JobTrace(app="x", config={})
        trace.record_phase("map", 0.0, pairs_emitted=10)
        trace.record_phase(
            "shuffle", 0.0, pairs_in=10, pairs_out=3, pairs_dropped=2,
            bytes_in=80, bytes_out=24, bytes_dropped=16,
        )
        bad = trace.check_conservation()
        assert any("bytes" in b for b in bad)
        assert any("pairs" in b for b in bad)

    def test_check_conservation_flags_combine_violations(self):
        """The combine laws actually trigger: expansion, intake
        mismatch, and map/combine intake disagreement are each
        flagged; the clean contracted trace is not."""

        def run(combine_out, shuffle_in, combine_in=10):
            trace = JobTrace(app="x", config={})
            trace.record_phase("map", 0.0, pairs_emitted=10)
            trace.record_phase(
                "combine", 0.0, pairs_in=combine_in,
                pairs_out=combine_out, bytes_in=combine_in * 8,
                bytes_out=combine_out * 8,
            )
            trace.record_phase(
                "shuffle", 0.0, pairs_in=shuffle_in, pairs_out=shuffle_in,
                pairs_dropped=0, bytes_in=shuffle_in * 8,
                bytes_out=shuffle_in * 8, bytes_dropped=0,
            )
            return trace.check_conservation()

        assert run(6, 6) == []  # clean contracted trace
        # A combiner that *expands* the stream is a bug.
        bad = run(12, 12)
        assert any("combine pairs_out" in v and "> pairs_in" in v
                   for v in bad)
        assert any("combine bytes_out" in v for v in bad)
        # The shuffle must consume exactly the combiner's output.
        assert any("combine pairs_out" in v and "shuffle pairs_in" in v
                   for v in run(6, 9))
        # The combiner must consume exactly the map's emitted stream.
        assert any("map pairs_emitted" in v and "combine pairs_in" in v
                   for v in run(6, 6, combine_in=8))

    def test_check_conservation_flags_nonshuffle_net_bytes(self):
        """Only the shuffle occupies the fabric: a combine phase that
        claims wire bytes is flagged."""
        trace = JobTrace(app="x", config={})
        trace.record_phase("map", 0.0, pairs_emitted=4)
        trace.record_phase("combine", 0.0, pairs_in=4, pairs_out=4,
                           net_bytes=32)
        bad = trace.check_conservation()
        assert any("only shuffle occupies the fabric" in v for v in bad)

    def test_trace_round_trips_through_dict(self):
        corpus = wordcount_corpus(800, vocab_size=32, seed=6)
        trace, _ = traced_run(
            wordcount(32), corpus, collect=True, num_mappers=2,
            num_reducers=2, capacity_factor=8.0,
        )
        clone = JobTrace.from_dict(trace.to_dict())
        assert clone.phase_times() == trace.phase_times()
        assert clone.config == trace.config
        assert clone.check_conservation() == []


class TestEstimator:
    def test_estimates_cover_compute_phases(self):
        app = wordcount(64)
        cfg = JobConfig(num_mappers=4, num_reducers=4, capacity_factor=4.0)
        est = stage_cost_estimates(app, cfg, 1024)
        assert set(est) == {"map", "shuffle", "reduce"}
        for phase, e in est.items():
            assert e["flops"] >= 0 and e["bytes"] >= 0, phase
            assert isinstance(e["available"], bool)
            if e["available"]:
                assert e["bytes"] > 0, phase
            # static per-phase resource estimates pair with the measured
            # trace counters: cpu_flops everywhere, fabric bytes only on
            # the shuffle (the exact pairs * PAIR_BYTES form).
            assert e["cpu_flops"] == e["flops"], phase
            if phase == "shuffle":
                from repro.telemetry.trace import PAIR_BYTES

                assert e["net_bytes"] > 0
                assert e["net_bytes"] % PAIR_BYTES == 0
            else:
                assert e["net_bytes"] == 0.0, phase

    def test_more_setup_rounds_cost_more_map_flops(self):
        app = wordcount(64)
        small = stage_cost_estimates(
            app, JobConfig(num_mappers=4, num_reducers=4, setup_rounds=1),
            1024,
        )
        big = stage_cost_estimates(
            app, JobConfig(num_mappers=4, num_reducers=4, setup_rounds=16),
            1024,
        )
        if small["map"]["available"] and big["map"]["available"]:
            assert big["map"]["flops"] > small["map"]["flops"]


def synthetic_phase_data(n=25, seed=0):
    """Analytic per-phase targets over a 2-param config space."""
    rng = np.random.default_rng(seed)
    params = rng.uniform(5, 40, size=(n, 2))
    m, r = params[:, 0], params[:, 1]
    times = {
        "map": 0.2 + 0.01 * m + 1e-4 * m**2,
        "shuffle": 0.5 + 0.02 * r,
        "reduce": 0.1 + 30.0 / r,
    }
    targets = {(p, "time_s"): v for p, v in times.items()}
    targets[("shuffle", "bytes_out")] = 8000.0 + 10.0 * r
    return params, targets


class TestPhaseModels:
    def test_resource_key_round_trip(self):
        key = phase_resource_key("shuffle", "bytes_out")
        assert key == "shuffle:bytes_out"
        assert split_resource_key(key) == ("shuffle", "bytes_out")
        with pytest.raises(ValueError):
            phase_resource_key("a:b", "c")
        with pytest.raises(ValueError):
            split_resource_key("no-separator")

    def test_composed_equals_monolithic_on_shared_basis(self):
        params, targets = synthetic_phase_data()
        pms = fit_phase_models(params, targets)
        totals = sum(
            targets[(p, "time_s")] for p in ("map", "shuffle", "reduce")
        )
        mono = fit(params, totals)
        stats = composed_vs_monolithic(pms, mono, params, totals)
        assert stats["composed_le_monolithic"]
        np.testing.assert_allclose(
            pms.predict_total(params),
            np.asarray(mono.predict(params)).ravel(),
            rtol=1e-6, atol=1e-9,
        )

    def test_predict_total_sums_phases(self):
        params, targets = synthetic_phase_data()
        pms = fit_phase_models(params, targets)
        assert pms.time_phases() == ["map", "shuffle", "reduce"]
        per_phase = pms.predict_phase_times(params)
        np.testing.assert_allclose(
            pms.predict_total(params),
            np.sum(list(per_phase.values()), axis=0),
        )

    def test_resource_model_not_in_total(self):
        params, targets = synthetic_phase_data()
        pms = fit_phase_models(params, targets)
        bytes_pred = pms.predict("shuffle", "bytes_out", params)
        assert bytes_pred.mean() > 1000  # bytes scale, not seconds
        assert pms.predict_total(params).mean() < 100

    def test_publish_and_load_via_database(self, tmp_path):
        params, targets = synthetic_phase_data()
        pms = fit_phase_models(params, targets)
        db = ModelDatabase()
        db.put("wc", "plat", fit(params, targets[("map", "time_s")]))
        pms.publish(db, "wc", "plat", backend="jnp")
        assert set(db.resources_for("wc", "plat", "jnp")) == {
            "map:time_s", "shuffle:time_s", "reduce:time_s",
            "shuffle:bytes_out",
        }
        # resource keys don't leak into the backend enumeration
        assert db.backends_for("wc", "plat") == [""]

        path = str(tmp_path / "db.json")
        db.save(path)
        loaded = ModelDatabase.load(path)
        assert len(loaded) == len(db)
        pms2 = PhaseModelSet.load(loaded, "wc", "plat", backend="jnp")
        np.testing.assert_allclose(
            pms2.predict_total(params), pms.predict_total(params),
            rtol=1e-12,
        )

    def test_targets_from_traces_means_repeats(self):
        def mk(t_map, nbytes):
            tr = JobTrace(app="wc", config={})
            tr.record_phase("map", t_map, pairs_emitted=100)
            tr.record_phase(
                "shuffle", 0.5, pairs_in=100, pairs_out=100,
                pairs_dropped=0, bytes_in=800, bytes_out=nbytes,
                bytes_dropped=800 - nbytes,
            )
            tr.record_phase("reduce", 0.1, segments_out=10)
            return tr

        targets = targets_from_traces(
            [[mk(1.0, 800), mk(3.0, 800)], [mk(2.0, 400), mk(2.0, 400)]]
        )
        np.testing.assert_allclose(
            targets[("map", "time_s")], [2.0, 2.0]
        )
        np.testing.assert_allclose(
            targets[("shuffle", "bytes_out")], [800.0, 400.0]
        )

    def test_fit_phase_models_shape_mismatch_rejected(self):
        params, targets = synthetic_phase_data()
        targets[("map", "time_s")] = targets[("map", "time_s")][:-1]
        with pytest.raises(ValueError, match="shape"):
            fit_phase_models(params, targets)


class TestDatabaseResourceKeys:
    def test_legacy_two_and_three_part_keys_load(self, tmp_path):
        import json

        params = np.random.default_rng(0).uniform(1, 40, size=(20, 2))
        model = fit(params, params.sum(axis=1))
        payload = {
            "wc\x00plat": model.to_dict(),
            "wc\x00plat\x00jnp": model.to_dict(),
        }
        path = tmp_path / "legacy.json"
        path.write_text(json.dumps(payload))
        db = ModelDatabase.load(str(path))
        assert ("wc", "plat") in db
        assert ("wc", "plat", "jnp") in db
        assert ("wc", "plat", "jnp", "") in db
        assert db.resources_for("wc", "plat", "jnp") == []

    def test_resourceless_save_format_unchanged(self, tmp_path):
        import json

        params = np.random.default_rng(0).uniform(1, 40, size=(20, 2))
        db = ModelDatabase()
        db.put("wc", "plat", fit(params, params.sum(axis=1)), backend="jnp")
        path = str(tmp_path / "db.json")
        db.save(path)
        keys = list(json.load(open(path)))
        assert keys == ["wc\x00plat\x00jnp"]  # PR 2 wire format

    def test_get_error_names_resource(self):
        db = ModelDatabase()
        with pytest.raises(KeyError, match="resource='map:time_s'"):
            db.get("wc", "plat", "jnp", resource="map:time_s")


class TestRecorderRetention:
    def test_max_traces_bounds_retention(self):
        rec = PhaseRecorder(max_traces=3)
        cfg = JobConfig(num_mappers=1, num_reducers=1)
        traces = [rec.start_job("wc", cfg, 10) for _ in range(7)]
        assert len(rec) == 3
        assert rec.traces == traces[-3:]
        with pytest.raises(ValueError, match="max_traces"):
            PhaseRecorder(max_traces=0)

    def test_pair_bytes_single_source(self):
        from repro.mapreduce import phases
        from repro import telemetry

        assert telemetry.PAIR_BYTES is phases.PAIR_BYTES


class TestTracedFailureCleanup:
    def test_failed_run_leaves_no_phantom_trace(self):
        corpus = wordcount_corpus(1000, vocab_size=64, seed=0)
        app = wordcount(64)
        recorder = PhaseRecorder()
        job = build_job(app, JobConfig(num_mappers=2, num_reducers=2),
                        len(corpus), recorder=recorder)
        job(corpus)
        assert len(recorder) == 1
        with pytest.raises(ValueError, match="expected"):
            job(corpus[:-10])  # wrong shape: fails inside the map stage
        assert len(recorder) == 1  # no phantom/partial trace retained
        assert recorder.last.total_s is not None
