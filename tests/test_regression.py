"""Core modeling tests: features (Eqn 1-2), OLS (Eqn 6), prediction (Eqn 4-5)."""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from hypothesis import given, settings, strategies as st

from repro.core import (
    ModelDatabase,
    design_matrix,
    fit,
    fit_feature_spec,
    grid,
    prediction_error_stats,
)


def _cubic_surface(p):
    m, r = p[..., 0], p[..., 1]
    return (
        120.0 + 2.0 * m - 0.05 * m**2 + 0.0008 * m**3
        + 4.0 * r - 0.09 * r**2 + 0.0011 * r**3
    )


class TestFeatures:
    def test_design_matrix_paper_ordering(self):
        spec = fit_feature_spec(np.array([[2.0, 3.0]]))
        row = np.asarray(design_matrix(spec, np.array([[2.0, 3.0]])))[0]
        np.testing.assert_allclose(
            row, [1, 2, 4, 8, 3, 9, 27], rtol=1e-6
        )

    def test_column_names(self):
        spec = fit_feature_spec(np.zeros((4, 2)))
        assert spec.column_names() == [
            "1", "p0", "p0^2", "p0^3", "p1", "p1^2", "p1^3"
        ]

    def test_cross_terms(self):
        spec = fit_feature_spec(np.zeros((4, 2)), cross_terms=True)
        assert spec.n_features == 8
        row = np.asarray(design_matrix(spec, np.array([[2.0, 3.0]])))[0]
        assert row[-1] == 6.0  # p0 * p1

    def test_scaling_maps_to_unit_interval(self):
        params = np.array([[5.0, 10.0], [40.0, 20.0]])
        spec = fit_feature_spec(params, scale=True)
        P = np.asarray(design_matrix(spec, params))
        assert P[0, 1] == 0.0 and P[1, 1] == 1.0

    def test_grid(self):
        g = grid([(5, 40, 5), (5, 40, 5)])
        assert g.shape == (64, 2)
        assert g.min() == 5 and g.max() == 40


class TestFit:
    def test_exact_recovery_noiseless(self):
        """A cubic no-cross-term surface is IN the model class: zero error."""
        space = grid([(5, 40, 5), (5, 40, 5)])
        times = _cubic_surface(space)
        model = fit(space, times)
        assert model.train_mape < 1e-6
        assert model.r2 > 1 - 1e-9
        test = np.array([[7.5, 13.0], [33.0, 8.0]])
        np.testing.assert_allclose(
            np.asarray(model.predict(test)), _cubic_surface(test), rtol=1e-6
        )

    def test_paper_error_band_with_noise(self):
        """~1% multiplicative noise -> test error well under the paper's 5%."""
        rng = np.random.default_rng(0)
        space = grid([(5, 40, 5), (5, 40, 5)])
        times = _cubic_surface(space) * (1 + rng.normal(0, 0.01, len(space)))
        model = fit(space, times)
        test = np.array([[7, 13], [22, 31], [38, 9], [17, 24], [11, 36]],
                        dtype=float)
        stats = prediction_error_stats(model, test, _cubic_surface(test))
        assert stats["mean_pct"] < 5.0

    def test_float32_scaled_matches_float64(self):
        space = grid([(5, 40, 5), (5, 40, 5)])
        rng = np.random.default_rng(1)
        times = _cubic_surface(space) * (1 + rng.normal(0, 0.005, len(space)))
        m64 = fit(space, times)
        m32 = fit(space, times, scale=True, lam=1e-9, dtype=jnp.float32)
        assert abs(m32.train_mape - m64.train_mape) < 0.1

    def test_robust_downweights_outliers(self):
        space = grid([(5, 40, 5), (5, 40, 5)])
        times = _cubic_surface(space).copy()
        times[7] *= 3.0  # a straggler experiment (paper's temporal changes)
        plain = fit(space, times)
        robust = fit(space, times, robust=True)
        clean = np.delete(np.arange(len(space)), 7)
        err_plain = prediction_error_stats(
            plain, space[clean], _cubic_surface(space[clean]))["mean_pct"]
        err_rob = prediction_error_stats(
            robust, space[clean], _cubic_surface(space[clean]))["mean_pct"]
        assert err_rob < err_plain

    def test_underdetermined_raises(self):
        with pytest.raises(ValueError, match="underdetermined"):
            fit(np.zeros((3, 2)), np.zeros(3))

    @given(
        coefs=st.lists(
            st.floats(-2, 2, allow_nan=False), min_size=7, max_size=7
        ),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_model_class_closure(self, coefs, seed):
        """Any function in the model's own class is fit exactly (property)."""
        rng = np.random.default_rng(seed)
        space = rng.uniform(1, 10, size=(30, 2))
        spec = fit_feature_spec(space)
        P = np.asarray(design_matrix(spec, space), dtype=np.float64)
        times = P @ np.asarray(coefs)
        if np.abs(times).max() < 1e-3:
            return  # degenerate all-zero surface
        model = fit(space, times)
        pred = np.asarray(model.predict(space))
        np.testing.assert_allclose(pred, times, rtol=1e-4, atol=1e-6)


class TestModelDatabase:
    def test_per_app_per_platform_isolation(self, tmp_path):
        db = ModelDatabase()
        space = grid([(5, 40, 5), (5, 40, 5)])
        model = fit(space, _cubic_surface(space))
        db.put("wordcount", "cluster-A", model)
        assert db.predict("wordcount", "cluster-A", [10, 10]) > 0
        with pytest.raises(KeyError, match="platform"):
            db.get("wordcount", "cluster-B")
        with pytest.raises(KeyError):
            db.get("eximparse", "cluster-A")

    def test_persistence_roundtrip(self, tmp_path):
        db = ModelDatabase()
        space = grid([(5, 40, 5), (5, 40, 5)])
        db.put("wc", "plat", fit(space, _cubic_surface(space)))
        path = str(tmp_path / "models.json")
        db.save(path)
        db2 = ModelDatabase.load(path)
        p = [17.0, 23.0]
        assert db2.predict("wc", "plat", p) == pytest.approx(
            db.predict("wc", "plat", p), rel=1e-9
        )

    def test_backend_keyed_roundtrip(self, tmp_path):
        """(application, platform, backend) keys survive save/load and stay
        isolated from the backend-less (paper-faithful) slot."""
        db = ModelDatabase()
        space = grid([(5, 40, 5), (5, 40, 5)])
        m_plain = fit(space, _cubic_surface(space))
        m_jnp = fit(space, 2.0 * _cubic_surface(space))
        m_xla = fit(space, 3.0 * _cubic_surface(space))
        db.put("wc", "plat", m_plain)
        db.put("wc", "plat", m_jnp, backend="jnp")
        db.put("wc", "plat", m_xla, backend="xla")
        assert len(db) == 3
        assert db.backends_for("wc", "plat") == ["", "jnp", "xla"]
        assert ("wc", "plat", "jnp") in db
        with pytest.raises(KeyError, match="backend"):
            db.get("wc", "plat", backend="pallas")
        path = str(tmp_path / "models.json")
        db.save(path)
        db2 = ModelDatabase.load(path)
        p = [17.0, 23.0]
        for backend in ("", "jnp", "xla"):
            assert db2.predict("wc", "plat", p, backend=backend) == (
                pytest.approx(db.predict("wc", "plat", p, backend=backend),
                              rel=1e-9)
            )

    def test_load_legacy_two_part_keys(self, tmp_path):
        """JSON written before the backend extension loads into backend=''."""
        import json

        space = grid([(5, 40, 5), (5, 40, 5)])
        model = fit(space, _cubic_surface(space))
        legacy = {"wc\x00plat": model.to_dict()}
        path = str(tmp_path / "legacy.json")
        with open(path, "w") as f:
            json.dump(legacy, f)
        db = ModelDatabase.load(path)
        assert db.applications() == [("wc", "plat", "")]
        assert db.predict("wc", "plat", [17.0, 23.0]) == pytest.approx(
            float(np.asarray(model.predict(np.asarray([17.0, 23.0]))).ravel()[0]),
            rel=1e-9,
        )
