"""Observability layer: spans, streaming metrics, drift, logging.

Four pillars under test:

* the P² streaming quantiles are accurate and deterministic (the bench
  gate compares committed p50/p99 values bit-for-bit);
* the span tree tiles every job's turnaround *exactly* — base-cluster,
  pipelined (negative-wall overlap phase), and elastic suspend-to-disk
  runs alike — and the Chrome export is well-formed with no two jobs
  sharing a worker slot at the same instant;
* the prediction ledger alarms on sustained category drift, stays silent
  on pathological single-sample ratios, and its scale hint drives
  ``OnlineRefiner.refit_category`` to an actually corrected model;
* trace serialization round-trips with a schema version and refuses
  versions it does not understand.
"""

from __future__ import annotations

import dataclasses
import io
import json

import numpy as np
import pytest

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.cluster.cluster import JobRecord, Plan, TraceResult
from repro.cluster.workload import JobSpec
from repro.elastic import ElasticCluster
from repro.obs import (
    LEDGER_SCHEMA_VERSION,
    ClusterMetrics,
    ControlAction,
    Logger,
    MetricsRegistry,
    P2Quantile,
    PredictionLedger,
    SpanRecorder,
    build_span_tree,
    check_span_tiling,
    render_slots,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.telemetry import TRACE_SCHEMA_VERSION, JobTrace


# --------------------------------------------------------------- quantiles


class TestP2Quantile:
    def test_exact_below_five(self):
        q = P2Quantile(0.5)
        for x in (5.0, 1.0, 3.0):
            q.add(x)
        assert q.value == 3.0

    @pytest.mark.parametrize("p", [0.5, 0.9, 0.99])
    def test_accuracy_vs_numpy(self, p):
        rng = np.random.default_rng(42)
        xs = rng.lognormal(0.0, 0.7, size=5000)
        q = P2Quantile(p)
        for x in xs:
            q.add(x)
        exact = float(np.quantile(xs, p))
        assert abs(q.value - exact) / exact < 0.08

    def test_deterministic(self):
        rng = np.random.default_rng(3)
        xs = rng.normal(10.0, 2.0, size=500)
        a, b = P2Quantile(0.99), P2Quantile(0.99)
        for x in xs:
            a.add(x)
            b.add(x)
        assert a.value == b.value

    def test_rejects_degenerate_p(self):
        with pytest.raises(ValueError):
            P2Quantile(0.0)
        with pytest.raises(ValueError):
            P2Quantile(1.0)


class TestClusterMetrics:
    def test_base_cluster_summary(self):
        oracle = AnalyticOracle(noise=0.02, seed=3)
        jobs = generate_workload(
            12, seed=3, mean_interarrival=0.1, size_range=(1 << 14, 1 << 16)
        )
        metrics = ClusterMetrics()
        cluster = Cluster(8, oracle, metrics=metrics)
        result = cluster.run(jobs, get_policy("fifo-static"))
        s = metrics.summary()
        assert s["jobs_completed"] == len(result.completed()) == 12
        assert s["p50_turnaround_s"] > 0
        assert s["p99_turnaround_s"] >= s["p50_turnaround_s"]
        assert s["goodput_tokens_per_s"] > 0
        # Gauges sampled at event granularity, series non-empty.
        g = metrics.registry.gauge("queue_depth")
        assert g.series and g.value == 0  # drained at run end

    def test_metrics_optional_and_equal_schedule(self):
        """metrics=None (default) must not change the schedule."""
        def run(metrics):
            oracle = AnalyticOracle(noise=0.02, seed=4)
            jobs = generate_workload(
                10, seed=4, mean_interarrival=0.1,
                size_range=(1 << 14, 1 << 16),
            )
            cluster = Cluster(6, oracle, metrics=metrics)
            r = cluster.run(jobs, get_policy("fifo-static"))
            return [(rec.spec.job_id, rec.start, rec.finish)
                    for rec in r.records]

        assert run(None) == run(ClusterMetrics())

    def test_elastic_regrant_counters(self):
        oracle = AnalyticOracle(noise=0.02, seed=7)
        jobs = generate_workload(
            30, seed=7, arrival="bursty", mean_interarrival=0.08,
            size_range=(1 << 14, 1 << 18),
        )
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=(1.1, 2.2), fraction=0.5, seed=8,
        )
        metrics = ClusterMetrics()
        cluster = ElasticCluster(
            8, oracle, snapshot_overhead_s=0.02, restore_overhead_s=0.02,
            metrics=metrics,
        )
        result = cluster.run(
            jobs, get_policy("predict-elastic", seed=7, suspend=True)
        )
        s = metrics.summary()
        assert s["n_regrants"] == result.metrics()["n_regrants"] > 0
        assert s["n_suspends"] > 0
        assert s["regrant_overhead_total_s"] > 0


# ------------------------------------------------------------------- spans


def _base_result(n_jobs=15, workers=8, seed=5):
    oracle = AnalyticOracle(noise=0.02, seed=seed)
    jobs = generate_workload(
        n_jobs, seed=seed, mean_interarrival=0.1,
        size_range=(1 << 14, 1 << 17),
    )
    return Cluster(workers, oracle).run(jobs, get_policy("fifo-static"))


def _elastic_suspend_result(seed=7):
    oracle = AnalyticOracle(noise=0.02, seed=seed)
    jobs = generate_workload(
        30, seed=seed, arrival="bursty", mean_interarrival=0.08,
        size_range=(1 << 14, 1 << 18),
    )
    jobs = assign_deadlines(
        jobs, lambda j: oracle.nominal_time(j.app, j.size),
        slack_range=(1.1, 2.2), fraction=0.5, seed=seed + 1,
    )
    cluster = ElasticCluster(
        8, oracle, snapshot_overhead_s=0.02, restore_overhead_s=0.02
    )
    return cluster.run(
        jobs, get_policy("predict-elastic", seed=seed, suspend=True)
    )


class TestSpanTiling:
    def test_base_run_tiles_exactly(self):
        result = _base_result()
        root = build_span_tree(result)
        assert check_span_tiling(root) == []
        # Every job span's children really do sum to its turnaround.
        for job in root.children:
            total = sum(c.wall_s for c in job.children)
            assert total == pytest.approx(job.wall_s, rel=1e-9, abs=1e-12)

    def test_elastic_suspend_run_tiles_exactly(self):
        result = _elastic_suspend_result()
        root = build_span_tree(result)
        assert check_span_tiling(root) == []
        kinds = {
            s.name for s in root.walk() if s.cat == "gap"
        }
        assert "suspended" in kinds, "suspend-to-disk gap must be spanned"
        # A suspended job's wait + segments + gaps tile its turnaround.
        suspended = [
            j for j in root.children if j.args.get("n_suspends", 0) > 0
        ]
        assert suspended
        for job in suspended:
            total = sum(c.wall_s for c in job.children)
            assert total == pytest.approx(job.wall_s, rel=1e-6)

    def test_negative_wall_pipeline_phase(self):
        """The pipelined mode's overlap phase has negative wall; it must
        participate in the tiling sum signed and export as an instant."""
        trace = JobTrace(app="wordcount", config={})
        trace.record_phase("map", 0.6)
        trace.record_phase("shuffle_reduce", 0.5)
        trace.record_phase("pipeline", -0.1, overlap_depth=2)
        spec = JobSpec(job_id=0, app="wordcount", size=1 << 14, arrival=0.0)
        rec = JobRecord(
            spec=spec,
            plan=Plan(backend="jnp", mappers=4, reducers=4, workers=2,
                      predicted_time=1.0, depth=2),
            start=0.5, finish=1.5, true_time=1.0, trace=trace,
        )
        result = TraceResult(policy="synthetic", total_workers=2,
                             records=[rec])
        root = build_span_tree(result)
        assert check_span_tiling(root) == []
        job = root.children[0]
        # wait 0.5 + phases (0.6 + 0.5 - 0.1) = 1.5 = turnaround.
        assert sum(c.wall_s for c in job.children) == pytest.approx(1.5)
        doc = to_chrome_trace(result)
        assert validate_chrome_trace(doc) == []
        instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
        assert any(e["name"] == "pipeline" for e in instants)

    def test_incomplete_jobs_excluded(self):
        spec = JobSpec(job_id=1, app="wordcount", size=1 << 14, arrival=0.0)
        rec = JobRecord(spec=spec, admitted=False, reject_reason="full")
        done = _base_result(n_jobs=5).records
        result = TraceResult(policy="mixed", total_workers=8,
                             records=done + [rec])
        root = build_span_tree(result)
        assert len(root.children) == len(done)


class TestChromeExport:
    def test_valid_and_slot_exclusive(self):
        result = _elastic_suspend_result()
        doc = to_chrome_trace(result)
        assert validate_chrome_trace(doc) == []
        # No two execution events may overlap on one worker slot.
        by_slot: dict = {}
        for e in doc["traceEvents"]:
            if e["ph"] == "X" and e["pid"] == 1 and e.get("cat") == "slot":
                by_slot.setdefault(e["tid"], []).append(
                    (e["ts"], e["ts"] + e["dur"])
                )
        assert by_slot, "expected pid-1 slot events"
        for tid, spans in by_slot.items():
            spans.sort()
            for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
                assert a1 <= b0 + 1, f"slot {tid} overlap: {a1} > {b0}"

    def test_counter_tracks_present(self):
        doc = to_chrome_trace(_elastic_suspend_result())
        counters = {
            e["name"] for e in doc["traceEvents"] if e["ph"] == "C"
        }
        assert {"queue_depth", "busy_workers", "suspended_jobs"} <= counters

    def test_recorder_roundtrip(self, tmp_path):
        rec = SpanRecorder()
        rec.record(_base_result(n_jobs=6))
        assert rec.check() == []
        assert rec.validate() == []
        path = tmp_path / "run.trace.json"
        rec.save_chrome(str(path))
        doc = json.loads(path.read_text())
        assert validate_chrome_trace(doc) == []

    def test_render_slots_ascii(self):
        out = render_slots(_base_result(n_jobs=6, workers=4), width=40)
        lines = out.splitlines()
        assert any(line.startswith("slot ") for line in lines)
        assert all(len(line) <= 100 for line in lines)


# ------------------------------------------------------------------- drift


class TestPredictionLedger:
    def test_alarm_fires_and_rearms(self):
        led = PredictionLedger(alpha=0.5, threshold=0.25, min_samples=3)
        alarms = [
            led.record("app", "jnp", predicted=1.0, realized=1.6, t=float(i))
            for i in range(6)
        ]
        fired = [a for a in alarms if a is not None]
        assert fired, "sustained 1.6x drift must alarm"
        first = fired[0]
        assert first.n >= 3
        assert 1.3 < first.scale_hint < 1.9
        # Re-armed: the state restarts counting after each alarm.
        assert led.ewma_error("app", "jnp") is None or first is not None
        assert len(fired) >= 2  # persistent drift keeps alarming

    def test_accurate_predictions_never_alarm(self):
        led = PredictionLedger()
        for i in range(50):
            assert led.record(
                "app", "jnp", predicted=1.0, realized=1.02, t=float(i)
            ) is None
        assert not led.alarms

    def test_pathological_ratio_is_outlier_not_alarm(self):
        """A floored/clamped prediction (realized/predicted ~ 400x) must
        not poison the EWMAs — it carries no scale information."""
        led = PredictionLedger(min_samples=1)
        for i in range(10):
            a = led.record(
                "app", "jnp", predicted=0.001, realized=0.4, t=float(i)
            )
            assert a is None
        assert led.n_outliers == 10
        assert led.ewma_error("app", "jnp") is None
        # ...but the pairs are still retained for reporting.
        assert led.category_mae_pct("app", "jnp") is not None

    def test_ratio_clip_validation(self):
        with pytest.raises(ValueError):
            PredictionLedger(ratio_clip=(1.5, 4.0))
        with pytest.raises(ValueError):
            PredictionLedger(ratio_clip=(0.5, 0.9))

    def test_to_dict(self):
        led = PredictionLedger()
        led.record("app", "jnp", 1.0, 1.6)
        d = led.to_dict()
        assert d["n_records"] == 1
        assert "app/jnp" in d["categories"]


class TestRefitCategory:
    def _policy_with_models(self):
        """Bootstrap a predictive policy so its db holds real models."""
        oracle = AnalyticOracle(noise=0.02, seed=11)
        jobs = generate_workload(
            4, seed=11, mean_interarrival=0.5,
            size_range=(1 << 14, 1 << 16), apps=("wordcount",),
        )
        policy = get_policy("predict-sjf", seed=11)
        Cluster(8, oracle).run(jobs, policy)
        return policy

    def test_scale_hint_rescales_predictions(self):
        policy = self._policy_with_models()
        refiner = policy.refiner
        app, cat = "wordcount", policy.categories[0]
        before = refiner.db.get(app, policy.platform, backend=cat)
        row = np.asarray([[8.0, 8.0, 4.0, 1.0]])
        from repro.cluster.policies import _np_predict

        p_before = float(_np_predict(before, row)[0])
        assert refiner.refit_category(
            app, cat, keep_last=4, scale_hint=2.0
        )
        after = refiner.db.get(app, policy.platform, backend=cat)
        p_after = float(_np_predict(after, row)[0])
        assert p_after == pytest.approx(2.0 * p_before, rel=1e-9)
        assert refiner.n_drift_refits == 1

    def test_no_hint_no_rows_returns_false(self):
        policy = self._policy_with_models()
        assert not policy.refiner.refit_category(
            "wordcount", policy.categories[0], scale_hint=None
        )

    def test_drift_alarms_trigger_refits_end_to_end(self):
        oracle = AnalyticOracle(
            noise=0.02, seed=7, shift_after_job=20, shift_factor=2.0
        )
        jobs = generate_workload(
            60, seed=7, mean_interarrival=0.3,
            size_range=(1 << 14, 1 << 16),
        )
        ledger = PredictionLedger()
        policy = get_policy("predict-sjf", seed=7, ledger=ledger)
        Cluster(12, oracle).run(jobs, policy)
        assert policy.n_drift_alarms > 0
        assert policy.refiner.n_drift_refits > 0
        assert len(ledger.alarms) == policy.n_drift_alarms


class TestOracleShift:
    def test_shift_applies_mid_trace_only(self):
        plain = AnalyticOracle(noise=0.0, seed=1)
        shifted = AnalyticOracle(
            noise=0.0, seed=1, shift_after_job=30, shift_factor=1.6
        )
        kw = dict(mappers=8, reducers=8, workers=4)
        t_pre = plain.time("wordcount", "jnp", 1 << 15, job_id=5, **kw)
        assert shifted.time(
            "wordcount", "jnp", 1 << 15, job_id=5, **kw
        ) == pytest.approx(t_pre)
        assert shifted.time(
            "wordcount", "jnp", 1 << 15, job_id=30, **kw
        ) == pytest.approx(1.6 * t_pre)

    def test_profiling_jobs_exempt(self):
        from repro.cluster.oracle import PROFILE_JOB_ID

        shifted = AnalyticOracle(
            noise=0.0, seed=1, shift_after_job=0, shift_factor=3.0
        )
        plain = AnalyticOracle(noise=0.0, seed=1)
        kw = dict(mappers=8, reducers=8, workers=4)
        assert shifted.time(
            "wordcount", "jnp", 1 << 15, job_id=PROFILE_JOB_ID + 1, **kw
        ) == pytest.approx(
            plain.time(
                "wordcount", "jnp", 1 << 15, job_id=PROFILE_JOB_ID + 1, **kw
            )
        )


# ----------------------------------------------------------- serialization


class TestTraceSchema:
    def _trace(self):
        t = JobTrace(app="wordcount", config={"mappers": 4, "input_len": 9})
        t.record_phase("map", 0.25, pairs_emitted=12)
        t.record_phase("shuffle", 0.1, bytes_in=96, bytes_out=96,
                       bytes_dropped=0, pairs_in=12, pairs_out=12,
                       pairs_dropped=0)
        t.finish(0.35)
        return t

    def test_round_trip(self):
        t = self._trace()
        s = t.to_json()
        back = JobTrace.from_json(s)
        assert back.to_dict() == t.to_dict()
        assert json.loads(s)["schema"] == TRACE_SCHEMA_VERSION

    def test_legacy_dict_without_schema_loads(self):
        d = self._trace().to_dict()
        del d["schema"]
        assert JobTrace.from_dict(d).app == "wordcount"

    def test_unsupported_version_rejected(self):
        d = self._trace().to_dict()
        d["schema"] = TRACE_SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema version"):
            JobTrace.from_dict(d)

    def test_non_object_json_rejected(self):
        with pytest.raises(ValueError, match="JSON object"):
            JobTrace.from_json("[1, 2, 3]")


class TestMalformedBaseline:
    def test_load_committed_reports_malformed(self, tmp_path):
        from benchmarks.run import load_committed

        good = {"status": "ok", "summary": {"makespan_s": 1.0}}
        (tmp_path / "BENCH_cluster.json").write_text(json.dumps(good))
        (tmp_path / "BENCH_elastic.json").write_text('{"status": "ok", ')
        (tmp_path / "BENCH_obs.json").write_text('["not", "a", "dict"]')
        committed, malformed = load_committed(
            str(tmp_path), ["cluster", "elastic", "obs", "pipeline"]
        )
        assert set(committed) == {"cluster"}
        assert sorted(malformed) == ["elastic", "obs"]

    def test_gate_survives_malformed_baseline(self, tmp_path):
        """End-to-end: --check over a truncated baseline must warn, not
        crash with a raw traceback."""
        from benchmarks.run import check_regressions, load_committed

        (tmp_path / "BENCH_obs.json").write_text('{"truncated...')
        committed, malformed = load_committed(str(tmp_path), ["obs"])
        assert malformed == ["obs"]
        # Malformed baselines are excluded from comparison entirely.
        assert check_regressions(committed, {"obs": {"status": "ok"}}) == []


# ----------------------------------------------------------------- logging


class TestLogger:
    def test_text_mode(self):
        buf = io.StringIO()
        log = Logger("sim", stream=buf)
        log.info("dispatch", msg="job 3 started", workers=4)
        assert buf.getvalue() == "[sim] job 3 started workers=4\n"

    def test_json_mode(self):
        buf = io.StringIO()
        log = Logger("sim", json_lines=True, stream=buf)
        log.warning("regrant", job_id=3, overhead_s=0.02)
        rec = json.loads(buf.getvalue())
        assert rec == {
            "logger": "sim", "level": "warning", "event": "regrant",
            "job_id": 3, "overhead_s": 0.02,
        }

    def test_level_filtering(self):
        buf = io.StringIO()
        log = Logger("sim", level="warning", stream=buf)
        log.debug("noise")
        log.info("noise")
        assert buf.getvalue() == ""
        log.error("boom")
        assert "boom" in buf.getvalue()

    def test_rejects_unknown_level(self):
        with pytest.raises(ValueError):
            Logger("sim", level="verbose")
        with pytest.raises(ValueError):
            Logger("sim").log("chatty", "event")


# ----------------------------------------------------- service-mode spans


class TestSpanRetention:
    def test_ring_keeps_last_max_jobs(self):
        result = _base_result(n_jobs=20)
        rec = SpanRecorder(max_jobs=5)
        root = rec.record(result)
        assert len(root.children) == 5
        assert rec.n_dropped_jobs == 15
        assert rec.n_dropped_spans > 0
        done = sorted(
            (r for r in result.records if r.completed),
            key=lambda r: (r.finish, r.spec.job_id),
        )
        expect = {r.spec.job_id for r in done[-5:]}
        assert {s.args["job_id"] for s in root.children} == expect

    def test_tiling_holds_on_retained_window(self):
        rec = SpanRecorder(max_jobs=5)
        rec.record(_base_result(n_jobs=20))
        assert rec.check() == []
        assert rec.validate() == []

    def test_no_drop_when_under_limit(self):
        rec = SpanRecorder(max_jobs=100)
        root = rec.record(_base_result(n_jobs=8))
        assert len(root.children) == 8
        assert rec.n_dropped_jobs == 0
        assert rec.n_dropped_spans == 0

    def test_validation(self):
        with pytest.raises(ValueError, match="max_jobs"):
            SpanRecorder(max_jobs=0)


class TestControlTracks:
    def _log(self):
        return [
            ControlAction(t=1.0, action="trip", job_id=None,
                          reason="burning", burn_fast=3.2, burn_slow=2.1),
            ControlAction(t=1.5, action="shed", job_id=4,
                          reason="queue > floor", burn_fast=3.0,
                          burn_slow=2.0),
            ControlAction(t=9.0, action="clear", job_id=None,
                          reason="recovered", burn_fast=0.1, burn_slow=0.4),
        ]

    def test_control_log_renders_pid3_tracks(self):
        doc = to_chrome_trace(
            _base_result(n_jobs=6), control_log=self._log()
        )
        assert validate_chrome_trace(doc) == []
        ev3 = [e for e in doc["traceEvents"] if e["pid"] == 3]
        inst = [e for e in ev3 if e["ph"] == "i"]
        assert {e["args"]["action"] for e in inst} == {
            "trip", "shed", "clear"
        }
        assert "shed job 4" in {e["name"] for e in inst}
        counters = [e for e in ev3 if e["ph"] == "C"]
        assert {e["name"] for e in counters} == {
            "slo_burn_fast", "slo_burn_slow"
        }
        assert all(
            isinstance(e["args"]["value"], float) for e in counters
        )

    def test_recorder_attaches_control_log(self):
        rec = SpanRecorder()
        rec.record(_base_result(n_jobs=6), control_log=self._log())
        doc = rec.chrome()
        assert any(e["pid"] == 3 for e in doc["traceEvents"])
        assert rec.validate() == []

    def test_no_log_no_control_tracks(self):
        doc = to_chrome_trace(_base_result(n_jobs=6))
        assert not any(e["pid"] == 3 for e in doc["traceEvents"])


# ----------------------------------------------------- ledger persistence


class TestLedgerSchema:
    def _ledger(self):
        led = PredictionLedger(min_samples=2, threshold=0.2)
        led.record("wordcount", "jnp", 1.0, 1.5, t=0.5)
        led.record("wordcount", "jnp", 1.0, 1.6, t=1.0)    # -> drift alarm
        led.record("sort", "jnp/d2", 2.0, 2.1, t=2.0)      # "/" in category
        led.record("sort", "jnp/d2", 2.0, 40.0, t=3.0)     # ratio outlier
        return led

    def test_round_trip_exact(self):
        led = self._ledger()
        assert led.alarms and led.n_outliers == 1
        s = led.to_json()
        assert json.loads(s)["schema"] == LEDGER_SCHEMA_VERSION
        back = PredictionLedger.from_json(s)
        assert back.state_dict() == led.state_dict()
        assert back.categories() == led.categories()
        assert len(back.alarms) == len(led.alarms)

    def test_restored_ledger_continues_identically(self):
        a = self._ledger()
        b = PredictionLedger.from_json(a.to_json())
        ra = a.record("wordcount", "jnp", 1.0, 1.4, t=4.0)
        rb = b.record("wordcount", "jnp", 1.0, 1.4, t=4.0)
        assert (ra is None) == (rb is None)
        assert a.ewma_error("wordcount", "jnp") == b.ewma_error(
            "wordcount", "jnp"
        )
        assert a.state_dict() == b.state_dict()

    def test_legacy_dict_without_schema_loads(self):
        d = self._ledger().state_dict()
        del d["schema"]
        assert PredictionLedger.from_state_dict(d).n_records == 4

    def test_future_version_rejected(self):
        d = self._ledger().state_dict()
        d["schema"] = LEDGER_SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema version"):
            PredictionLedger.from_state_dict(d)

    def test_non_dict_rejected(self):
        with pytest.raises(ValueError):
            PredictionLedger.from_state_dict([1, 2])


# -------------------------------------------------------- prom exposition


class TestPromGolden:
    def _registry(self):
        reg = MetricsRegistry()
        reg.counter("jobs_completed").inc(7)
        reg.counter("jobs_rejected").inc(2)
        reg.gauge("queue_depth").set(3.0)
        h = reg.histogram("turnaround_s", quantiles=(0.5, 0.99))
        for i in range(1, 21):
            h.observe(float(i) / 4.0)
        # The fabric/CPU gauges the resource timeline publishes.
        reg.gauge("fabric_bytes_per_s").set(1.5e6)
        reg.gauge("fabric_utilization").set(0.75)
        reg.gauge("fabric_net_peak_bytes_per_s").set(2.5e6)
        reg.gauge("cluster_cpu_mean_busy").set(5.5)
        reg.counter("contended_jobs").inc(3)
        reg.counter("fabric_over_capacity_episodes").inc(2)
        return reg

    def test_matches_golden_file(self):
        import pathlib

        golden = (
            pathlib.Path(__file__).with_name("data") / "metrics_golden.prom"
        )
        assert self._registry().to_prom_text() == golden.read_text()

    def test_save_prom_round_trip(self, tmp_path):
        p = tmp_path / "m.prom"
        reg = self._registry()
        reg.save_prom(str(p))
        assert p.read_text() == reg.to_prom_text()

    def test_byte_stable(self):
        assert (
            self._registry().to_prom_text()
            == self._registry().to_prom_text()
        )


# ------------------------------------------------------------- determinism


class TestDeterminism:
    def test_metrics_deterministic_across_runs(self):
        def once():
            oracle = AnalyticOracle(noise=0.02, seed=9)
            jobs = generate_workload(
                15, seed=9, mean_interarrival=0.1,
                size_range=(1 << 14, 1 << 16),
            )
            m = ClusterMetrics()
            Cluster(8, oracle, metrics=m).run(jobs, get_policy("fifo-static"))
            return m.summary()

        a, b = once(), once()
        assert a == b

    def test_chrome_export_deterministic(self):
        docs = [
            json.dumps(to_chrome_trace(_base_result(n_jobs=8)),
                       sort_keys=True)
            for _ in range(2)
        ]
        assert docs[0] == docs[1]
