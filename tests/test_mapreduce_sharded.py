"""Sharded (all_to_all) MapReduce path — needs >1 device, so runs in a
subprocess with forced host device count.

Covers the multi-device halves of the ExecutionPlan mode-equivalence
story (the W=1 in-process halves live in tests/test_plan.py): the real
4-device mesh mode vs the single-controller modes, the emulated
(resumable) collective vs the real one, per-phase wall times on the
sharded path, and cross-shard-reduced overflow counters.
"""

import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np
from collections import Counter
from repro.mapreduce import (ExecutionPlan, JobConfig, build_job,
                             build_job_sharded, collect_results, wordcount,
                             wordcount_corpus)
from repro.telemetry import PhaseRecorder

mesh = jax.make_mesh((4,), ("workers",))
corpus = wordcount_corpus(5000, vocab_size=129, seed=11)
app = wordcount(129)
for (M, R), backend in [((8, 6), "jnp"), ((5, 9), "pallas"), ((4, 4), "xla")]:
    cfg = JobConfig(num_mappers=M, num_reducers=R, num_workers=4,
                    capacity_factor=12.0, reduce_backend=backend)
    plan = ExecutionPlan(app, cfg, len(corpus))
    ok, ov, dropped = plan.sharded(mesh)(corpus)
    assert int(dropped) == 0, (M, R)
    got = collect_results(ok, ov)
    want = dict(Counter(corpus.tolist()))
    assert got == want, (M, R, len(got), len(want))
    # equivalence with the single-controller path
    cfg1 = JobConfig(num_mappers=M, num_reducers=R, capacity_factor=12.0)
    ok1, ov1, d1 = build_job(app, cfg1, len(corpus))(corpus)
    assert collect_results(ok1, ov1) == got
    # emulated collective (the resumable/fused a2a mode at W=4) must be
    # bit-exact against the real 4-device mesh run (one backend is
    # enough: the emulated/real split is shuffle-side, backend-agnostic)
    if backend == "jnp":
        a2a = JobConfig(num_mappers=M, num_reducers=R, num_workers=4,
                        capacity_factor=12.0, reduce_backend=backend,
                        shuffle_backend="all_to_all")
        plan_a2a = ExecutionPlan(app, a2a, len(corpus))
        ok_e, ov_e, d_e = plan_a2a.fused()(corpus)
        assert np.array_equal(np.asarray(ok_e), np.asarray(ok)), (M, R)
        assert np.array_equal(np.asarray(ov_e), np.asarray(ov)), (M, R)
        assert int(d_e) == int(dropped), (M, R)
# config-driven route: shuffle backend selected via JobConfig
cfg = JobConfig(num_mappers=6, num_reducers=5, num_workers=4,
                capacity_factor=12.0, shuffle_backend="all_to_all")
ok, ov, d = build_job(app, cfg, len(corpus), mesh=mesh)(corpus)
assert int(d) == 0
assert collect_results(ok, ov) == dict(Counter(corpus.tolist()))
# per-phase wall times on the REAL sharded path: three fenced mesh
# programs, counters cross-shard reduced, same outputs as the fused mode
rec = PhaseRecorder()
ok_t, ov_t, d_t = build_job(app, cfg, len(corpus), mesh=mesh,
                            recorder=rec)(corpus)
assert np.array_equal(np.asarray(ok_t), np.asarray(ok))
assert int(d_t) == 0
trace = rec.last
assert trace.phase_names() == ["map", "shuffle", "reduce"]
assert all(p.wall_s > 0 for p in trace.phases)
assert trace.check_conservation() == []
assert trace.counter("map", "pairs_emitted") == len(corpus)
# per-phase dropped counters, cross-shard reduced: max-skew corpus (one
# key) overflows the per-(src, dst) send buffers at W=4
skew = np.zeros(600, dtype=np.int32)
cfg = JobConfig(num_mappers=2, num_reducers=4, num_workers=4,
                capacity_factor=1.0)
ok, ov, d, stats = build_job_sharded(app, cfg, len(skew), mesh,
                                     counters=True)(skew)
assert stats["dropped_per_worker"].shape == (4, 2)
assert stats["dropped_send"] + stats["dropped_recv"] == int(d) > 0
assert stats["dropped_send"] > 0  # skew saturates the send stage
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_engine_matches_global(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": str(tmp_path)},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "SHARDED_OK" in proc.stdout, proc.stderr[-3000:]
