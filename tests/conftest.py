"""Suite-wide test config.

Provides a deterministic ``hypothesis`` fallback when the real library is
unavailable (this container has no network installs): the shim in
``tests/_hypothesis_shim.py`` is registered under the ``hypothesis`` module
name before test modules import it.
"""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    _shim_path = pathlib.Path(__file__).with_name("_hypothesis_shim.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
