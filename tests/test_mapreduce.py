"""MapReduce engine correctness: wave scheduling, shuffle, reduce, apps."""

import math
from collections import Counter

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.mapreduce import (
    JobConfig,
    build_job,
    collect_results,
    exim_mainlog,
    eximparse,
    wordcount,
    wordcount_corpus,
)


def _exim_oracle(log: np.ndarray, M: int) -> dict:
    """Total bytes per txn; records straddling split boundaries are dropped
    (static split alignment, matching the engine)."""
    S = math.ceil(len(log) / M)
    want: dict[int, int] = {}
    for m in range(M):
        split = log[m * S:(m + 1) * S]
        for i in range(len(split) // 3):
            t, _, s = split[3 * i:3 * i + 3]
            want[int(t)] = want.get(int(t), 0) + int(s)
    return want


class TestWordCount:
    @pytest.mark.parametrize("M,R", [(1, 1), (4, 3), (7, 5), (13, 2), (3, 11)])
    def test_matches_counter(self, M, R):
        corpus = wordcount_corpus(4000, vocab_size=257, seed=M * 100 + R)
        app = wordcount(257)
        cfg = JobConfig(num_mappers=M, num_reducers=R, capacity_factor=8.0)
        ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
        assert int(dropped) == 0
        assert collect_results(ok, ov) == dict(Counter(corpus.tolist()))

    def test_combiner_equivalence(self):
        corpus = wordcount_corpus(4000, vocab_size=300, seed=7)
        app = wordcount(300)
        base = JobConfig(num_mappers=5, num_reducers=4, capacity_factor=8.0)
        comb = JobConfig(num_mappers=5, num_reducers=4, capacity_factor=8.0,
                         combiner=True)
        r1 = build_job(app, base, len(corpus))(corpus)
        r2 = build_job(app, comb, len(corpus))(corpus)
        assert collect_results(r1[0], r1[1]) == collect_results(r2[0], r2[1])

    def test_capacity_overflow_is_counted_not_silent(self):
        corpus = np.zeros(1000, dtype=np.int32)  # all one key: max skew
        app = wordcount(16)
        cfg = JobConfig(num_mappers=2, num_reducers=8, capacity_factor=1.0)
        ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
        got = collect_results(ok, ov)
        assert int(dropped) > 0
        assert got[0] + int(dropped) == 1000  # conservation

    @given(
        n=st.integers(200, 2000),
        m=st.integers(1, 12),
        r=st.integers(1, 12),
        vocab=st.integers(2, 64),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_lossless_aggregation(self, n, m, r, vocab, seed):
        corpus = wordcount_corpus(n, vocab_size=vocab, seed=seed)
        app = wordcount(vocab)
        cfg = JobConfig(num_mappers=m, num_reducers=r, capacity_factor=16.0)
        ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
        assert int(dropped) == 0
        got = collect_results(ok, ov)
        assert sum(got.values()) == n
        assert got == dict(Counter(corpus.tolist()))


class TestEximParse:
    @pytest.mark.parametrize("M,R", [(6, 4), (2, 9)])
    def test_per_transaction_bytes(self, M, R):
        log = exim_mainlog(6000, n_transactions=50, seed=3)
        app = eximparse(50)
        cfg = JobConfig(num_mappers=M, num_reducers=R, capacity_factor=8.0)
        ok, ov, dropped = build_job(app, cfg, len(log))(log)
        assert int(dropped) == 0
        assert collect_results(ok, ov) == _exim_oracle(log, M)


class TestWaveScheduling:
    def test_wave_counts(self):
        cfg = JobConfig(num_mappers=10, num_reducers=7, num_workers=4)
        assert cfg.map_waves == 3
        assert cfg.reduce_waves == 2

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError):
            JobConfig(num_mappers=0, num_reducers=1)
