"""Resource observability: CPU/net phase counters, the shared fabric,
and the cluster resource timeline.

Covers the PR's three layers end to end: the measurement protocol
(``cpu_s`` / ``cpu_workers`` / ``net_bytes`` / ``net_s`` at the phase
fences, with conservation laws) across every execution-plan mode; the
contention-aware ground truth (:class:`SharedFabric` fair-share pricing
+ the audited per-job ``contention`` phase); and the cluster-wide fold
(:class:`ResourceTimeline` series, episodes, gauges, Chrome tracks).
"""

from collections import Counter

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    generate_workload,
    get_policy,
)
from repro.cluster.oracle import SharedFabric
from repro.elastic import run_resumable
from repro.mapreduce import (
    ExecutionPlan,
    JobConfig,
    collect_results,
    wordcount,
    wordcount_corpus,
)
from repro.obs import (
    MetricsRegistry,
    ResourceTimeline,
    SpanRecorder,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.telemetry import JobTrace, PhaseRecorder
from repro.telemetry.trace import PAIR_BYTES

CORPUS = wordcount_corpus(360, vocab_size=53, seed=9)
APP = wordcount(53)
WANT = dict(Counter(np.asarray(CORPUS).tolist()))
#: every emitted pair crosses the fabric: wordcount emits one pair per
#: token, so the on-wire bytes are an exact form of the input size.
NET_BYTES = len(CORPUS) * PAIR_BYTES


def _cfg(**kw):
    kw.setdefault("num_mappers", 5)
    kw.setdefault("num_reducers", 3)
    kw.setdefault("num_workers", 2)
    kw.setdefault("capacity_factor", 8.0)
    return JobConfig(**kw)


def _merged(traces):
    merged = JobTrace(app=traces[0].app, config=dict(traces[0].config))
    for t in traces:
        merged.phases.extend(t.phases)
    merged.finish(sum(t.total_s for t in traces))
    return merged


class TestModeCounters:
    """The resource-counter protocol holds in every plan mode, and the
    deterministic fabric total agrees across all of them (traced is the
    fenced lowering of fused, so fused is covered by construction)."""

    @pytest.fixture(scope="class")
    def mesh1(self):
        return jax.make_mesh((1,), ("workers",))

    def _check(self, trace):
        assert trace.check_conservation() == []
        for phase in ("map", "shuffle", "reduce"):
            p = trace.phase(phase)
            assert p.counters["cpu_s"] >= 0.0, phase
            assert p.counters["cpu_workers"] >= 1.0, phase
        sh = trace.phase("shuffle")
        assert sh.counters["net_bytes"] == NET_BYTES
        assert sh.counters["net_s"] >= 0.0

    def test_traced_counters(self):
        recorder = PhaseRecorder()
        plan = ExecutionPlan(APP, _cfg(), len(CORPUS))
        out = plan.traced(recorder)(CORPUS)
        assert collect_results(out[0], out[1]) == WANT
        self._check(recorder.last)

    def test_pipelined_traced_counters(self):
        recorder = PhaseRecorder()
        plan = ExecutionPlan(APP, _cfg(overlap_depth=2), len(CORPUS))
        plan.traced(recorder)(CORPUS)
        trace = recorder.last
        self._check(trace)
        # Host bookkeeping moves no fabric bytes: the pipeline phase's
        # zero is recorded and law-checked, not merely absent.
        pipe = trace.phase("pipeline")
        assert pipe.counters["net_bytes"] == 0.0

    def test_sharded_traced_counters(self, mesh1):
        recorder = PhaseRecorder()
        cfg = _cfg(num_workers=1, shuffle_backend="all_to_all")
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        plan.sharded(mesh1, recorder=recorder)(CORPUS)
        self._check(recorder.last)

    def test_resumable_counters(self):
        recorder = PhaseRecorder()
        plan = ExecutionPlan(APP, _cfg(), len(CORPUS))
        job = plan.resumable(recorder=recorder)
        run_resumable(job, CORPUS)
        self._check(_merged(recorder.traces))

    def test_law_violations_are_caught(self):
        # Fabric bytes outside the shuffle.
        t = JobTrace(app="x", config={})
        t.record_phase("map", 1.0, net_bytes=64.0)
        assert any("only shuffle" in v for v in t.check_conservation())
        # On-wire bytes must be the exact pair form.
        t = JobTrace(app="x", config={})
        t.record_phase(
            "shuffle", 1.0, pairs_in=10, pairs_out=10, pairs_dropped=0,
            net_bytes=7.0,
        )
        assert any("PAIR_BYTES" in v for v in t.check_conservation())
        # The wire cannot run for negative seconds.
        t = JobTrace(app="x", config={})
        t.record_phase("shuffle", 1.0, net_s=-0.5)
        assert any("net_s" in v for v in t.check_conservation())
        # CPU seconds cannot exceed wall x the parallelism ceiling.
        t = JobTrace(app="x", config={})
        t.record_phase("reduce", 1.0, cpu_s=9.0, cpu_workers=2.0)
        assert any("cpu_s" in v for v in t.check_conservation())

    def test_negative_wall_phase_exempt_from_cpu_law(self):
        # The analytic pipelined trace books overlap as negative wall;
        # it must not trip the per-phase CPU law.
        oracle = AnalyticOracle(noise=0.0)
        oracle.time("wordcount", "jnp", 1 << 14, 8, 8, 4, depth=2)
        trace = oracle.take_trace()
        pipe = trace.phase("pipeline")
        assert pipe.wall_s < 0
        assert pipe.counters["net_bytes"] == 0.0
        assert trace.check_conservation() == []


class TestAnalyticResourceCounters:
    def test_cpu_within_wall_budget(self):
        oracle = AnalyticOracle(noise=0.0)
        oracle.time("wordcount", "jnp", 1 << 15, 8, 8, 4)
        trace = oracle.take_trace()
        assert trace.check_conservation() == []
        for phase in ("map", "shuffle", "reduce"):
            p = trace.phase(phase)
            assert 0.0 <= p.counters["cpu_s"] <= p.wall_s * 4 + 1e-9
        assert trace.counter("shuffle", "net_bytes") == (1 << 15) * 8

    def test_profile_exposes_cpu_and_net(self):
        oracle = AnalyticOracle(noise=0.0)
        prof = oracle.phase_profile("wordcount", "jnp", 1 << 14, 8, 8, 4)
        assert set(prof["cpu_s"]) == {"map", "shuffle", "reduce"}
        assert prof["net_bytes"] == prof["shuffle_bytes"]
        assert all(v >= 0 for v in prof["cpu_s"].values())


class TestSharedFabric:
    def test_uncontended_transfer_has_no_stretch(self):
        fabric = SharedFabric(100.0)
        assert fabric.admit(0, 0.0, 2.0, 150.0) == 0.0  # 75 B/s < 100
        assert fabric.episodes == []

    def test_fair_share_stretch_hand_checked(self):
        # t=0: job 0 moves 100 B in 1 s (rate 100 = capacity, alone ok).
        # t=0: job 1 wants 100 B in 1 s too -> demand 200 vs capacity
        # 100: both halves run at fair share 50 B/s, so job 1 drains
        # 50 B by t=1 and the rest at full rate 100: done at t=1.5.
        fabric = SharedFabric(100.0)
        assert fabric.admit(0, 0.0, 1.0, 100.0) == 0.0
        stretch = fabric.admit(1, 0.0, 1.0, 100.0)
        assert stretch == pytest.approx(0.5)
        (ep,) = fabric.episodes
        assert ep["job_id"] == 1
        assert ep["peak_bytes_per_s"] == pytest.approx(200.0)
        assert ep["contention_s"] == pytest.approx(0.5)

    def test_disjoint_transfers_never_interact(self):
        fabric = SharedFabric(10.0)
        assert fabric.admit(0, 0.0, 1.0, 9.0) == 0.0
        assert fabric.admit(1, 5.0, 1.0, 9.0) == 0.0
        assert fabric.contention_s_total == 0.0

    def test_prune_drops_finished_transfers(self):
        fabric = SharedFabric(10.0)
        fabric.admit(0, 0.0, 1.0, 9.0)
        fabric.admit(1, 10.0, 1.0, 9.0)
        fabric.prune(5.0)
        assert len(fabric._transfers) == 1

    @given(
        starts=st.lists(
            st.floats(min_value=0.0, max_value=50.0), min_size=2,
            max_size=8,
        ),
        gap=st.floats(min_value=0.01, max_value=2.0),
    )
    @settings(max_examples=25)
    def test_disjoint_lifetimes_never_reorder(self, starts, gap):
        """Property: transfers with disjoint windows are causally
        independent — zero stretch each, so completion order stays the
        arrival order of the windows."""
        fabric = SharedFabric(25.0)
        t, windows = 0.0, []
        for i, s in enumerate(sorted(starts)):
            start = t + s  # strictly after the previous window closed
            nominal = 0.5 + gap
            stretch = fabric.admit(i, start, nominal, 20.0 * nominal)
            assert stretch == 0.0
            windows.append((i, start + nominal + stretch))
            t = start + nominal + gap
        finishes = [f for _, f in windows]
        assert finishes == sorted(finishes)
        assert fabric.episodes == []


class TestClusterContention:
    def _contended(self):
        oracle = AnalyticOracle(noise=0.02, seed=3)
        jobs = generate_workload(
            12, seed=3, arrival="bursty", mean_interarrival=0.02,
            size_range=(1 << 16, 1 << 18),
        )
        policy = get_policy(
            "fifo-static", workers=2, mappers=8, reducers=8
        )
        return Cluster(8, oracle, net_capacity=2e5).run(jobs, policy)

    def test_contention_stretches_and_audits(self):
        result = self._contended()
        m = result.metrics()
        assert m["n_contended_jobs"] > 0
        assert m["contention_s_total"] > 0
        assert result.net_capacity == 2e5
        assert result.contention_episodes
        for rec in result.records:
            if rec.contention_s:
                names = rec.trace.phase_names()
                # audited right after the shuffle it stretched
                assert names.index("contention") == (
                    names.index("shuffle") + 1
                )
                p = rec.trace.phase("contention")
                assert p.wall_s == pytest.approx(rec.contention_s)
                assert p.counters["net_bytes"] == 0.0
                assert p.counters["cpu_s"] == 0.0
            # walls still tile the audited turnaround exactly
            assert rec.trace.check_conservation() == []
            assert rec.trace.phase_time_sum() == pytest.approx(
                rec.true_time
            )

    def test_span_tiling_closes_over_contention(self):
        result = self._contended()
        rec = SpanRecorder()
        rec.record(result)
        assert rec.check() == []

    def test_slower_than_uncontended(self):
        contended = self._contended()
        oracle = AnalyticOracle(noise=0.02, seed=3)
        jobs = generate_workload(
            12, seed=3, arrival="bursty", mean_interarrival=0.02,
            size_range=(1 << 16, 1 << 18),
        )
        policy = get_policy(
            "fifo-static", workers=2, mappers=8, reducers=8
        )
        free = Cluster(8, oracle).run(jobs, policy)
        assert (
            contended.metrics()["makespan_s"]
            > free.metrics()["makespan_s"]
        )

    def test_rejects_oracle_that_cannot_price_contention(self):
        class Blind:
            platform = "blind"

            def time(self, *a, **k):
                return 1.0

        with pytest.raises(ValueError, match="cannot price contention"):
            Cluster(8, Blind(), net_capacity=1e6)
        Cluster(8, Blind())  # unconstrained fabric stays fine


class TestResourceTimeline:
    def _result(self):
        return TestClusterContention()._contended()

    def test_series_and_episodes(self):
        tl = ResourceTimeline.from_result(self._result())
        assert tl.has_data
        s = tl.summary()
        # nominal demand exceeds the budget that stretched the run
        assert s["net_peak_bytes_per_s"] > 2e5
        assert s["n_over_capacity_episodes"] > 0
        assert s["over_capacity_s"] > 0
        assert s["net_peak_utilization"] > 1.0
        assert 0 < s["cpu_peak_busy"] <= 8.0
        for e in tl.over_capacity_episodes():
            assert e["t1"] > e["t0"]
            assert e["peak_bytes_per_s"] > e["capacity"]

    def test_series_levels_close_to_zero(self):
        tl = ResourceTimeline.from_result(self._result())
        for series in (tl.net_series(), tl.cpu_series()):
            assert series[-1][1] == pytest.approx(0.0, abs=1e-9)
            assert all(level > -1e-9 for _, level in series)

    def test_publish_gauges(self):
        registry = MetricsRegistry()
        tl = ResourceTimeline.from_result(self._result())
        summary = tl.publish(registry)
        text = registry.to_prom_text()
        assert "fabric_net_peak_bytes_per_s" in text
        assert "cluster_cpu_mean_busy" in text
        assert "fabric_over_capacity_episodes" in text
        assert summary == tl.summary()

    def test_chrome_counter_tracks(self):
        result = self._result()
        doc = to_chrome_trace(result)
        assert validate_chrome_trace(doc) == []
        names = {e["name"] for e in doc["traceEvents"]
                 if e.get("ph") == "C"}
        assert {"fabric_bytes_per_s", "fabric_capacity",
                "busy_cpu"} <= names
        procs = {
            e["args"]["name"] for e in doc["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "process_name"
        }
        assert "cluster resources" in procs

    def test_empty_result_has_no_data(self):
        oracle = AnalyticOracle(noise=0.0)
        result = Cluster(4, oracle).run(
            generate_workload(1, seed=0),
            get_policy("fifo-static", workers=2),
        )
        for rec in result.records:
            rec.trace = None
        tl = ResourceTimeline.from_result(result)
        assert not tl.has_data
        assert tl.summary()["net_peak_bytes_per_s"] == 0.0
