"""Backend equivalence: every reduce backend and both shuffle backends must
produce identical job output (collect_results) and identical overflow
accounting (dropped) — the execution strategy is a timing axis, never a
semantics axis."""

from collections import Counter

import numpy as np
import pytest

from repro.mapreduce import (
    JobConfig,
    MapReduceApp,
    PAD_KEY,
    REDUCE_BACKENDS,
    build_job,
    collect_results,
    exim_mainlog,
    eximparse,
    get_reduce_backend,
    wordcount,
    wordcount_corpus,
)

ALL_REDUCE = sorted(REDUCE_BACKENDS)

# (M, R, W, combiner) — exercises multi-wave map and reduce schedules.
CONFIG_GRID = [
    (1, 1, 1, False),
    (4, 3, 2, False),
    (7, 5, 3, True),
    (5, 8, 2, True),
]


def _job_output(app, corpus, **cfg_kwargs):
    cfg_kwargs.setdefault("capacity_factor", 8.0)
    cfg = JobConfig(**cfg_kwargs)
    ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
    return collect_results(ok, ov), int(dropped)


class TestReduceBackendEquivalence:
    @pytest.mark.parametrize("M,R,W,combiner", CONFIG_GRID)
    def test_wordcount_identical_across_backends(self, M, R, W, combiner):
        corpus = wordcount_corpus(1500, vocab_size=211, seed=M * 10 + R)
        app = wordcount(211)
        ref = _job_output(app, corpus, num_mappers=M, num_reducers=R,
                          num_workers=W, combiner=combiner)
        assert ref[0] == dict(Counter(corpus.tolist()))
        for name in ALL_REDUCE:
            got = _job_output(app, corpus, num_mappers=M, num_reducers=R,
                              num_workers=W, combiner=combiner,
                              reduce_backend=name)
            assert got == ref, name

    @pytest.mark.parametrize("M,R,W,combiner", CONFIG_GRID)
    def test_eximparse_identical_across_backends(self, M, R, W, combiner):
        log = exim_mainlog(1800, n_transactions=40, seed=M + R)
        app = eximparse(40)
        ref = _job_output(app, log, num_mappers=M, num_reducers=R,
                          num_workers=W, combiner=combiner)
        for name in ALL_REDUCE:
            got = _job_output(app, log, num_mappers=M, num_reducers=R,
                              num_workers=W, combiner=combiner,
                              reduce_backend=name)
            assert got == ref, name

    def test_dropped_identical_under_skew(self):
        """Capacity overflow accounting must not depend on the backend."""
        corpus = np.zeros(600, dtype=np.int32)  # one key: max skew
        app = wordcount(16)
        results = {
            name: _job_output(app, corpus, num_mappers=2, num_reducers=4,
                              capacity_factor=1.0, reduce_backend=name)
            for name in ALL_REDUCE
        }
        ref = results[ALL_REDUCE[0]]
        assert ref[1] > 0  # skew actually overflows
        assert all(r == ref for r in results.values())


# Shuffle-backend equivalence (lexsort vs all_to_all, emulated vs real
# mesh, per-phase dropped counters) lives in tests/test_plan.py: both
# shuffle families are modes of one ExecutionPlan, so their agreement is
# asserted once by the mode-equivalence suite.


class TestBackendValidation:
    def test_unknown_reduce_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown reduce backend"):
            JobConfig(num_mappers=1, num_reducers=1, reduce_backend="nope")

    def test_unknown_shuffle_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown shuffle backend"):
            JobConfig(num_mappers=1, num_reducers=1, shuffle_backend="nope")

    def test_unsupported_reduce_op_rejected_at_build(self):
        """pallas is sum-only; a max-op app must fail fast, not mis-reduce."""
        app = MapReduceApp(
            name="maxapp", key_space=8,
            map_fn=lambda t, v: (t, t, v), reduce_op="max",
        )
        cfg = JobConfig(num_mappers=2, num_reducers=2,
                        reduce_backend="pallas")
        with pytest.raises(ValueError, match="supports"):
            build_job(app, cfg, 64)

    def test_get_reduce_backend_unknown_name(self):
        with pytest.raises(ValueError, match="registered"):
            get_reduce_backend("missing")


class TestPallasPrecisionBound:
    def test_exact_below_bound_lossy_above(self):
        """The float32 MXU accumulator is a documented contract: integer
        sums are exact below EXACT_INT_BOUND and lose low bits above it —
        pick a non-pallas backend for workloads near the bound."""
        import jax.numpy as jnp

        from repro.mapreduce.backends import PallasReduceBackend

        backend = PallasReduceBackend()
        bound = PallasReduceBackend.EXACT_INT_BOUND
        keys = jnp.asarray([[3, 3, PAD_KEY, PAD_KEY]], jnp.int32)
        below = jnp.asarray([[bound // 2, bound // 2 - 1, 0, 0]], jnp.int32)
        ok, ov = backend.reduce(keys, below, "sum")
        assert int(ov[0, 0]) == bound - 1  # exact below the bound
        above = jnp.asarray([[bound, 1, 0, 0]], jnp.int32)
        _, ov = backend.reduce(keys, above, "sum")
        assert int(ov[0, 0]) != bound + 1  # lossy above: 2**24 + 1 rounds


class TestMaxReduceOp:
    def test_max_app_end_to_end(self):
        """A reduce_op='max' app through jnp and xla backends."""
        rng = np.random.default_rng(5)
        corpus = rng.integers(0, 1_000, size=900).astype(np.int32)

        def map_fn(tokens, valid):
            import jax.numpy as jnp
            keys = jnp.where(valid, tokens % 13, PAD_KEY)
            vals = jnp.where(valid, tokens, jnp.iinfo(jnp.int32).min)
            return keys, vals.astype(jnp.int32), valid

        app = MapReduceApp(name="groupmax", key_space=13, map_fn=map_fn,
                           reduce_op="max")
        want = {}
        for t in corpus.tolist():
            want[t % 13] = max(want.get(t % 13, -(2 ** 31)), t)
        for backend in ("jnp", "xla"):
            cfg = JobConfig(num_mappers=5, num_reducers=3,
                            capacity_factor=8.0, reduce_backend=backend)
            ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
            assert int(dropped) == 0
            # max aggregates may repeat per reducer slot row; collect the
            # per-key max rather than collect_results' summing gather.
            out_k = np.asarray(ok).ravel()
            out_v = np.asarray(ov).ravel()
            got = {}
            for k, v in zip(out_k, out_v):
                if int(k) != int(PAD_KEY):
                    got[int(k)] = max(got.get(int(k), -(2 ** 31)), int(v))
            assert got == want, backend
