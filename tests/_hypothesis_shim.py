"""Deterministic fallback for the ``hypothesis`` API surface this suite uses.

The container has no network installs, so ``hypothesis`` may be absent.
``tests/conftest.py`` registers this module as ``hypothesis`` in that case.
It is NOT a property-testing engine: no shrinking, no adaptive generation —
just a seeded-RNG sampler that runs each ``@given`` test ``max_examples``
times with deterministic draws, so the property tests still exercise many
parameter combinations and failures are reproducible.

Supported: ``given``, ``settings(max_examples=, deadline=)``, and the
strategies ``integers``, ``floats``, ``booleans``, ``just``,
``sampled_from``, ``lists``.
"""

from __future__ import annotations

import inspect
import types

import numpy as np

__version__ = "0.0-shim"
_DEFAULT_MAX_EXAMPLES = 10


class SearchStrategy:
    """A strategy is just a draw function over a seeded Generator."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))


def integers(min_value, max_value):
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1))
    )


def floats(min_value=None, max_value=None, allow_nan=None,
           allow_infinity=None, width=64):
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)
    return SearchStrategy(lambda rng: float(rng.uniform(lo, hi)))


def booleans():
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def just(value):
    return SearchStrategy(lambda rng: value)


def sampled_from(elements):
    elems = list(elements)
    return SearchStrategy(lambda rng: elems[int(rng.integers(len(elems)))])


def lists(elements, min_size=0, max_size=None):
    hi = min_size + 10 if max_size is None else max_size

    def draw(rng):
        size = int(rng.integers(min_size, hi + 1))
        return [elements.draw(rng) for _ in range(size)]

    return SearchStrategy(draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*st_args, **st_kwargs):
    if st_args:
        raise TypeError("shim supports keyword-style @given only")

    def deco(fn):
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items() if name not in st_kwargs
        ]

        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper,
                "_shim_max_examples",
                getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in st_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # Drawn params must not look like pytest fixtures.
        wrapper.__signature__ = sig.replace(parameters=remaining)
        if hasattr(fn, "_shim_max_examples"):
            wrapper._shim_max_examples = fn._shim_max_examples
        return wrapper

    return deco


class HealthCheck:
    all = ()


# Module-shaped ``strategies`` attribute so that both
# ``from hypothesis import strategies as st`` and
# ``import hypothesis.strategies`` resolve.
strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.just = just
strategies.sampled_from = sampled_from
strategies.lists = lists
