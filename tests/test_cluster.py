"""Cluster scheduling layer: workload, simulator, policies, online refit."""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    Dispatch,
    JobSpec,
    POLICIES,
    Plan,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.cluster.policies import (
    StaticFIFO,
    _np_design,
    register_policy,
)
from repro.core.features import design_matrix, fit_feature_spec


# Small grids keep bootstrap profiling fast in tests.
FAST_GRIDS = dict(
    mapper_grid=(4, 8, 16),
    reducer_grid=(4, 8, 16),
    worker_grid=(2, 4),
    bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
)


def fast_policy(name, **kwargs):
    return get_policy(name, seed=0, **FAST_GRIDS, **kwargs)


# ---------------------------------------------------------------------------
# Workload generation
# ---------------------------------------------------------------------------


class TestWorkload:
    def test_deterministic_and_sorted(self):
        a = generate_workload(30, seed=3)
        b = generate_workload(30, seed=3)
        assert a == b
        arr = [j.arrival for j in a]
        assert arr == sorted(arr) and arr[0] == 0.0
        assert generate_workload(30, seed=4) != a

    @pytest.mark.parametrize("arrival", ["poisson", "uniform", "bursty"])
    def test_arrival_processes(self, arrival):
        jobs = generate_workload(40, seed=0, arrival=arrival,
                                 mean_interarrival=0.5)
        assert len(jobs) == 40
        assert all(j.arrival >= 0 for j in jobs)

    def test_sizes_within_range_and_heterogeneous(self):
        jobs = generate_workload(50, seed=0, size_range=(1000, 64000))
        sizes = [j.size for j in jobs]
        assert min(sizes) >= 1000 and max(sizes) <= 64000
        assert len(set(j.app for j in jobs)) == 2

    def test_assign_deadlines(self):
        jobs = generate_workload(40, seed=0)
        est = lambda j: j.size * 1e-5  # noqa: E731
        with_dl = assign_deadlines(jobs, est, slack_range=(2.0, 3.0),
                                   fraction=0.5, seed=1)
        n_dl = sum(1 for j in with_dl if j.deadline is not None)
        assert 0 < n_dl < 40
        for j in with_dl:
            if j.deadline is not None:
                slack = (j.deadline - j.arrival) / est(j)
                assert 2.0 <= slack <= 3.0

    def test_bad_inputs(self):
        with pytest.raises(ValueError):
            generate_workload(0)
        with pytest.raises(ValueError):
            generate_workload(5, arrival="martian")
        with pytest.raises(ValueError):
            JobSpec(job_id=0, app="sort", size=100, arrival=0.0)


# ---------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------


class TestClusterSim:
    def test_fifo_accounting(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(12, seed=0, mean_interarrival=0.05)
        res = Cluster(8, oracle).run(jobs, get_policy("fifo-static",
                                                      workers=4))
        m = res.metrics()
        assert m["n_completed"] == 12 and m["n_rejected"] == 0
        for r in res.records:
            assert r.start >= r.spec.arrival
            assert r.finish == pytest.approx(r.start + r.true_time)
        # FIFO never reorders: starts follow arrival order.
        starts = [r.start for r in res.records]
        assert starts == sorted(starts)
        assert 0.0 < m["utilization"] <= 1.0

    def test_concurrency_bounded_by_workers(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(15, seed=1, mean_interarrival=0.01)
        total = 8
        res = Cluster(total, oracle).run(
            jobs, get_policy("fifo-static", workers=4)
        )
        events = []
        for r in res.records:
            events.append((r.start, r.plan.workers))
            events.append((r.finish, -r.plan.workers))
        # Sweep: completions release before same-time starts claim.
        events.sort(key=lambda e: (e[0], e[1]))
        in_use = 0
        for _, delta in events:
            in_use += delta
            assert 0 <= in_use <= total

    def test_oversized_plan_rejected(self):
        class Greedy(StaticFIFO):
            name = "greedy-test"

            def select(self, queue, free_workers, now):
                return Dispatch(queue[0], Plan("jnp", 8, 8, free_workers + 1))

        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(2, seed=0)
        with pytest.raises(ValueError, match="workers"):
            Cluster(4, oracle).run(jobs, Greedy())

    def test_stranded_jobs_fail_loudly(self):
        class Lazy(StaticFIFO):
            name = "lazy-test"

            def select(self, queue, free_workers, now):
                return None

        jobs = generate_workload(3, seed=0)
        with pytest.raises(RuntimeError, match="stranded"):
            Cluster(4, AnalyticOracle()).run(jobs, Lazy())


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------


class TestAnalyticOracle:
    def test_deterministic_per_job_and_noise_isolation(self):
        o = AnalyticOracle(noise=0.05, seed=0)
        t1 = o.time("wordcount", "jnp", 1 << 16, 8, 8, 4, job_id=1)
        assert t1 == o.time("wordcount", "jnp", 1 << 16, 8, 8, 4, job_id=1)
        assert t1 != o.time("wordcount", "jnp", 1 << 16, 8, 8, 4, job_id=2)

    def test_wave_quantization_nonmonotonic(self):
        # More workers can't hurt; more mappers is non-monotonic (the
        # paper's central observation).
        o = AnalyticOracle(noise=0.0)
        t4 = o.time("wordcount", "jnp", 1 << 16, 16, 8, 4)
        t8 = o.time("wordcount", "jnp", 1 << 16, 16, 8, 8)
        assert t8 < t4
        times = [o.time("wordcount", "jnp", 1 << 16, m, 8, 4)
                 for m in (2, 8, 64, 512)]
        best = int(np.argmin(times))
        assert 0 < best < 3  # interior optimum in M

    def test_backend_crossover(self):
        # pallas (high launch overhead, best throughput) wins big jobs,
        # jnp wins small ones — the categorical knob matters.
        o = AnalyticOracle(noise=0.0)
        small = {b: o.time("wordcount", b, 1 << 12, 8, 8, 4)
                 for b in o.backends()}
        big = {b: o.time("wordcount", b, 1 << 20, 8, 8, 4)
               for b in o.backends()}
        assert min(small, key=small.get) == "jnp"
        assert min(big, key=big.get) == "pallas"


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


def batch_trace(sizes, app="wordcount"):
    """All jobs arrive at t=0: pure ordering test bed."""
    return [
        JobSpec(job_id=i, app=app, size=s, arrival=0.0)
        for i, s in enumerate(sizes)
    ]


class TestPredictivePolicies:
    def test_registry(self):
        for name in ("fifo-static", "predict-fifo", "predict-sjf",
                     "predict-deadline"):
            assert name in POLICIES
        with pytest.raises(KeyError, match="unknown policy"):
            get_policy("lottery")
        with pytest.raises(ValueError, match="concrete name"):
            register_policy(type("Anon", (StaticFIFO,), {"name": "abstract"}))

    def test_np_design_matches_jnp_design_matrix(self):
        rng = np.random.default_rng(0)
        rows = rng.uniform(1, 40, size=(17, 4))
        spec = fit_feature_spec(rows, degree=3, cross_terms=True, scale=True)
        np.testing.assert_allclose(
            _np_design(spec, rows),
            np.asarray(design_matrix(spec, rows), dtype=np.float64),
            rtol=1e-5, atol=1e-6,
        )

    def test_bootstrap_fills_model_database(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = Cluster(8, oracle)
        pol = fast_policy("predict-sjf")
        pol.prepare(cluster, ["wordcount"])
        assert set(pol.db.backends_for("wordcount", oracle.platform)) == set(
            oracle.backends()
        )

    def test_sjf_dispatches_in_predicted_order(self):
        # Single-grant worker grid + 4-worker cluster: one job at a time,
        # so start order IS the policy's predicted-time order.
        oracle = AnalyticOracle(noise=0.0)
        jobs = batch_trace([1 << 17, 1 << 13, 1 << 15, 1 << 16, 1 << 14])
        pol = get_policy(
            "predict-sjf", seed=0,
            mapper_grid=(4, 8, 16), reducer_grid=(4, 8, 16),
            worker_grid=(4,), bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
            online=False,
        )
        res = Cluster(4, oracle).run(jobs, pol)
        by_start = sorted(res.records, key=lambda r: r.start)
        preds = [r.plan.predicted_time for r in by_start]
        assert preds == sorted(preds)
        # Sanity: predicted order matches true-size order on this trace.
        assert [r.spec.size for r in by_start] == sorted(j.size for j in jobs)

    def test_deadline_policy_rejects_infeasible_admits_feasible(self):
        oracle = AnalyticOracle(noise=0.0)
        tight = JobSpec(job_id=0, app="wordcount", size=1 << 17,
                        arrival=0.0, deadline=0.01)  # impossible
        loose = JobSpec(job_id=1, app="wordcount", size=1 << 14,
                        arrival=0.0, deadline=60.0)
        res = Cluster(8, oracle).run([tight, loose],
                                     fast_policy("predict-deadline"))
        rec_tight, rec_loose = res.records
        assert not rec_tight.admitted
        assert "infeasible" in rec_tight.reject_reason
        assert rec_loose.completed and rec_loose.met_deadline
        assert res.metrics()["slo_attainment"] == 0.5

    def test_predictions_attached_before_dispatch(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(8, seed=2, mean_interarrival=0.05)
        res = Cluster(8, oracle).run(jobs, fast_policy("predict-fifo"))
        for r in res.records:
            assert r.plan.predicted_time is not None
            assert r.plan.predicted_time > 0

    def test_online_refit_reduces_prediction_mae(self):
        # Coarse bootstrap (minimal sample count over the full config
        # space) + noise-free truth: the only error source is model
        # coarseness, which every completed job's observation chips away
        # at — so in-trace MAE must drop over the trace.
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(40, seed=5, mean_interarrival=0.05,
                                 size_range=(1 << 14, 1 << 18))
        kwargs = dict(seed=0, n_bootstrap=20)
        cluster = Cluster(8, oracle)
        online = cluster.run(jobs, get_policy("predict-sjf", online=True,
                                              **kwargs))
        m = online.metrics()
        assert m["pred_mae_pct_second_half"] < m["pred_mae_pct_first_half"]
        # ...and beats the frozen-model run on the same trace's second half.
        frozen = cluster.run(jobs, get_policy("predict-sjf", online=False,
                                              **kwargs))
        fm = frozen.metrics()
        assert (m["pred_mae_pct_second_half"]
                < fm["pred_mae_pct_second_half"])

    def test_seedless_refiner_demands_margin_before_replacing_model(self):
        # Warm-started from a saved db (no bootstrap profiles): live
        # observations alone must reach 2x the feature count before the
        # loaded model is replaced — clustered-config refits are too
        # rank-deficient to trust at bare determinacy.
        from repro.cluster.online import OnlineRefiner
        from repro.core.predictor import ModelDatabase
        from repro.core.regression import fit

        rng = np.random.default_rng(0)
        db = ModelDatabase()
        boot = rng.uniform(1, 40, size=(30, 2))
        db.put("wc", "plat", fit(boot, boot.sum(axis=1)), backend="jnp")
        ref = OnlineRefiner(db, "plat",
                            fit_kwargs=dict(degree=2, scale=True,
                                            lam=1e-6, cross_terms=False))
        n_feat = 1 + 2 * 2  # degree-2, 2 params, no cross terms
        before = db.get("wc", "plat", backend="jnp")
        refits = [
            ref.observe("wc", "jnp", rng.uniform(1, 40, size=2), float(i + 1))
            for i in range(2 * n_feat)
        ]
        assert not any(refits[: 2 * n_feat - 1])
        assert refits[-1]  # replaced only at the 2x margin
        assert db.get("wc", "plat", backend="jnp") is not before

    def test_online_refit_updates_database_model(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(25, seed=6, mean_interarrival=0.05)
        pol = fast_policy("predict-sjf", n_bootstrap=20)
        Cluster(8, oracle).run(jobs, pol)
        assert pol.refiner.n_refits > 0
        assert pol._model_version > 0


class TestEngineOracleSmoke:
    def test_real_engine_trace(self):
        # The simulated cluster driving the *actual* MapReduce engine:
        # 2 tiny jobs, static FIFO (no bootstrap profiling -> 1 compile/job).
        from repro.cluster import EngineOracle

        oracle = EngineOracle()
        jobs = [
            JobSpec(job_id=0, app="wordcount", size=2048, arrival=0.0),
            JobSpec(job_id=1, app="eximparse", size=2048, arrival=0.0),
        ]
        res = Cluster(4, oracle).run(
            jobs, get_policy("fifo-static", mappers=4, reducers=4, workers=2)
        )
        assert res.metrics()["n_completed"] == 2
        assert all(r.true_time > 0 for r in res.records)


# ---------------------------------------------------------------------------
# Telemetry integration: traces, per-phase refits, resource-aware policy,
# queue-aware admission
# ---------------------------------------------------------------------------


class TestOracleTraces:
    def test_analytic_trace_matches_time(self):
        o = AnalyticOracle(noise=0.05, seed=3)
        t = o.time("wordcount", "jnp", 1 << 16, 8, 8, 4, job_id=7)
        trace = o.take_trace()
        assert trace is not None
        assert trace.phase_names() == ["map", "shuffle", "reduce"]
        assert trace.phase_time_sum() == pytest.approx(t, rel=1e-9)
        assert trace.check_conservation() == []

    def test_analytic_phase_profile_noise_free_and_sums(self):
        o = AnalyticOracle(noise=0.1, seed=0)
        prof = o.phase_profile("eximparse", "xla", 1 << 15, 8, 8, 4)
        assert set(prof["time_s"]) == {"map", "shuffle", "reduce"}
        assert sum(prof["time_s"].values()) == pytest.approx(
            o.time("eximparse", "xla", 1 << 15, 8, 8, 4, _noiseless=True)
        )
        assert prof["shuffle_bytes"] > 0
        # shuffle bytes scale with input size
        prof2 = o.phase_profile("eximparse", "xla", 1 << 16, 8, 8, 4)
        assert prof2["shuffle_bytes"] == pytest.approx(
            2 * prof["shuffle_bytes"]
        )

    def test_cluster_attaches_traces_to_records(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(6, seed=1, mean_interarrival=0.05)
        res = Cluster(8, oracle).run(
            jobs, get_policy("fifo-static", workers=4)
        )
        for r in res.records:
            assert r.trace is not None
            assert r.trace.phase_time_sum() == pytest.approx(r.true_time)


class TestPerPhaseOnlineRefit:
    def test_observe_phases_publishes_resource_models(self):
        from repro.cluster.online import OnlineRefiner
        from repro.core.predictor import ModelDatabase

        rng = np.random.default_rng(0)
        db = ModelDatabase()
        ref = OnlineRefiner(
            db, "plat",
            phase_fit_kwargs=dict(degree=1, scale=True, lam=1e-6,
                                  cross_terms=False),
        )
        n_feat = 1 + 2  # degree-1, 2 params
        refit_seen = False
        for i in range(2 * n_feat + 1):
            row = rng.uniform(1, 40, size=2)
            refit_seen |= ref.observe_phases(
                "wc", "jnp", row,
                {"map": row[0] * 0.1, "shuffle": 1.0, "reduce": row[1]},
            )
        assert refit_seen and ref.n_phase_refits > 0
        assert set(db.resources_for("wc", "plat", "jnp")) == {
            "map:time_s", "shuffle:time_s", "reduce:time_s"
        }

    def test_policy_feeds_traces_to_phase_refiner(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(40, seed=7, mean_interarrival=0.05)
        pol = fast_policy("predict-sjf")
        Cluster(8, oracle).run(jobs, pol)
        # every completion contributed phase rows (refits need volume, the
        # accumulation itself must always happen)
        total_rows = sum(
            len(v) for v in pol.refiner._phase_obs.values()
        )
        assert total_rows == 40 * 3  # 3 phases per analytic trace


class TestResourceAwarePolicy:
    def test_registered(self):
        assert "predict-resource" in POLICIES

    def test_default_identical_to_sjf(self):
        oracle = AnalyticOracle(noise=0.0)
        jobs = generate_workload(25, seed=3, mean_interarrival=0.05)
        cluster = Cluster(8, oracle)
        sjf = cluster.run(jobs, fast_policy("predict-sjf"))
        res = cluster.run(jobs, fast_policy("predict-resource"))
        # unconstrained fabric: decision-for-decision identical
        assert [r.start for r in res.records] == [
            r.start for r in sjf.records
        ]
        assert res.metrics()["makespan_s"] == pytest.approx(
            sjf.metrics()["makespan_s"]
        )

    def test_bootstrap_publishes_shuffle_bytes_models(self):
        from repro.telemetry.models import phase_resource_key

        oracle = AnalyticOracle(noise=0.0)
        pol = fast_policy("predict-resource")
        pol.prepare(Cluster(8, oracle), ["wordcount"])
        res_key = phase_resource_key("shuffle", "bytes")
        for b in oracle.backends():
            assert ("wordcount", oracle.platform, b, res_key) in pol.db
        # the bytes model tracks the oracle's linear size law (models
        # are keyed per combiner arm; default grid is combiner-off only)
        model = pol._bytes_models[("wordcount", "jnp", False)]
        from repro.cluster.policies import SIZE_UNIT, _np_predict

        lo = _np_predict(model, np.asarray([8, 8, 4, (1 << 14) / SIZE_UNIT]))
        hi = _np_predict(model, np.asarray([8, 8, 4, (1 << 16) / SIZE_UNIT]))
        assert hi[0] == pytest.approx(4 * lo[0], rel=0.05)

    def test_tight_capacity_defers_shuffle_heavy_jobs(self):
        # WordCount is shuffle-heavy (8 bytes/token at wordcount speed,
        # ~586 KB/s predicted at this size); EximParse moves a third of
        # the bytes over a longer run (~170 KB/s).  With a fabric budget
        # that fits one wordcount plus an eximparse but not two
        # wordcounts, the policy must dispatch the (slower-but-lighter)
        # eximparse job while the first wordcount runs, even though pure
        # SJF would pick the second wordcount.
        oracle = AnalyticOracle(noise=0.0)
        jobs = [
            JobSpec(job_id=0, app="wordcount", size=1 << 17, arrival=0.0),
            JobSpec(job_id=1, app="wordcount", size=1 << 17, arrival=0.0),
            JobSpec(job_id=2, app="eximparse", size=1 << 17, arrival=0.0),
        ]
        pol = get_policy(
            "predict-resource", seed=0, net_capacity=7e5,
            mapper_grid=(4, 8, 16), reducer_grid=(4, 8, 16),
            worker_grid=(2,), bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
            online=False,
        )
        res = Cluster(4, oracle).run(jobs, pol)
        assert res.metrics()["n_completed"] == 3
        assert pol.n_contention_deferrals > 0
        by_start = sorted(res.records, key=lambda r: (r.start, r.spec.job_id))
        assert [r.spec.job_id for r in by_start] == [0, 2, 1]

    def test_net_capacity_validation(self):
        with pytest.raises(ValueError, match="net_capacity"):
            get_policy("predict-resource", net_capacity=0.0)


class TestQueueAwareAdmission:
    def grids(self, **kw):
        return dict(
            seed=0, mapper_grid=(4, 8, 16), reducer_grid=(4, 8, 16),
            worker_grid=(8,), bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
            **kw,
        )

    def predicted_fastest(self, cluster, size):
        probe = get_policy("predict-deadline", **self.grids())
        probe.prepare(cluster, ["wordcount"])
        job = JobSpec(job_id=99, app="wordcount", size=size, arrival=0.0)
        return probe.best_plan(job, cluster.total_workers).predicted_time

    def test_queued_infeasible_job_rejected_up_front(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = Cluster(8, oracle)
        t_one = self.predicted_fastest(cluster, 1 << 17)
        # A is feasible and runs first (earlier deadline).  B's deadline
        # covers its own service time but not A's ahead of it: feasible at
        # dispatch, infeasible once queued.
        a = JobSpec(job_id=0, app="wordcount", size=1 << 17, arrival=0.0,
                    deadline=t_one * 1.5)
        b = JobSpec(job_id=1, app="wordcount", size=1 << 17, arrival=0.0,
                    deadline=t_one * 1.6)
        res = cluster.run([a, b], get_policy("predict-deadline",
                                             **self.grids()))
        rec_a, rec_b = res.records
        assert rec_a.admitted and rec_a.met_deadline
        assert not rec_b.admitted
        assert "queue wait" in rec_b.reject_reason
        # legacy behavior check: without queue awareness B looks feasible
        # at t=0 and is only rejected after its budget has burned down in
        # the queue (late rejection, no queue-wait term in the reason)
        res_off = cluster.run(
            [a, b], get_policy("predict-deadline", queue_aware=False,
                               **self.grids())
        )
        assert not res_off.records[1].admitted
        assert "queue wait" not in res_off.records[1].reject_reason

    def test_queued_but_feasible_job_admitted_and_meets(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = Cluster(8, oracle)
        t_one = self.predicted_fastest(cluster, 1 << 17)
        a = JobSpec(job_id=0, app="wordcount", size=1 << 17, arrival=0.0,
                    deadline=t_one * 1.5)
        b = JobSpec(job_id=1, app="wordcount", size=1 << 17, arrival=0.0,
                    deadline=t_one * 3.0)  # generous: survives the queue
        res = cluster.run([a, b], get_policy("predict-deadline",
                                             **self.grids()))
        rec_a, rec_b = res.records
        assert rec_a.admitted and rec_a.met_deadline
        assert rec_b.admitted and rec_b.met_deadline
        assert res.metrics()["slo_attainment"] == 1.0


class TestEngineOracleTraced:
    def test_traced_engine_jobs_carry_real_traces(self):
        from repro.cluster import EngineOracle

        oracle = EngineOracle(traced=True)
        jobs = [
            JobSpec(job_id=0, app="wordcount", size=2048, arrival=0.0),
            JobSpec(job_id=1, app="wordcount", size=2048, arrival=1000.0),
        ]
        res = Cluster(4, oracle).run(
            jobs, get_policy("fifo-static", mappers=4, reducers=4, workers=2)
        )
        for r in res.records:
            assert r.trace is not None
            assert r.trace.phase_names() == ["map", "shuffle", "reduce"]
            assert r.trace.check_conservation() == []
            # wall-clocked time is the traced job's outer total
            assert r.true_time > 0

    def test_untraced_phase_profile_keeps_time_untraced(self):
        from repro.cluster import EngineOracle

        oracle = EngineOracle()
        prof = oracle.phase_profile("wordcount", "jnp", 2048, 4, 4, 2)
        assert set(prof["time_s"]) == {"map", "shuffle", "reduce"}
        assert prof["shuffle_bytes"] > 0
        oracle.time("wordcount", "jnp", 2048, 4, 4, 2)
        assert oracle.take_trace() is None


class TestQueueAwareParallelism:
    def test_concurrently_feasible_jobs_not_rejected(self):
        # Two deadline jobs whose grants fit the pool side by side must
        # both be admitted: neither actually queues behind the other, so
        # the sweep's virtual pool must not count phantom wait.
        oracle = AnalyticOracle(noise=0.0)
        cluster = Cluster(16, oracle)
        probe = get_policy(
            "predict-deadline", seed=0, mapper_grid=(4, 8, 16),
            reducer_grid=(4, 8, 16), worker_grid=(8,),
            bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
        )
        probe.prepare(cluster, ["wordcount"])
        t_one = probe.best_plan(
            JobSpec(job_id=99, app="wordcount", size=1 << 17, arrival=0.0),
            16,
        ).predicted_time
        jobs = [
            JobSpec(job_id=i, app="wordcount", size=1 << 17, arrival=0.0,
                    deadline=t_one * 1.3)
            for i in range(2)
        ]
        res = cluster.run(jobs, get_policy(
            "predict-deadline", seed=0, mapper_grid=(4, 8, 16),
            reducer_grid=(4, 8, 16), worker_grid=(8,),
            bootstrap_sizes=(1 << 13, 1 << 15, 1 << 17),
        ))
        assert all(r.admitted for r in res.records)
        assert res.metrics()["slo_attainment"] == 1.0


class TestPhaseRefitCadence:
    def test_phase_refits_run_at_slower_cadence(self):
        from repro.cluster.online import OnlineRefiner
        from repro.core.predictor import ModelDatabase

        rng = np.random.default_rng(1)
        ref = OnlineRefiner(
            ModelDatabase(), "plat", refit_every=1,
            phase_fit_kwargs=dict(degree=1, scale=True, lam=1e-6,
                                  cross_terms=False),
        )
        assert ref.phase_refit_every == 5
        refits = [
            ref.observe_phases("wc", "jnp", rng.uniform(1, 40, size=2),
                               {"map": 1.0})
            for _ in range(40)
        ]
        # plenty of data, but at most one refit per 5 completions
        assert 0 < sum(refits) <= 40 // 5
        with pytest.raises(ValueError, match="phase_refit_every"):
            OnlineRefiner(ModelDatabase(), "plat", phase_refit_every=0)
